"""Ingest-while-serving — streaming appends against stop-the-world rebuilds
(DESIGN.md §12).

A warm served instance takes K appended chunks; after each append the same
two full-scope probes re-run (their cached answers went stale through the
``__rows__`` pseudo-scope bump, nothing else did).  The reference for each
round is the stop-the-world alternative: a FRESH Daisy built from all rows
so far, cleaned by the same probes.

The dataset follows serve_bg_warmup's equivalence regime (§12 caveats):
attribute-disjoint rules (FD on zip/city, DC on price/disc),
cluster-disjoint cities, candidate sets under k, full-scope probes.  Chunk
size equals ``strip_rows``, so appended rows fill whole ledger strips and
the pair accounting below is exact rather than rounded.

Acceptance gates (ISSUE 6, enforced here and smoked in CI):

(a) **bit-identity** — every round's probe answers AND the full canonical
    overlay state (values, kinds, counts over the valid prefix) equal the
    rebuilt reference's;
(b) **O(new×all) delta** — the round's DC detect pairs are exactly
    ``checked×new`` (the queued ingest-delta) ``+ new×total`` (the fresh
    strips' own clean), strictly fewer than the rebuild's ``total²`` full
    scan;
(c) **zero checked-strip rescans** — implied by the exact equality in (b):
    both scans' row sides cover only checked×fresh-column or fresh-row
    strips, so any re-scanned checked strip would add ≥ strip×total pairs
    on top — and double-checked against the ledger (every pre-append
    checked strip still checked, fresh strips drained to warm).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv
from repro.core.constraints import DC, FD, Atom
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import GroupBySpec, Pred, Query
from repro.core.relation import make_relation
from repro.launch.serve import ServeOptions
from repro.service import QueryServer

RULES = [
    FD("zc", "zip", "city"),
    DC("pd", [Atom("price", "<", "price"), Atom("disc", ">", "disc")]),
]
OVERLAY = ["zip", "city", "price", "disc"]


def build_data(total: int, groups: int, seed: int = 23):
    """Cluster-disjoint FD columns + a noisy-monotone DC pair, for the
    whole stream (seed rows and appends drawn in one pass, so streamed and
    rebuilt instances see byte-identical rows)."""
    rng = np.random.default_rng(seed)
    zipc = rng.integers(0, groups, total).astype(np.int32)
    city = (zipc * 8 + rng.integers(0, 4, total)).astype(np.int32)
    price = rng.integers(0, 100, total).astype(np.int32)
    disc = (100 - price + rng.integers(-5, 5, total)).astype(np.int32)
    return {"zip": zipc, "city": city, "price": price, "disc": disc}


def _make_daisy(data, chunk: int, tracer=None):
    rel = make_relation(data, overlay=OVERLAY, k=8, rules=["zc", "pd"])
    cfg = DaisyConfig(
        use_cost_model=False, accuracy_threshold=2.0,
        dc_block=chunk, strip_rows=chunk,
    )
    return Daisy({"h": rel}, {"h": RULES}, cfg, tracer=tracer)


def _probes():
    return [
        Query("h", groupby=GroupBySpec(keys=("city",), agg="count")),
        Query("h", preds=(Pred("price", ">=", 0),)),
    ]


def _canonical(daisy, n_rows: int):
    """Capacity-independent overlay signature over the valid prefix."""
    rel = daisy.db["h"]
    sig = {}
    for attr in OVERLAY:
        vals = np.asarray(rel.cand[attr])[:n_rows]
        cnts = np.asarray(rel.ccount[attr])[:n_rows]
        kinds = np.asarray(rel.ckind[attr])[:n_rows]
        sig[attr] = [
            sorted(
                (int(v), int(kk), round(float(c), 3))
                for v, c, kk in zip(vals[r], cnts[r], kinds[r])
                if c > 1e-9
            )
            for r in range(n_rows)
        ]
    return sig


def _answers(results, n_rows: int):
    out = []
    for res in results:
        if res.groups is not None:
            # group buffers are capacity-padded; real groups have count > 0
            cols = [
                (k, np.asarray(v)) for k, v in sorted(res.groups.items())
                if np.asarray(v).ndim == 1
            ]
            live = np.asarray(res.groups["count"]) > 0
            out.append(sorted(zip(*(v[live].tolist() for _, v in cols))))
        else:
            out.append(np.asarray(res.mask)[:n_rows].tolist())
    return out


def _dc_pairs(reports) -> int:
    """DC detect pairs across a round's step reports (ingest-delta + clean),
    keyed by rule name so FD group-by work stays out of the accounting."""
    return sum(
        s.detect_pairs
        for rep in reports
        for s in rep.steps
        if s.rule == "pd"
    )


def _rebuild(data, n_rows: int, chunk: int):
    """The stop-the-world reference: fresh instance over rows[:n_rows],
    cleaned by the same probes.  Returns (answers, overlay signature,
    DC detect pairs of its full clean)."""
    daisy = _make_daisy({k: v[:n_rows] for k, v in data.items()}, chunk)
    results = [daisy.execute(q) for q in _probes()]
    pairs = _dc_pairs([r.report for r in results])
    return _answers(results, n_rows), _canonical(daisy, n_rows), pairs


def run(quick: bool = False, tracer=None):
    opts = ServeOptions(
        sessions=2,
        rows=128 if quick else 512,
        ingest_chunks=3 if quick else 6,
        ingest_rows=32 if quick else 64,
        seed=23,
    )
    chunk = opts.ingest_rows
    total = opts.rows + opts.held_back_rows
    data = build_data(total, groups=max(opts.rows // 16, 4), seed=opts.seed)

    # only the streamed instance is traced; the stop-the-world rebuild
    # reference stays untraced, so gate (a) doubles as the traced-vs-
    # untraced bit-neutrality gate (DESIGN.md §13)
    daisy = _make_daisy(
        {k: v[: opts.rows] for k, v in data.items()}, chunk, tracer=tracer
    )
    server = QueryServer(daisy, max_batch=opts.max_batch)
    sessions = [server.open_session(f"user{i}") for i in range(opts.sessions)]
    windows = []

    def probe_round():
        t0 = time.perf_counter()
        tickets = [
            server.submit(sessions[i % len(sessions)], q)
            for i, q in enumerate(_probes())
        ]
        server.drain()
        dt = time.perf_counter() - t0
        windows.append((t0, t0 + dt))
        return [t.result for t in tickets], dt

    # warm the seed instance (both scopes fully cleaned and cached)
    warm_results, warm_dt = probe_round()
    ref_ans, ref_sig, _ = _rebuild(data, opts.rows, chunk)
    assert _answers(warm_results, opts.rows) == ref_ans
    assert _canonical(daisy, opts.rows) == ref_sig

    rows_csv = []
    n_prev = opts.rows
    for c in range(opts.ingest_chunks):
        lo = opts.rows + c * chunk
        chunk_data = {k: v[lo: lo + chunk] for k, v in data.items()}
        scope = daisy.ledger.scope("h", "pd")
        checked_strips_before = {
            int(s) for s in range(scope.n_strips)
            if int(s) not in set(int(x) for x in scope.cold_strips())
        }
        ingest_ticket = server.ingest("h", chunk_data)
        results, dt = probe_round()
        n_now = lo + chunk
        assert ingest_ticket.result.rows == chunk

        # gate (a): answers and overlay state bit-identical to the rebuild
        reb_ans, reb_sig, reb_pairs = _rebuild(data, n_now, chunk)
        assert _answers(results, n_now) == reb_ans, (
            f"round {c}: streamed answers differ from stop-the-world rebuild"
        )
        sig = _canonical(daisy, n_now)
        for attr in OVERLAY:
            assert sig[attr] == reb_sig[attr], (
                f"round {c}: overlay state diverged on {attr!r}"
            )

        # gate (b): delta work is exactly checked x new + new x total pairs,
        # strictly under the rebuild's full scan
        pairs = _dc_pairs([r.report for r in results])
        expected = n_prev * chunk + chunk * n_now
        assert pairs == expected, (
            f"round {c}: DC pairs {pairs} != checked x new + new x total "
            f"{expected} — a checked strip was re-scanned"
        )
        assert pairs < reb_pairs, (
            f"round {c}: streamed delta {pairs} not under rebuild full scan "
            f"{reb_pairs}"
        )

        # gate (c): ledger view — pre-append checked strips stayed checked,
        # fresh strips drained to warm
        scope = daisy.ledger.scope("h", "pd")
        cold_now = {int(s) for s in scope.cold_strips()}
        assert not (checked_strips_before & cold_now), (
            f"round {c}: an append re-opened a checked strip"
        )
        assert not cold_now and not scope.fresh, (
            f"round {c}: fresh strips not drained ({cold_now}, {scope.fresh})"
        )

        rows_csv.append(
            [c, n_now, chunk, pairs, reb_pairs, round(dt, 4), round(warm_dt, 4)]
        )
        print(
            f"serve_ingest round {c}: {n_now} rows — DC pairs {pairs} "
            f"(= {n_prev}x{chunk} delta + {chunk}x{n_now} fresh) vs rebuild "
            f"{reb_pairs}; probe round {dt*1e3:.0f}ms"
        )
        n_prev = n_now

    snap = server.snapshot()

    # gate (d) (DESIGN.md §13, under --trace only): the spans explain
    # >= 90% of the measured probe-round wall-clock (queue-wait is a
    # synthetic overlapping track and is excluded)
    cov = roll = None
    if tracer is not None:
        from repro.obs import coverage, rollup

        events = tracer.events()
        cov = coverage(events, windows, exclude_threads=("queue",))
        assert cov >= 0.9, (
            f"trace rollup covers only {cov:.1%} of the serving wall-clock"
        )
        roll = rollup(events)
        print(f"serve_ingest trace: {len(events)} spans cover {cov:.1%} of "
              f"{sum(b - a for a, b in windows):.2f}s serving")

    print(
        f"serve_ingest: {snap['ingests']} appends / {snap['ingested_rows']} "
        f"rows streamed into a live instance; answers bit-identical to "
        f"stop-the-world rebuilds at every round; "
        f"{snap['ingest_pending_deltas']} pending deltas drained"
    )
    artifact = write_csv(
        "serve_ingest",
        ["round", "rows_total", "rows_appended", "dc_pairs_streamed",
         "dc_pairs_rebuild", "probe_seconds", "warm_probe_seconds"],
        rows_csv,
    )
    return {
        "artifact": artifact,
        "gates": {
            "bit_identical": True,
            "delta_pairs_exact": True,
            "no_checked_strip_rescan": True,
            "trace_coverage": cov,
        },
        "headline": {
            "appends": snap["ingests"],
            "ingested_rows": snap["ingested_rows"],
            "pending_deltas": snap["ingest_pending_deltas"],
            "final_rows": int(np.asarray(daisy.db["h"].num_rows())),
            "rounds": [
                {"round": r[0], "dc_pairs_streamed": r[3],
                 "dc_pairs_rebuild": r[4]}
                for r in rows_csv
            ],
        },
        "rollup": roll,
    }


if __name__ == "__main__":
    run()
