"""Fig. 7 — SP cost vs orderkey (lhs) cardinality; rhs-filter queries.

Daisy vs offline over lineorder with FD orderkey -> suppkey; 50
non-overlapping range queries on the rhs covering the whole dataset.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import build_lineorder_db, run_daisy, run_offline, write_csv
from repro.core.executor import DaisyConfig
from repro.core.operators import Pred, Query

N = 4096
QUERIES = 50


def rhs_range_queries(n_suppkeys: int):
    edges = np.linspace(0, n_suppkeys, QUERIES + 1).astype(int)
    return [
        Query("t", preds=(Pred("suppkey", ">=", int(lo)), Pred("suppkey", "<", int(hi))))
        for lo, hi in zip(edges[:-1], edges[1:])
    ]


def run(quick: bool = False):
    rows = []
    cards = [64, 256, 1024] if quick else [64, 256, 1024, 2048]
    for n_ok in cards:
        rel, fd, _ = build_lineorder_db(N, n_ok, max(n_ok // 8, 16))
        qs = rhs_range_queries(max(n_ok // 8, 16))
        t_d = run_daisy(rel, [fd], qs, DaisyConfig(expected_queries=QUERIES))
        t_o = run_offline(rel, [fd], qs)
        rows.append([n_ok, round(t_d, 3), round(t_o, 3), round(t_o / t_d, 2)])
        print(f"fig07 orderkeys={n_ok}: daisy {t_d:.2f}s offline {t_o:.2f}s "
              f"(x{t_o/t_d:.2f})")
    return write_csv("fig07", ["orderkeys", "daisy_s", "offline_s", "speedup"], rows)


if __name__ == "__main__":
    run()
