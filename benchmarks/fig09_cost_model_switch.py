"""Fig. 9 — incremental-only vs full-only vs Daisy-with-cost-model.

The regime where each violating rhs takes many candidate values (expensive
updates): Daisy should start incremental and switch to full mid-workload,
beating both pure strategies.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv
from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.offline import OfflineCleaner
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation

N = 4096
QUERIES = 90


def build():
    rng = np.random.default_rng(7)
    # disjoint dirty groups with many distinct rhs values -> heavy updates
    a = (np.arange(N) // 8).astype(np.int32)
    b = (a * 100 + rng.integers(0, 97, N)).astype(np.int32)
    rel = make_relation({"a": a, "b": b}, overlay=["a", "b"], k=8, rules=["r"])
    return rel, FD("r", "a", "b")


def workload():
    return [Query("t", preds=(Pred("a", "==", i),)) for i in range(QUERIES)]


def run(quick: bool = False):
    nq = 30 if quick else QUERIES
    qs = workload()[:nq]
    results = []

    rel, fd = build()
    d_inc = Daisy({"t": rel}, {"t": [fd]}, DaisyConfig(use_cost_model=False))
    t0 = time.perf_counter()
    for q in qs:
        d_inc.execute(q)
    t_inc = time.perf_counter() - t0

    rel, fd = build()
    t_off = 0.0
    off = OfflineCleaner({"t": rel}, {"t": [fd]})
    t0 = time.perf_counter()
    off.clean_all()
    for q in qs:
        off.execute(q)
    t_off = time.perf_counter() - t0

    rel, fd = build()
    d_cm = Daisy(
        {"t": rel}, {"t": [fd]},
        DaisyConfig(use_cost_model=True, expected_queries=nq),
    )
    t0 = time.perf_counter()
    switched_at = None
    for i, q in enumerate(qs):
        res = d_cm.execute(q)
        if switched_at is None and any(s.mode == "full" for s in res.report.steps):
            switched_at = i
    t_daisy = time.perf_counter() - t0

    results.append(
        ["incremental", round(t_inc, 3)],
    )
    results.append(["offline", round(t_off, 3)])
    results.append([f"daisy(switch@{switched_at})", round(t_daisy, 3)])
    print(f"fig09: incremental {t_inc:.2f}s | offline {t_off:.2f}s | "
          f"daisy {t_daisy:.2f}s (switched at query {switched_at})")
    return write_csv("fig09", ["strategy", "seconds"], results)


if __name__ == "__main__":
    run()
