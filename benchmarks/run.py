"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
        [--json-out [DIR]] [--trace]

Default is the quick profile (CI-sized datasets); --full runs the
paper-scale sweeps.  CSVs land in experiments/bench/.

``--json-out`` writes one machine-readable ``BENCH_<name>.json`` per
benchmark (acceptance gates, headline numbers, and — under ``--trace`` —
the per-phase span rollup from DESIGN.md §13) into DIR (default: the CSV
output dir).  CI uploads these as artifacts so a run's gate results are
inspectable without re-running.  ``--trace`` hands a live ``repro.obs``
tracer to every benchmark whose ``run`` accepts one, which also arms
their trace-coverage gates.
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time
import traceback

from benchmarks import (
    fig07_orderkey_selectivity,
    fig08_suppkey_selectivity,
    fig09_cost_model_switch,
    fig10_multi_rule,
    fig11_violation_scaling,
    fig12_dc_inequality,
    fig13_join_queries,
    fig_dist_detect,
    kernel_sparsity,
    serve_bg_warmup,
    serve_ingest,
    serve_overload,
    serve_throughput,
    table5_accuracy,
    table8_exploratory,
)
from benchmarks.common import OUT_DIR

MODULES = [
    ("fig07", fig07_orderkey_selectivity),
    ("fig08", fig08_suppkey_selectivity),
    ("fig09", fig09_cost_model_switch),
    ("fig10", fig10_multi_rule),
    ("fig11", fig11_violation_scaling),
    ("fig12", fig12_dc_inequality),
    ("fig13", fig13_join_queries),
    ("fig_dist", fig_dist_detect),
    ("kernel_sparsity", kernel_sparsity),
    ("serve", serve_throughput),
    ("serve_bg", serve_bg_warmup),
    ("serve_ingest", serve_ingest),
    ("serve_overload", serve_overload),
    ("table5", table5_accuracy),
    ("table8", table8_exploratory),
]


def _run_one(name, mod, quick: bool, trace: bool):
    """Run one benchmark, normalizing its return into the JSON record
    shape.  Benchmarks predating ISSUE 8 return a CSV path; the traced
    serving benchmarks return ``{artifact, gates, headline, rollup}``."""
    kwargs = {"quick": quick}
    tracer = None
    if trace and "tracer" in inspect.signature(mod.run).parameters:
        from repro.obs import Tracer

        tracer = Tracer()
        kwargs["tracer"] = tracer
    out = mod.run(**kwargs)
    if not isinstance(out, dict):
        out = {"artifact": out}
    record = {
        "benchmark": name,
        "quick": quick,
        "status": "ok",
        "artifact": out.get("artifact"),
        "gates": out.get("gates", {}),
        "headline": out.get("headline", {}),
        "rollup": out.get("rollup"),
    }
    if tracer is not None:
        record["spans"] = len(tracer)
        record["dropped_spans"] = tracer.dropped
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--json-out", nargs="?", const=OUT_DIR, default=None, metavar="DIR",
        help="write BENCH_<name>.json per benchmark (default DIR: %(const)s)",
    )
    ap.add_argument(
        "--trace", action="store_true",
        help="trace benchmarks that accept a tracer; arms coverage gates",
    )
    args = ap.parse_args()
    quick = not args.full
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        print(f"=== {name} ===")
        t0 = time.time()
        try:
            record = _run_one(name, mod, quick, args.trace)
            record["seconds"] = round(time.time() - t0, 3)
            print(f"--- {name} done in {time.time()-t0:.1f}s\n")
        except Exception:
            failures += 1
            record = {
                "benchmark": name, "quick": quick, "status": "failed",
                "seconds": round(time.time() - t0, 3),
                "error": traceback.format_exc(limit=8),
            }
            print(f"!!! {name} FAILED")
            traceback.print_exc()
        if args.json_out:
            os.makedirs(args.json_out, exist_ok=True)
            path = os.path.join(args.json_out, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(record, f, indent=2, sort_keys=True)
            print(f"    wrote {path}")
    if failures:
        sys.exit(f"{failures} benchmarks failed")
    print("all benchmarks complete")


if __name__ == "__main__":
    main()
