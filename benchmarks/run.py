"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default is the quick profile (CI-sized datasets); --full runs the
paper-scale sweeps.  CSVs land in experiments/bench/.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    fig07_orderkey_selectivity,
    fig08_suppkey_selectivity,
    fig09_cost_model_switch,
    fig10_multi_rule,
    fig11_violation_scaling,
    fig12_dc_inequality,
    fig13_join_queries,
    fig_dist_detect,
    serve_bg_warmup,
    serve_ingest,
    serve_throughput,
    table5_accuracy,
    table8_exploratory,
)

MODULES = [
    ("fig07", fig07_orderkey_selectivity),
    ("fig08", fig08_suppkey_selectivity),
    ("fig09", fig09_cost_model_switch),
    ("fig10", fig10_multi_rule),
    ("fig11", fig11_violation_scaling),
    ("fig12", fig12_dc_inequality),
    ("fig13", fig13_join_queries),
    ("fig_dist", fig_dist_detect),
    ("serve", serve_throughput),
    ("serve_bg", serve_bg_warmup),
    ("serve_ingest", serve_ingest),
    ("table5", table5_accuracy),
    ("table8", table8_exploratory),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        print(f"=== {name} ===")
        t0 = time.time()
        try:
            mod.run(quick=quick)
            print(f"--- {name} done in {time.time()-t0:.1f}s\n")
        except Exception:
            failures += 1
            print(f"!!! {name} FAILED")
            traceback.print_exc()
    if failures:
        sys.exit(f"{failures} benchmarks failed")
    print("all benchmarks complete")


if __name__ == "__main__":
    main()
