"""Background-cleaning warmup — progressive exploratory workload with and
without the BackgroundCleaner (DESIGN.md §10).

The workload models an exploratory analysis session discovering new views
over time: cycle ``c`` revisits every view opened so far and opens
``step`` new ones.  Under PR 3's service, every newly opened view pays
its first-touch detect/repair on the interactive path; with the
background cleaner draining cold scopes in the idle window between
cycles, the scope is already warm when the view is first queried and the
cleaning steps skip.

The dataset is built cluster-DISJOINT (every zip group's city values are
unique to the group), so relaxation closures never bridge groups and
every answer is a pure function of its own group's cleaning state —
which makes the bit-identity gate exact for EVERY answer, not just at
steady state, regardless of how background increments interleave with
foreground queries (the §10 soundness argument, testable form).

Acceptance gates (ISSUE 4 + the ISSUE 5 partial-reuse gate, enforced here
and smoked in CI):

* every answer bit-identical (canonical signatures, reusing
  ``serve_throughput.signature``) across service, service+bg, and the
  serial fresh-Daisy on-demand reference;
* the service+bg variant reaches steady state in STRICTLY fewer
  foreground detect calls than the plain service (PR 3) on the same
  workload — with the saved work showing up in the background
  attribution instead;
* both variants reach a final cycle that pays zero foreground detect
  work, and service+bg serves it entirely from the cache;
* **partial-work reuse** (DESIGN.md §11): a foreground DC full clean on a
  scope the background cleaner has HALF cleaned (strip increments) scans
  strictly fewer detect pairs than the same query on a cold scope, at a
  bit-identical answer — the work-ledger gate that the old all-or-nothing
  ``mark_checked`` could not pass.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv
from benchmarks.serve_throughput import signature
from repro.core.constraints import DC, FD, Atom
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.launch.serve import ServeOptions
from repro.service import BackgroundCleaner, QueryServer, ResultCache

RULES = {"h": [FD("zc", "zip", "city")]}


def build_db(n: int, groups: int, error_frac: float = 0.3, seed: int = 11):
    """Cluster-disjoint FD dataset: city values live in [g*8, (g+1)*8) for
    zip group g, so no value bridges groups.  Every group deterministically
    gets >= 1 error row (row 0) and >= 1 clean row (row 1): every view's
    first touch really pays detect work, and relaxation closures always
    reach the whole group."""
    rng = np.random.default_rng(seed)
    per = n // groups
    zipc = np.repeat(np.arange(groups, dtype=np.int32), per)
    n = per * groups
    city = (zipc * 8).astype(np.int32)
    edit = rng.random(n) < error_frac
    edit[0::per] = True  # row 0 of each group: guaranteed dirty
    edit[1::per] = False  # row 1 of each group: guaranteed clean
    city[edit] = (zipc[edit] * 8 + rng.integers(1, 8, int(edit.sum()))).astype(
        np.int32
    )
    return {
        "h": make_relation(
            {"zip": zipc, "city": city}, overlay=["zip", "city"], k=8, rules=["zc"]
        )
    }


def workload(groups: int, v0: int, step: int, cycles: int):
    """Per-cycle query lists: cycle c revisits all views opened so far and
    opens ``step`` new ones (capped at ``groups``).  A view g's query
    selects the group's majority city value — its answer depends on the
    group's repair candidates, so bit-identity is a real check."""
    views = [Query("h", preds=(Pred("city", "==", g * 8),)) for g in range(groups)]
    return [views[: min(v0 + c * step, groups)] for c in range(cycles)]


def run_serial(db, cfg, cycle_queries):
    """On-demand reference: a fresh Daisy executes the same query stream
    serially (the PR 3 bit-identity baseline)."""
    daisy = Daisy(db, RULES, cfg)
    sigs = []
    for queries in cycle_queries:
        sigs.extend(signature(daisy.execute(q)) for q in queries)
    return sigs


def run_service(db, cfg, cycle_queries, idle_increments: int, opts: ServeOptions,
                tracer=None):
    """Serve the workload cycle by cycle; with ``opts.background`` the
    cleaner drains up to ``idle_increments`` cold-scope increments in the
    idle window after each cycle (the deterministic, cooperative form of the
    idle-budget tuning knob — the threaded form is ``BackgroundCleaner.start``).
    All serving knobs arrive through the shared ``ServeOptions`` bundle, so
    they line up 1:1 with the CLI driver's flags.

    ``tracer`` (DESIGN.md §13) wires the whole stack; the returned
    ``windows`` are the measured serving intervals (submit..drain and the
    non-empty cleaner drains) the coverage gate is computed over."""
    daisy = Daisy(db, RULES, cfg, tracer=tracer)
    server = QueryServer(
        daisy, cache=ResultCache(capacity=512), max_batch=opts.max_batch
    )
    cleaner = (
        BackgroundCleaner(daisy, server=server,
                          increment_rows=opts.fd_increment_rows,
                          increment_strips=opts.increment_strips)
        if opts.background
        else None
    )
    sessions = [server.open_session(f"user{i}") for i in range(opts.sessions)]
    sigs, per_cycle, windows = [], [], []
    for c, queries in enumerate(cycle_queries):
        d0 = server.metrics.detect_calls
        h0 = server.metrics.cache_hits
        t0 = time.perf_counter()
        tickets = [
            server.submit(sessions[i % len(sessions)], q)
            for i, q in enumerate(queries)
        ]
        server.drain()
        windows.append((t0, time.perf_counter()))
        sigs.extend(signature(t.result) for t in tickets)
        per_cycle.append(
            {
                "cycle": c,
                "views": len(queries),
                "fg_detect": server.metrics.detect_calls - d0,
                "hits": server.metrics.cache_hits - h0,
            }
        )
        if cleaner is not None:
            t0 = time.perf_counter()
            if cleaner.drain(max_increments=idle_increments):
                windows.append((t0, time.perf_counter()))
    return sigs, server, per_cycle, windows


def dc_partial_reuse_gate(n: int, seed: int = 17):
    """The §11 gate: strip-incremental background progress makes a
    foreground full DC clean strictly cheaper (detect pairs) than on a
    cold scope, with bit-identical answers and final candidate state."""
    rng = np.random.default_rng(seed)

    def build_dc():
        price = rng.uniform(0.0, 100.0, n).astype(np.float32)
        disc = (100.0 - price + rng.normal(0, 5.0, n)).astype(np.float32)
        return make_relation(
            {"price": price, "disc": disc}, overlay=["price", "disc"],
            k=8, rules=["pd"],
        )

    dc = DC("pd", [Atom("price", "<", "price"), Atom("disc", ">", "disc")])
    # accuracy_threshold=2.0: every auto DC step resolves to a full clean,
    # so both variants run the SAME plan and only the ledger state differs
    cfg = lambda: DaisyConfig(  # noqa: E731 — local config factory
        use_cost_model=False, accuracy_threshold=2.0,
        dc_block=max(n // 8, 8), strip_rows=max(n // 8, 8), dc_partitions=4,
    )
    state = rng.bit_generator.state
    cold = Daisy({"t": build_dc()}, {"t": [dc]}, cfg())
    rng.bit_generator.state = state
    half = Daisy({"t": build_dc()}, {"t": [dc]}, cfg())

    # background-clean HALF the strips of the half variant
    scope = half.ledger.scope("t", "pd")
    total = len(scope.cold_strips())
    done = 0
    while len(scope.cold_strips()) > total - total // 2:
        assert half.clean_scope_increment("t", "pd", max_strips=1) is not None
        done += 1
    q = Query("t", preds=(Pred("price", ">=", 0.0),))
    pairs = {}
    tiles = {}
    masks = {}
    for name, daisy in (("cold", cold), ("half-cleaned", half)):
        p0 = daisy.detect_pairs
        t0 = daisy.tiles_launched
        res = daisy.execute(q)
        pairs[name] = daisy.detect_pairs - p0
        tiles[name] = daisy.tiles_launched - t0
        masks[name] = np.asarray(res.mask)
        assert res.report.steps[0].mode == "full", res.report.steps[0]
    assert pairs["half-cleaned"] < pairs["cold"], (
        f"half-cleaned scope did not reuse background strips "
        f"({pairs['half-cleaned']} vs {pairs['cold']} pairs)"
    )
    # DESIGN.md §15: the candidate-bound savings must be LAUNCH savings too —
    # the checked strips' tile pairs never enter the worklist
    assert tiles["half-cleaned"] < tiles["cold"], (
        f"half-cleaned scope did not launch fewer tiles "
        f"({tiles['half-cleaned']} vs {tiles['cold']})"
    )
    np.testing.assert_array_equal(masks["cold"], masks["half-cleaned"])
    for attr in ("price", "disc"):
        np.testing.assert_array_equal(
            np.asarray(cold.db["t"].cand[attr]),
            np.asarray(half.db["t"].cand[attr]),
        )
        np.testing.assert_array_equal(
            np.asarray(cold.db["t"].ccount[attr]),
            np.asarray(half.db["t"].ccount[attr]),
        )
    print(
        f"serve_bg_warmup partial-reuse: {done} background strip increments "
        f"-> foreground full clean {pairs['cold']} -> "
        f"{pairs['half-cleaned']} detect pairs "
        f"({tiles['cold']} -> {tiles['half-cleaned']} tiles launched), "
        f"answers bit-identical"
    )
    return pairs, tiles


def run(quick: bool = False, tracer=None):
    n = 480 if quick else 3840
    groups = 24 if quick else 64
    v0, step = (4, 4) if quick else (8, 8)
    cycles = 8 if quick else 10
    idle_increments = 6 if quick else 10
    cfg = DaisyConfig(use_cost_model=False)
    cycle_queries = workload(groups, v0, step, cycles)
    n_queries = sum(len(qs) for qs in cycle_queries)

    # the serial reference always runs UNtraced: the bit-identity gate
    # against it is therefore also the traced-vs-untraced neutrality gate
    t0 = time.perf_counter()
    sigs_serial = run_serial(build_db(n, groups), cfg, cycle_queries)
    dt_serial = time.perf_counter() - t0

    rows, results = [], {}
    all_windows = []
    for variant, background in (("service", False), ("service+bg", True)):
        opts = ServeOptions(
            sessions=4, rows=n, background=background,
            increment_rows=(n // groups) * (step + 1),
        )
        t0 = time.perf_counter()
        sigs, server, per_cycle, windows = run_service(
            build_db(n, groups), cfg, cycle_queries, idle_increments, opts,
            tracer=tracer,
        )
        all_windows.extend(windows)
        dt = time.perf_counter() - t0
        snap = server.snapshot()
        results[variant] = (sigs, snap, per_cycle)
        for pc in per_cycle:
            rows.append(
                [variant, pc["cycle"], pc["views"], pc["fg_detect"], pc["hits"],
                 snap["background"]["increments"], round(dt, 3)]
            )
        warm = " ".join(
            f"{scope}={p['strips_done']}/{p['strips_total']}"
            for scope, p in snap["ledger"].items()
        )
        print(
            f"serve_bg_warmup {variant}: {n_queries} queries in {dt:.2f}s — "
            f"fg detect {snap['detect_calls']}, bg detect "
            f"{snap['background']['detect_calls']} "
            f"({snap['background']['increments']} increments), "
            f"hit rate {snap['hit_rate']:.0%}, warmup [{warm}]"
        )

    sigs_svc, snap_svc, cyc_svc = results["service"]
    sigs_bg, snap_bg, cyc_bg = results["service+bg"]

    # gate 1: every answer bit-identical across all three runs
    assert sigs_svc == sigs_serial, "service answers differ from serial reference"
    assert sigs_bg == sigs_serial, "service+bg answers differ from serial reference"

    # gate 2: background warmup strictly reduces foreground detect work,
    # and the difference is real background work, not skipped cleaning
    fg_svc = snap_svc["detect_calls"]
    fg_bg = snap_bg["detect_calls"]
    assert fg_bg < fg_svc, (
        f"background cleaning did not reduce foreground detects "
        f"({fg_bg} vs {fg_svc})"
    )
    assert snap_bg["background"]["detect_calls"] > 0, "cleaner did no detect work"

    # gate 3: both reach a zero-foreground-detect steady state; with the
    # cleaner warm and no more version bumps, the last cycle is all hits
    assert cyc_svc[-1]["fg_detect"] == 0 and cyc_bg[-1]["fg_detect"] == 0
    assert cyc_bg[-1]["hits"] == cyc_bg[-1]["views"], (
        "service+bg last cycle not fully cache-served"
    )

    # gate 4 (ISSUE 5 + §15): strip-level partial-work reuse on a DC scope,
    # visible in detect pairs AND in launched tiles
    _, reuse_tiles = dc_partial_reuse_gate(240 if quick else 1024)

    # gate 5 (DESIGN.md §13, under --trace only): the span union explains
    # >= 90% of the measured serving wall-clock (queue-wait lives on its
    # synthetic track and is excluded — it overlaps real serving spans)
    cov = roll = None
    if tracer is not None:
        from repro.obs import coverage, rollup

        events = tracer.events()
        cov = coverage(events, all_windows, exclude_threads=("queue",))
        assert cov >= 0.9, (
            f"trace rollup covers only {cov:.1%} of the serving wall-clock"
        )
        roll = rollup(events)
        print(f"serve_bg_warmup trace: {len(events)} spans cover "
              f"{cov:.1%} of {sum(b - a for a, b in all_windows):.2f}s serving")

    print(
        f"serve_bg_warmup: answers bit-identical; foreground detects "
        f"{fg_svc} -> {fg_bg} "
        f"({snap_bg['background']['detect_calls']} absorbed in background); "
        f"serial reference {dt_serial:.2f}s"
    )
    artifact = write_csv(
        "serve_bg_warmup",
        ["variant", "cycle", "views", "fg_detect", "cache_hits",
         "bg_increments_total", "seconds_total"],
        rows,
    )
    return {
        "artifact": artifact,
        "gates": {
            "bit_identical": True,
            "fg_detects_reduced": fg_bg < fg_svc,
            "steady_state_cached": cyc_bg[-1]["hits"] == cyc_bg[-1]["views"],
            "partial_reuse": True,
            "tiles_drop_with_warmup": (
                reuse_tiles["half-cleaned"] < reuse_tiles["cold"]
            ),
            "trace_coverage": cov,
        },
        "headline": {
            "queries": n_queries,
            "tiles_launched_fg": snap_bg["tiles_launched"],
            "tiles_skipped_fg": snap_bg["tiles_skipped"],
            "reuse_tiles_cold": reuse_tiles["cold"],
            "reuse_tiles_half": reuse_tiles["half-cleaned"],
            "fg_detect_service": fg_svc,
            "fg_detect_service_bg": fg_bg,
            "bg_detect": snap_bg["background"]["detect_calls"],
            "bg_increments": snap_bg["background"]["increments"],
            "hit_rate_service_bg": snap_bg["hit_rate"],
            "serial_seconds": round(dt_serial, 3),
        },
        "rollup": roll,
    }


if __name__ == "__main__":
    run()
