"""Dense vs sharded DC/FD detection on a large synthetic relation
(DESIGN.md §8; the fig-style benchmark the ROADMAP distribution section
called for).

The rule carries a same-attribute equality atom, so the sharded path can
hash-route rows by the equality key (``shuffle_by_key``) and run the
``dc_pairs`` role scans per logical shard: the comparison space drops
from ``n^2`` to ``sum_s rows_s^2`` (~``n^2 / shards`` under uniform
keys) at the cost of one all-to-all of the routed payload.  On a
single-device CPU run the per-shard scans execute as a ``vmap`` over the
logical shards — identical numerics to the mesh execution, which is what
lets the bit-identity gate run everywhere.

Acceptance gates (smoked in CI):

* sharded results bit-identical to the dense scans, row for row, for
  every shard count (DC counts/stats and FD candidate tables);
* the sharded comparison space is strictly smaller than the dense one at
  every shard count, and shrinks monotonically as shards grow;
* the routing info reports per-shard source-strip coverage (the work
  ledger's grid, DESIGN.md §11) summing to at least the strip count of
  the routed rows — the per-host work-partition signal the sharded
  service will consume.
"""

from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import write_csv
from repro.core.constraints import DC, FD, Atom
from repro.core.detect import detect_dc, detect_fd
from repro.core.relation import make_relation
from repro.dist.detect import detect_dc_sharded_info, detect_fd_sharded_info


def one_device_mesh():
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def build(n: int, n_regions: int, seed: int = 13):
    """Synthetic orders: price/discount must be monotone-consistent WITHIN
    a region (the equality atom that makes the DC routable); noise plants
    cross-row inversions inside regions."""
    rng = np.random.default_rng(seed)
    region = rng.integers(0, n_regions, n).astype(np.int32)
    price = rng.uniform(1000.0, 5000.0, n).astype(np.float32)
    discount = (6000.0 - price + rng.normal(0, 150.0, n)).astype(np.float32)
    supp = rng.integers(0, 64, n).astype(np.int32)
    return make_relation(
        {"region": region, "extended_price": price, "discount": discount,
         "orderkey": region, "suppkey": supp},
        overlay=["extended_price", "discount", "suppkey"],
        k=8,
        rules=["dc_rpd", "fd_rs"],
    )


DC_RULE = DC(
    "dc_rpd",
    [Atom("region", "==", "region"),
     Atom("extended_price", "<", "extended_price"),
     Atom("discount", ">", "discount")],
)
FD_RULE = FD("fd_rs", "orderkey", "suppkey")


def _timed(fn, repeats: int = 1):
    out = fn()  # warm the jit caches before timing
    jax.block_until_ready(out[0] if isinstance(out, tuple) else out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
        jax.block_until_ready(out[0] if isinstance(out, tuple) else out)
    return out, (time.perf_counter() - t0) / repeats


def run(quick: bool = False):
    n = 2048 if quick else 16384
    shard_counts = [2, 4] if quick else [2, 4, 8, 16]
    strip_rows = 256
    block = 256
    rel = build(n, n_regions=max(n // 32, 8))
    mesh = one_device_mesh()

    (dense_dc, dt_dense) = _timed(
        lambda: detect_dc(rel, DC_RULE, rel.valid, rel.valid, block=block)
    )
    (dense_fd, dt_dense_fd) = _timed(
        lambda: detect_fd(rel, FD_RULE, rel.valid, k=8)
    )
    dense_pairs = int(rel.capacity) ** 2
    rows = [["dense", 1, dense_pairs, 1.0, round(dt_dense, 4),
             round(dt_dense_fd, 4), 0]]

    prev_pairs = dense_pairs
    for shards in shard_counts:
        (res, dt_dc) = _timed(
            lambda s=shards: detect_dc_sharded_info(
                rel, DC_RULE, rel.valid, rel.valid, mesh,
                n_shards=s, block=block, strip_rows=strip_rows,
            )
        )
        det, info = res
        (res_fd, dt_fd) = _timed(
            lambda s=shards: detect_fd_sharded_info(
                rel, FD_RULE, rel.valid, mesh, k=8, n_shards=s,
                strip_rows=strip_rows,
            )
        )
        det_fd, _ = res_fd

        # gate 1: bit-identical to the dense scans, row for row
        np.testing.assert_array_equal(
            np.asarray(det.t1_count), np.asarray(dense_dc.t1_count)
        )
        np.testing.assert_array_equal(
            np.asarray(det.t2_count), np.asarray(dense_dc.t2_count)
        )
        for got, want in zip(det.t1_stat, dense_dc.t1_stat):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(det_fd.violated), np.asarray(dense_fd.violated)
        )
        np.testing.assert_array_equal(
            np.asarray(det_fd.rhs_cand), np.asarray(dense_fd.rhs_cand)
        )

        # gate 2: strictly smaller comparison space, shrinking with shards
        assert info.sharded_pairs < dense_pairs, (
            f"{shards} shards did not shrink the pair space "
            f"({info.sharded_pairs} vs {dense_pairs})"
        )
        assert info.sharded_pairs <= prev_pairs, (
            f"pair space grew from {prev_pairs} at {shards} shards"
        )
        prev_pairs = info.sharded_pairs

        # gate 3: per-shard strip coverage reported and plausible
        assert info.per_shard_strips is not None
        assert sum(info.per_shard_strips) >= -(-info.routed_rows // strip_rows)

        rows.append([
            "sharded", shards, info.sharded_pairs,
            round(dense_pairs / max(info.sharded_pairs, 1), 2),
            round(dt_dc, 4), round(dt_fd, 4),
            max(info.per_shard_strips),
        ])
        print(
            f"fig_dist_detect: {shards:>2} shards — pairs {info.sharded_pairs}"
            f" ({dense_pairs / max(info.sharded_pairs, 1):.1f}x fewer), "
            f"dc {dt_dc*1e3:.1f} ms, fd {dt_fd*1e3:.1f} ms, "
            f"max strips/shard {max(info.per_shard_strips)}"
        )

    print(
        f"fig_dist_detect: dense {dense_pairs} pairs in {dt_dense*1e3:.1f} ms; "
        f"sharded bit-identical at every shard count"
    )
    return write_csv(
        "fig_dist_detect",
        ["variant", "shards", "pairs", "pair_savings_x",
         "dc_seconds", "fd_seconds", "max_strips_per_shard"],
        rows,
    )


if __name__ == "__main__":
    run()
