"""Overload behavior under traffic shaping (DESIGN.md §14).

The scenario the qos layer exists for: batch traffic floods the queue
well past the admission threshold while interactive users keep clicking.
Without shaping, the interactive tickets would queue behind the batch
backlog and their latency would track the flood; with it, they are
answered at submit from the version-vector cache with an explicit
staleness tag, the batch class absorbs the backlog by queueing, and
nobody starves.

Phases (two independent servers over identical cluster-disjoint data,
both warmed through the ``batch`` class so the ``interactive`` latency
histogram contains exactly the phase being measured):

* **baseline** — one session, one interactive query at a time, drained
  synchronously: the uncontended interactive p99.
* **overload** — a serving thread; a burst of distinct first-touch batch
  queries drives the queue depth to >= 2x the overload threshold, then
  four sessions burst interactive queries into the backlog.

Acceptance gates (enforced here, smoked in CI):

* **interactive p99** stays within a fixed multiple (25x, with a 50 ms
  absolute floor against clock noise) of the uncontended baseline while
  the flood is >= 2x past the overload depth — because overloaded
  interactive tickets shed instead of queueing;
* **shed soundness** — every shed answer carries a staleness tag and is
  bit-identical to the warmed cache entry for its fingerprint (the
  cluster-disjoint dataset makes the warm signature the exact expected
  answer at ANY later version: batch groups bump the shared rule scope
  version but cannot change an interactive group's answer);
* **batch absorbs the backlog** — the batch class sheds nothing and
  every batch ticket is served fresh;
* **zero starved tickets** — every submitted ticket is served or
  explicitly shed (``answered == submitted``), none cancelled.
"""

from __future__ import annotations

import threading
import time

from benchmarks.common import write_csv
from benchmarks.serve_bg_warmup import RULES, build_db
from benchmarks.serve_throughput import signature
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query, query_fingerprint
from repro.service import QoSPolicy, QueryServer

P99_MULT = 25.0
P99_FLOOR_S = 0.05


def make_views(groups: int, n_interactive: int):
    """Disjoint view pools: the first ``n_interactive`` groups are the
    interactive working set, the rest are the batch flood."""
    views = [Query("h", preds=(Pred("city", "==", g * 8),)) for g in range(groups)]
    return views[:n_interactive], views[n_interactive:]


def make_server(n: int, groups: int, policy: QoSPolicy, warm_views, tracer=None):
    """A warmed server: every interactive view executed once through the
    ``batch`` class, so the interactive histogram starts empty and every
    interactive fingerprint has a cache entry to shed from."""
    daisy = Daisy(build_db(n, groups), RULES, DaisyConfig(use_cost_model=False),
                  tracer=tracer)
    server = QueryServer(daisy, max_batch=4, qos=policy)
    warm = server.open_session("warm", max_inflight=10_000)
    sigs = {}
    for q in warm_views:
        t = server.submit(warm, q, slo="batch")
        server.drain()
        sigs[query_fingerprint(q)] = signature(t.result)
    return server, sigs


def run(quick: bool = False, tracer=None):
    n = 480 if quick else 3840
    groups = 40 if quick else 64
    n_interactive = 16 if quick else 24
    overload_depth = 4 if quick else 8
    bursts = 3
    policy = QoSPolicy(overload_depth=overload_depth)
    i_views, b_views = make_views(groups, n_interactive)
    windows = []

    # ---------------------------------------------------- baseline phase
    base_server, _ = make_server(n, groups, policy, i_views, tracer=tracer)
    sess = base_server.open_session("solo", max_inflight=10_000)
    t0 = time.perf_counter()
    for q in i_views:
        base_server.submit(sess, q, slo="interactive")
        base_server.drain()
    windows.append((t0, time.perf_counter()))
    base_lat = base_server.snapshot()["latency"]["interactive"]
    p99_base = base_lat["p99_s"]

    # ---------------------------------------------------- overload phase
    server, warm_sigs = make_server(n, groups, policy, i_views, tracer=tracer)
    answered_warm = server.snapshot()["answered"]
    serving = threading.Thread(target=server.run, name="serving")
    serving.start()
    sessions = [server.open_session(f"u{i}", max_inflight=10_000) for i in range(4)]
    t0 = time.perf_counter()
    # flood: distinct first-touch batch queries — real executor work that
    # keeps the queue deep while the interactive burst goes in behind it
    batch_tix = [
        server.submit(sessions[i % 4], q, slo="batch")
        for i, q in enumerate(b_views)
    ]
    max_depth = server.qos_state()["depth"]
    inter_tix = []
    for r in range(bursts):
        for i, q in enumerate(i_views):
            inter_tix.append(
                server.submit(sessions[(r + i) % 4], q, slo="interactive")
            )
        max_depth = max(max_depth, server.qos_state()["depth"])
    for t in batch_tix:
        t.wait(timeout=600)
    for t in inter_tix:
        t.wait(timeout=600)
    windows.append((t0, time.perf_counter()))
    server.stop()
    serving.join(timeout=60)
    assert not serving.is_alive()
    snap = server.snapshot()
    p99_over = snap["latency"]["interactive"]["p99_s"]

    # ------------------------------------------------------------- gates
    overload_factor = max_depth / overload_depth
    assert overload_factor >= 2.0, (
        f"flood only reached {max_depth} pending "
        f"(< 2x overload depth {overload_depth}) — not an overload run"
    )

    n_shed = sum(1 for t in inter_tix if t.shed)
    for t in inter_tix:
        assert t.event.is_set(), f"ticket {t.seq} starved"
        if t.shed:
            # never silently: always tagged, and bit-identical to the
            # warmed entry the tag points at
            assert t.staleness is not None
            assert signature(t.result) == warm_sigs[t.fingerprint], (
                f"shed answer for {t.fingerprint} differs from its cache entry"
            )
        else:
            assert t.staleness is None
    for t in batch_tix:
        assert t.event.is_set(), f"batch ticket {t.seq} starved"
        assert not t.shed and t.staleness is None and t.error is None
    assert snap["qos"]["by_class"].get("batch", {}).get("shed", 0) == 0
    assert snap["answered"] - answered_warm == len(batch_tix) + len(inter_tix)
    assert snap["qos"]["cancelled"] == 0 and snap["errors"] == 0

    p99_bound = max(P99_MULT * p99_base, P99_FLOOR_S)
    assert p99_over <= p99_bound, (
        f"interactive p99 {p99_over*1e3:.2f}ms exceeds "
        f"{P99_MULT}x uncontended baseline {p99_base*1e3:.2f}ms "
        f"(bound {p99_bound*1e3:.2f}ms) at {overload_factor:.1f}x overload"
    )

    # gate (DESIGN.md §13, under --trace only): spans must explain the
    # measured serving windows — overload must not hide wall-clock
    cov = roll = None
    if tracer is not None:
        from repro.obs import coverage, rollup

        events = tracer.events()
        cov = coverage(events, windows, exclude_threads=("queue",))
        assert cov >= 0.9, (
            f"trace rollup covers only {cov:.1%} of the serving wall-clock"
        )
        roll = rollup(events)

    stale_total = snap["qos"]["shed_staleness_total"]
    print(
        f"serve_overload: {overload_factor:.1f}x past depth {overload_depth} — "
        f"interactive p99 {p99_base*1e3:.2f}ms -> {p99_over*1e3:.2f}ms "
        f"(bound {p99_bound*1e3:.2f}ms), {n_shed}/{len(inter_tix)} shed "
        f"(avg staleness {stale_total / max(n_shed, 1):.1f}), "
        f"{len(batch_tix)} batch served fresh"
    )
    artifact = write_csv(
        "serve_overload",
        ["phase", "class", "count", "p50_ms", "p95_ms", "p99_ms", "shed"],
        [
            ["baseline", "interactive", len(i_views),
             round(base_lat["p50_s"] * 1e3, 3),
             round(base_lat["p95_s"] * 1e3, 3),
             round(p99_base * 1e3, 3), 0],
            ["overload", "interactive", len(inter_tix),
             round(snap["latency"]["interactive"]["p50_s"] * 1e3, 3),
             round(snap["latency"]["interactive"]["p95_s"] * 1e3, 3),
             round(p99_over * 1e3, 3), n_shed],
            ["overload", "batch", len(batch_tix),
             round(snap["latency"]["batch"]["p50_s"] * 1e3, 3),
             round(snap["latency"]["batch"]["p95_s"] * 1e3, 3),
             round(snap["latency"]["batch"]["p99_s"] * 1e3, 3), 0],
        ],
    )
    return {
        "artifact": artifact,
        "gates": {
            "interactive_p99_bounded": p99_over <= p99_bound,
            "shed_bit_identical": True,
            "batch_absorbed": True,
            "zero_starved": True,
            "overload_factor": round(overload_factor, 2),
            "trace_coverage": cov,
        },
        "headline": {
            "p99_base_ms": round(p99_base * 1e3, 3),
            "p99_overload_ms": round(p99_over * 1e3, 3),
            "p99_bound_ms": round(p99_bound * 1e3, 3),
            "shed": n_shed,
            "interactive": len(inter_tix),
            "batch": len(batch_tix),
            "avg_staleness": round(stale_total / max(n_shed, 1), 2),
            "max_depth": max_depth,
        },
        "rollup": roll,
    }


if __name__ == "__main__":
    run()
