"""Tables 5/6/7 — repair accuracy (P/R/F1) on a hospital-like dataset with
known ground truth, under 1..3 rules; plus the provenance benefit (one
incremental execution vs per-rule re-execution)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv
from repro.core.accuracy import repair_accuracy
from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import hospital_like

N = 2048


def build(rules):
    ds = hospital_like(N, error_frac=0.05)
    rel = make_relation(
        ds.data, overlay=["zip", "city", "state"], k=8,
        rules=[r.name for r in rules],
    )
    return rel, ds


def full_scan_queries(nq: int = 4):
    edges = np.linspace(0, N // 20 + 1, nq + 1).astype(int)
    return [
        Query("t", preds=(Pred("zip", ">=", int(a)), Pred("zip", "<", int(b))))
        for a, b in zip(edges[:-1], edges[1:])
    ]


def run(quick: bool = False):
    import jax.numpy as jnp

    phi1 = FD("phi1", "zip", "city")
    phi2 = FD("phi2", "zip", "state")
    rows = []
    for label, rules in [("phi1", [phi1]), ("phi1+phi2", [phi1, phi2])]:
        rel, ds = build(rules)
        daisy = Daisy({"t": rel}, {"t": rules}, DaisyConfig(use_cost_model=False))
        for q in full_scan_queries():
            daisy.execute(q)
        truth = {k: jnp.asarray(v) for k, v in ds.truth.items()}
        acc = repair_accuracy(daisy.db["t"], truth, ["city", "state"])
        rows.append([label, round(acc.precision, 3), round(acc.recall, 3),
                     round(acc.f1, 3), acc.errors])
        print(f"table5 {label}: P={acc.precision:.3f} R={acc.recall:.3f} "
              f"F1={acc.f1:.3f} ({acc.errors} errors)")

    # Table 7: incremental rule addition vs re-execution from scratch
    t0 = time.perf_counter()
    rel, ds = build([phi1, phi2])
    daisy = Daisy({"t": rel}, {"t": [phi1]}, DaisyConfig(use_cost_model=False))
    for q in full_scan_queries():
        daisy.execute(q)
    # new rule arrives: executes over provenance (original values) only
    daisy.rules["t"].append(phi2)
    daisy._collect_stats()
    for q in full_scan_queries():
        daisy.execute(q)
    t_incr = time.perf_counter() - t0

    t0 = time.perf_counter()
    for rules in ([phi1], [phi1, phi2]):
        rel, ds = build(rules)
        d = Daisy({"t": rel}, {"t": rules}, DaisyConfig(use_cost_model=False))
        for q in full_scan_queries():
            d.execute(q)
    t_rerun = time.perf_counter() - t0
    rows.append(["table7_incremental_s", round(t_incr, 3), "", "", ""])
    rows.append(["table7_reexec_s", round(t_rerun, 3), "", "", ""])
    print(f"table7: incremental rule add {t_incr:.2f}s vs re-exec {t_rerun:.2f}s")
    return write_csv("table5", ["rules", "precision", "recall", "f1", "errors"], rows)


if __name__ == "__main__":
    run()
