"""Fig. 8 — SP cost vs suppkey (rhs) cardinality; lhs-filter queries
(these exercise the transitive-closure relaxation)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import build_lineorder_db, run_daisy, run_offline, write_csv
from repro.core.executor import DaisyConfig
from repro.core.operators import Pred, Query

N = 4096
QUERIES = 50
N_ORDERKEYS = 512


def lhs_range_queries():
    edges = np.linspace(0, N_ORDERKEYS, QUERIES + 1).astype(int)
    return [
        Query("t", preds=(Pred("orderkey", ">=", int(lo)), Pred("orderkey", "<", int(hi))))
        for lo, hi in zip(edges[:-1], edges[1:])
    ]


def run(quick: bool = False):
    rows = []
    cards = [16, 64] if quick else [16, 64, 256, 1024]
    for n_sk in cards:
        rel, fd, _ = build_lineorder_db(N, N_ORDERKEYS, n_sk)
        qs = lhs_range_queries()
        t_d = run_daisy(rel, [fd], qs, DaisyConfig(expected_queries=QUERIES))
        t_o = run_offline(rel, [fd], qs)
        rows.append([n_sk, round(t_d, 3), round(t_o, 3), round(t_o / t_d, 2)])
        print(f"fig08 suppkeys={n_sk}: daisy {t_d:.2f}s offline {t_o:.2f}s "
              f"(x{t_o/t_d:.2f})")
    return write_csv("fig08", ["suppkeys", "daisy_s", "offline_s", "speedup"], rows)


if __name__ == "__main__":
    run()
