"""Serving throughput — Table-8-style repeated exploratory workload over the
query service (DESIGN.md §9).

Three ways to answer the same multi-user workload (a mixed pool of SP and
join queries, cycled the way exploratory sessions revisit views):

* **offline**    clean everything up front, then serve (the paper's §7
                 baseline) — all cleaning paid before the first answer;
* **on-demand**  one Daisy, queries executed serially as they arrive (the
                 pre-service single-caller mode);
* **service**    QueryServer + clean-state-aware cache sharing one Daisy
                 across sessions.

The acceptance gate (ISSUE 3): the service answers the workload with >=5x
fewer detect/repair invocations than cacheless on-demand, while every
answer stays bit-identical to a fresh serial Daisy run over the same query
order (the on-demand run IS that reference).
"""

from __future__ import annotations

import hashlib
import time

import numpy as np

from benchmarks.common import write_csv
from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.offline import OfflineCleaner
from repro.core.operators import JoinClause, Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import inject_fd_errors, ssb_lineorder, suppliers
from repro.service import QueryServer, ResultCache


def build_db(n: int, n_sup: int, seed: int = 33):
    lo = ssb_lineorder(n, n // 8, n_sup, seed=seed)
    ds_lo = inject_fd_errors(lo, "orderkey", "suppkey", 1.0, 0.1, n_sup, seed=seed + 1)
    sup = suppliers(n_sup, seed=seed + 2)
    ds_sup = inject_fd_errors(sup, "address", "suppkey", 1.0, 0.1, n_sup, seed=seed + 3)
    db = {
        "lineorder": make_relation(
            ds_lo.data, overlay=["orderkey", "suppkey"], k=8, rules=["phi"]
        ),
        "suppliers": make_relation(
            ds_sup.data, overlay=["address", "suppkey"], k=8, rules=["psi"]
        ),
    }
    rules = {
        "lineorder": [FD("phi", "orderkey", "suppkey")],
        "suppliers": [FD("psi", "address", "suppkey")],
    }
    return db, rules


def workload(n_sup: int, n_join: int, n_sp: int, cycles: int):
    """Mixed exploratory pool (joins dominate: their Def. 3 (d) re-check is
    the honest per-query detect work the cache amortizes), revisited
    ``cycles`` times in a fixed order."""
    edges = np.linspace(0, n_sup, n_join + 1).astype(int)
    pool = [
        Query(
            "lineorder",
            preds=(Pred("suppkey", ">=", int(a)), Pred("suppkey", "<", int(b))),
            joins=(JoinClause("suppliers", "suppkey", "suppkey"),),
        )
        for a, b in zip(edges[:-1], edges[1:])
    ]
    sp_edges = np.linspace(0, n_sup, n_sp + 1).astype(int)
    pool += [
        Query("lineorder", preds=(Pred("suppkey", "<", int(b)),))
        for b in sp_edges[1:]
    ]
    return pool * cycles


def signature(result) -> str:
    """Bit-exact digest of a DaisyResult's answer *content*.

    SP masks are positional and hash as-is.  Join lineage is a SET of
    qualifying row-id tuples — the packing order of the fixed-capacity
    arrays depends on which incremental-join part (base vs relaxation
    extras, Fig. 5) produced a pair, so the valid pairs are sorted into
    canonical order first.  Group-by output likewise hashes the non-empty
    (key, count, agg) rows in sorted order."""
    h = hashlib.sha256()
    if result.mask is not None:
        h.update(np.asarray(result.mask).tobytes())
    if result.join is not None:
        valid = np.asarray(result.join.valid)
        cols = [np.asarray(result.join.rows[t])[valid] for t in result.join.tables]
        order = np.lexsort(cols[::-1])
        h.update("|".join(result.join.tables).encode())
        for c in cols:
            h.update(np.ascontiguousarray(c[order]).tobytes())
    if result.groups is not None:
        count = np.asarray(result.groups["count"])
        sel = count > 0
        cols = [
            np.asarray(v)[sel]
            for k, v in sorted(result.groups.items())
            if k.startswith("key_")
        ] + [count[sel], np.asarray(result.groups["agg"])[sel]]
        order = np.lexsort(cols[::-1])
        for c in cols:
            h.update(np.ascontiguousarray(c[order]).tobytes())
    return h.hexdigest()


def run_offline(db, rules, cfg, queries):
    off = OfflineCleaner(db, rules, cfg)
    t0 = time.perf_counter()
    off.clean_all()
    sigs = [signature(off.execute(q)) for q in queries]
    dt = time.perf_counter() - t0
    # clean_all detects+repairs once per rule outside the engine's counters
    n_rules = sum(len(rs) for rs in rules.values())
    work = 2 * n_rules + off._engine.detect_calls + off._engine.repair_calls
    return sigs, dt, work, 0


def run_ondemand(db, rules, cfg, queries):
    daisy = Daisy(db, rules, cfg)
    t0 = time.perf_counter()
    sigs = [signature(daisy.execute(q)) for q in queries]
    dt = time.perf_counter() - t0
    return sigs, dt, daisy.detect_calls + daisy.repair_calls, 0


def run_service(db, rules, cfg, queries, n_sessions: int = 4):
    daisy = Daisy(db, rules, cfg)
    server = QueryServer(daisy, cache=ResultCache(capacity=512), max_batch=8)
    sessions = [server.open_session(f"user{i}") for i in range(n_sessions)]
    t0 = time.perf_counter()
    tickets = [
        server.submit(sessions[i % n_sessions], q) for i, q in enumerate(queries)
    ]
    server.drain()
    sigs = [signature(t.result) for t in tickets]
    dt = time.perf_counter() - t0
    work = daisy.detect_calls + daisy.repair_calls
    return sigs, dt, work, server.metrics.cache_hits


def run(quick: bool = False):
    n = 512 if quick else 2048
    n_sup = 32 if quick else 64
    n_join, n_sp = (3, 1) if quick else (6, 2)
    cycles = 22 if quick else 30
    cfg = DaisyConfig(join_capacity=4096 if quick else 16384, use_cost_model=False)
    queries = workload(n_sup, n_join, n_sp, cycles)

    rows = []
    results = {}
    for variant, runner in (
        ("offline", run_offline),
        ("ondemand", run_ondemand),
        ("service", run_service),
    ):
        db, rules = build_db(n, n_sup)
        sigs, dt, work, hits = runner(db, rules, cfg, queries)
        results[variant] = sigs
        rows.append(
            [variant, len(queries), round(dt, 3), work, hits,
             round(len(queries) / dt, 1), round(work / len(queries), 3)]
        )
        print(
            f"serve_throughput {variant}: {len(queries)} queries in {dt:.2f}s "
            f"({len(queries)/dt:.1f} q/s), detect+repair {work} "
            f"({work/len(queries):.2f}/query), cache hits {hits}"
        )

    # acceptance: bit-identical answers, >=5x less detect/repair work
    mismatches = sum(
        a != b for a, b in zip(results["service"], results["ondemand"])
    )
    assert mismatches == 0, (
        f"{mismatches}/{len(queries)} service answers differ from the serial "
        "fresh-Daisy reference"
    )
    work_service = rows[2][3]
    work_ondemand = rows[1][3]
    assert work_service * 5 <= work_ondemand, (
        f"service did {work_service} detect/repair invocations vs on-demand "
        f"{work_ondemand}: amortization below the 5x gate"
    )
    print(
        f"serve_throughput: answers bit-identical; service amortization "
        f"{work_ondemand / max(work_service, 1):.1f}x"
    )
    return write_csv(
        "serve_throughput",
        ["variant", "queries", "seconds", "detect_repair", "cache_hits",
         "qps", "work_per_query"],
        rows,
    )


if __name__ == "__main__":
    run()
