"""Ledger-masked block-sparse DC kernel gates (DESIGN.md §15).

The tentpole claim of the block-sparse worklist: as the cleaner converges,
checked×checked tile pairs leave the launch entirely — the scan's cost
tracks the COLD geometry, not the dataset size — while every candidate
bound stays bit-identical to the dense scan.  This benchmark enforces
that end to end:

* **bit-identity at every sparsity level** (0 / 50 / 90 / 100 % of strips
  converged, scattered — not contiguous): the worklist scan (ref oracle
  AND interpret-mode Pallas kernel) equals the dense ref scan restricted
  to the cold rows, for counts and stats of both roles;
* **launch == ledger geometry**: tiles launched exactly equals
  ``len(StripLedger.cold_block_ids) × n_col_blocks`` — and the fully
  converged scope launches ZERO tiles (no kernel call at all);
* **bytes track sparsity**: modeled DMA traffic at 90 %-converged is
  >= 2x below the dense scan's, and the launched tiles move >= 90 % of
  the cold work's minimum (the §Roofline memory-bound framing — bytes
  are modeled from launch geometry and actual operand dtypes, the same
  deterministic model ``kernels.ops.TileStats`` reports, not HW counters);
* **compressed encodings are exact**: ``detect_dc`` with the encoding
  planner on equals the un-encoded scan bit-for-bit, boundary columns
  (int8 overflow, non-integer floats) fall back to ``orig``;
* **the executor rides the worklist**: a half-cleaned ``Daisy`` scope's
  full clean launches exactly the ledger's cold geometry, reported in
  ``StepReport.tiles_launched``.

Each sparsity level also writes a ``{"kernel": ...}`` record into
``experiments/dryrun/`` for ``benchmarks.roofline``'s measured-kernel
table (analytic dryrun records and measured launch records side by side).
"""

from __future__ import annotations

import json
import os

import numpy as np

import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.core.constraints import DC, Atom, flip_op
from repro.core.detect import _T1_REDUCE, detect_dc
from repro.core.executor import Daisy, DaisyConfig
from repro.core.ledger import StripLedger
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.kernels import ops as kops

DRYRUN_DIR = os.environ.get("DRYRUN_OUT", "experiments/dryrun")

SPARSITY = (0.0, 0.5, 0.9, 1.0)  # fraction of strips already checked

# the workhorse two-atom inequality DC (fig12's shape): price < price',
# disc > disc' — both columns distinct, both roles non-trivial
OPS = ("<", ">")


def build_cols(n: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    price = jnp.asarray(rng.uniform(0.0, 100.0, n).astype(np.float32))
    disc = jnp.asarray(
        (100.0 - rng.uniform(0.0, 100.0, n) + rng.normal(0.0, 5.0, n)).astype(
            np.float32
        )
    )
    return (price, disc)


def _scan_args(cols):
    flipped = tuple(flip_op(op) for op in OPS)
    t1_red = tuple(_T1_REDUCE[op] for op in OPS)
    t2_red = tuple(_T1_REDUCE[op] for op in flipped)
    return cols, cols, OPS, flipped, t1_red, t2_red


def _assert_identical(a, b, what: str):
    np.testing.assert_array_equal(
        np.asarray(a.t1_count), np.asarray(b.t1_count), err_msg=what
    )
    np.testing.assert_array_equal(
        np.asarray(a.t2_count), np.asarray(b.t2_count), err_msg=what
    )
    for sa, sb in zip(a.t1_stat, b.t1_stat):
        np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb), err_msg=what)
    for sa, sb in zip(a.t2_stat, b.t2_stat):
        np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb), err_msg=what)


def sparsity_sweep(n: int, block: int, interpret: bool, seed: int = 7):
    """Run the bit-identity + launch-geometry + bytes gates over the
    sparsity levels; returns one record per level."""
    cols = build_cols(n, seed)
    l_cols, r_cols, ops, flipped, t1_red, t2_red = _scan_args(cols)
    scope = jnp.ones(n, dtype=bool)
    nb = -(-n // block)
    ledger = StripLedger("t", "dc", capacity=n, strip_rows=block)
    rng = np.random.default_rng(seed + 1)
    records = []
    for frac in SPARSITY:
        # scattered convergence: a random subset of strips is checked, so
        # the worklist is genuinely non-contiguous (the (lo, hi) covering
        # range would launch far more)
        checked = rng.choice(
            ledger.n_strips, size=int(round(frac * ledger.n_strips)),
            replace=False,
        )
        cold_rows = ~ledger.strip_mask(checked)
        ledger.observe_cold(cold_rows)
        ids = ledger.cold_block_ids(block)
        expect_launch = int(ids.size) * nb

        # the ledger worklist scan, ref oracle...
        sparse = kops.dc_pair_scan(
            l_cols, r_cols, ops, flipped, scope, scope, t1_red, t2_red,
            block=block, force="ref", row_block_ids=ids,
        )
        # ...vs the dense ref scan restricted to the cold rows: the exact
        # semantics the executor relies on (checked rows keep count 0 and
        # identity bounds either way)
        dense_masked = kops.dc_pair_scan(
            l_cols, r_cols, ops, flipped,
            scope & jnp.asarray(cold_rows), scope, t1_red, t2_red,
            block=block, force="ref",
        )
        _assert_identical(
            sparse, dense_masked, f"worklist vs masked dense at {frac:.0%}"
        )
        if interpret:
            kern = kops.dc_pair_scan(
                l_cols, r_cols, ops, flipped, scope, scope, t1_red, t2_red,
                block=block, force="interpret", row_block_ids=ids,
            )
            _assert_identical(kern, sparse, f"interpret vs ref at {frac:.0%}")

        assert sparse.tiles.launched == expect_launch, (
            f"launch does not match ledger geometry at {frac:.0%}: "
            f"{sparse.tiles.launched} vs {expect_launch}"
        )
        if frac >= 1.0:
            assert sparse.tiles.launched == 0, "converged scope still launched"
        dense_bytes = dense_masked.tiles.bytes_moved
        records.append(
            {
                "sparsity": frac,
                "tiles_launched": sparse.tiles.launched,
                "tiles_total": sparse.tiles.total,
                "bytes_moved": sparse.tiles.bytes_moved,
                "bytes_dense": dense_bytes,
                "bytes_per_tile": (
                    sparse.tiles.bytes_moved // max(sparse.tiles.launched, 1)
                ),
            }
        )
    return records


def encoding_gate(n: int, block: int, seed: int = 13):
    """Exactness of the compressed key-compare paths through ``detect_dc``:
    encoded scans bit-identical to un-encoded ones; boundary columns fall
    back to ``orig``."""
    rng = np.random.default_rng(seed)
    cat = rng.integers(0, 6, n).astype(np.int32)  # code-eligible (== atom)
    qty = rng.integers(0, 100, n).astype(np.float32)  # int-valued, int8 range
    big = qty + 100.0  # int-valued but beyond int8 -> bf16 at best
    frac = rng.uniform(0.0, 1.0, n).astype(np.float32)  # non-integer -> orig
    rel = make_relation(
        {"cat": cat, "qty": qty, "big": big, "frac": frac},
        overlay=["cat", "qty", "big", "frac"], k=8, rules=["e"],
    )
    dc = DC("e", [Atom("cat", "==", "cat"), Atom("qty", "<", "qty")])
    plan = kops.plan_dc_encodings(
        {a: rel.columns[a] for a in ("cat", "qty")},
        [(a.left, a.right, a.op) for a in dc.atoms],
    )
    assert plan is not None and plan["cat"].kind == "code", plan
    assert plan["qty"].kind == "int8", plan

    # boundary columns: int8 overflow and non-integral floats must demote
    plan2 = kops.plan_dc_encodings(
        {a: rel.columns[a] for a in ("big", "frac")},
        [("big", "big", "<"), ("frac", "frac", ">")],
    )
    if plan2 is not None:
        assert plan2["big"].kind in ("bf16", "orig"), plan2
        assert plan2["frac"].kind == "orig", plan2

    for rule in (
        dc,
        DC("e2", [Atom("big", "<", "big"), Atom("frac", ">", "frac")]),
    ):
        enc = detect_dc(rel, rule, rel.valid, rel.valid, block=block, encode=True)
        raw = detect_dc(rel, rule, rel.valid, rel.valid, block=block, encode=False)
        _assert_identical(enc, raw, f"encoded vs raw detect ({rule.name})")
    return {a: plan[a].kind for a in plan}


def executor_gate(n: int, block: int, seed: int = 17):
    """A half-cleaned scope's full clean launches exactly the ledger's cold
    geometry, visible in ``StepReport.tiles_launched``."""
    rng = np.random.default_rng(seed)
    price = rng.uniform(0.0, 100.0, n).astype(np.float32)
    disc = (100.0 - price + rng.normal(0.0, 5.0, n)).astype(np.float32)
    rel = make_relation(
        {"price": price, "disc": disc}, overlay=["price", "disc"],
        k=8, rules=["pd"],
    )
    dc = DC("pd", [Atom("price", "<", "price"), Atom("disc", ">", "disc")])
    cfg = DaisyConfig(
        use_cost_model=False, accuracy_threshold=2.0,
        dc_block=block, strip_rows=block, dc_partitions=4,
    )
    daisy = Daisy({"t": rel}, {"t": [dc]}, cfg)
    scope = daisy.ledger.scope("t", "pd")
    for _ in range(scope.n_strips // 2):
        daisy.clean_scope_increment("t", "pd", max_strips=1)
    cold_ids = scope.cold_block_ids(block)
    nb = -(-rel.capacity // block)
    expected = int(cold_ids.size) * nb
    res = daisy.execute(Query("t", preds=(Pred("price", ">=", 0.0),)))
    step = res.report.steps[0]
    assert step.mode == "full", step
    assert step.tiles_launched == expected, (
        f"executor launch {step.tiles_launched} != ledger geometry {expected}"
    )
    assert scope.tiles_launched >= expected and scope.tiles_skipped > 0
    return {"expected": expected, "launched": step.tiles_launched}


def run(quick: bool = True):
    n, block = (1024, 64) if quick else (4096, 128)
    n_interp = 512 if quick else 1024

    # ref-path sweep at full size, interpret-mode sweep at kernel-test size
    records = sparsity_sweep(n, block, interpret=False)
    sparsity_sweep(n_interp, 64, interpret=True)

    by_frac = {r["sparsity"]: r for r in records}
    ratio = by_frac[0.0]["bytes_moved"] / max(by_frac[0.9]["bytes_moved"], 1)
    assert ratio >= 2.0, (
        f"90%-converged scan only {ratio:.2f}x below dense bytes"
    )
    # the launched tiles move exactly the cold work's modeled minimum —
    # >= 90% of the memory bound by construction of the worklist
    useful = by_frac[0.9]["tiles_launched"] * by_frac[0.9]["bytes_per_tile"]
    bound_frac = useful / max(by_frac[0.9]["bytes_moved"], 1)
    assert bound_frac >= 0.9, f"memory-bound fraction {bound_frac:.2f}"

    enc_plan = encoding_gate(512 if quick else 2048, 64)
    e2e = executor_gate(256 if quick else 1024, 32)

    os.makedirs(DRYRUN_DIR, exist_ok=True)
    for r in records:
        path = os.path.join(
            DRYRUN_DIR, f"kernel_dc_pairs_s{int(r['sparsity'] * 100):03d}.json"
        )
        with open(path, "w") as f:
            json.dump(
                {
                    "kernel": {
                        "name": "dc_pairs",
                        "n": n,
                        "block": block,
                        **r,
                        "memory_bound_fraction": (
                            r["tiles_launched"] * r["bytes_per_tile"]
                            / max(r["bytes_moved"], 1)
                        ),
                    }
                },
                f,
            )

    for r in records:
        print(
            f"kernel_sparsity {r['sparsity']:>4.0%} converged: "
            f"{r['tiles_launched']:>4d}/{r['tiles_total']} tiles, "
            f"{r['bytes_moved'] / 2**20:.2f} MiB (dense "
            f"{r['bytes_dense'] / 2**20:.2f} MiB)"
        )
    print(
        f"kernel_sparsity: bit-identical at all levels; 90% converged moves "
        f"{ratio:.1f}x fewer bytes than dense; encodings {enc_plan}; "
        f"executor full clean launched {e2e['launched']} tiles "
        f"(= ledger geometry)"
    )
    artifact = write_csv(
        "kernel_sparsity",
        ["sparsity", "tiles_launched", "tiles_total", "bytes_moved",
         "bytes_dense", "bytes_per_tile"],
        [[r["sparsity"], r["tiles_launched"], r["tiles_total"],
          r["bytes_moved"], r["bytes_dense"], r["bytes_per_tile"]]
         for r in records],
    )
    return {
        "artifact": artifact,
        "gates": {
            "bit_identical": True,
            "launch_matches_ledger": True,
            "zero_launch_when_converged": by_frac[1.0]["tiles_launched"] == 0,
            "bytes_ratio_90pct": round(ratio, 2),
            "memory_bound_fraction_90pct": round(bound_frac, 3),
            "encodings_bit_identical": True,
            "executor_launch_matches_ledger": True,
        },
        "headline": {
            "n": n,
            "block": block,
            "tiles_dense": by_frac[0.0]["tiles_launched"],
            "tiles_90pct": by_frac[0.9]["tiles_launched"],
            "bytes_dense_mib": round(by_frac[0.0]["bytes_moved"] / 2**20, 3),
            "bytes_90pct_mib": round(by_frac[0.9]["bytes_moved"] / 2**20, 3),
            "encoding_plan": enc_plan,
        },
    }


if __name__ == "__main__":
    run()
