"""Fig. 13/14/15 — join workloads: plain joins, mixed SP+join with the
cost-model switch, and multi-join + group-by complex queries."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv
from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.offline import OfflineCleaner
from repro.core.operators import GroupBySpec, JoinClause, Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import inject_fd_errors, ssb_lineorder, suppliers

N = 2048
N_SUP = 64


def build_db(seed: int = 31):
    lo = ssb_lineorder(N, 256, N_SUP, seed=seed)
    ds_lo = inject_fd_errors(lo, "orderkey", "suppkey", 1.0, 0.1, N_SUP, seed=seed + 1)
    sup = suppliers(N_SUP, seed=seed + 2)
    ds_sup = inject_fd_errors(sup, "address", "suppkey", 1.0, 0.1, N_SUP, seed=seed + 3)
    db = {
        "lineorder": make_relation(
            ds_lo.data, overlay=["orderkey", "suppkey"], k=8, rules=["phi"]
        ),
        "suppliers": make_relation(
            ds_sup.data, overlay=["address", "suppkey"], k=8, rules=["psi"]
        ),
    }
    rules = {
        "lineorder": [FD("phi", "orderkey", "suppkey")],
        "suppliers": [FD("psi", "address", "suppkey")],
    }
    return db, rules


def join_queries(nq: int):
    edges = np.linspace(0, N_SUP, nq + 1).astype(int)
    return [
        Query(
            "lineorder",
            preds=(Pred("suppkey", ">=", int(a)), Pred("suppkey", "<", int(b))),
            joins=(JoinClause("suppliers", "suppkey", "suppkey"),),
        )
        for a, b in zip(edges[:-1], edges[1:])
    ]


def run(quick: bool = False):
    nq = 6 if quick else 20
    cfg = DaisyConfig(join_capacity=16384, use_cost_model=False)
    rows = []

    qs = join_queries(nq)
    db, rules = build_db()
    daisy = Daisy(db, rules, cfg)
    t0 = time.perf_counter()
    for q in qs:
        daisy.execute(q)
    t_d = time.perf_counter() - t0

    db, rules = build_db()
    off = OfflineCleaner(db, rules, cfg)
    t0 = time.perf_counter()
    off.clean_all()
    for q in qs:
        off.execute(q)
    t_o = time.perf_counter() - t0
    rows.append(["join_only", round(t_d, 3), round(t_o, 3)])
    print(f"fig13 joins: daisy {t_d:.2f}s offline {t_o:.2f}s")

    # Fig. 15-style: join + group-by (Q2/Q3 analogue)
    q_complex = Query(
        "lineorder",
        preds=(Pred("suppkey", ">=", 0),),
        joins=(JoinClause("suppliers", "suppkey", "suppkey"),),
        groupby=GroupBySpec(keys=("region",), agg="count", table="suppliers"),
    )
    db, rules = build_db()
    daisy = Daisy(db, rules, cfg)
    _, t_d2 = _timed(lambda: daisy.execute(q_complex))
    db, rules = build_db()
    off = OfflineCleaner(db, rules, cfg)
    off.clean_all()
    _, t_o2 = _timed(lambda: off.execute(q_complex))
    rows.append(["join_groupby", round(t_d2, 3), round(t_o2, 3)])
    print(f"fig15 join+groupby: daisy {t_d2:.2f}s offline(post-clean) {t_o2:.2f}s")
    return write_csv("fig13", ["workload", "daisy_s", "offline_s"], rows)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


if __name__ == "__main__":
    run()
