"""Fig. 12 — general DCs with inequality predicates.

rule: NOT(t1.extended_price < t2.extended_price AND t1.discount > t2.discount)
over lineorder with 0.2% / 2% / 20% induced violation rates; Algorithm 2's
accuracy estimate decides partial vs full cleaning per query.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv
from repro.core.constraints import DC, Atom
from repro.core.executor import Daisy, DaisyConfig
from repro.core.offline import OfflineCleaner
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import inject_dc_errors, ssb_lineorder

N = 1024  # pairwise scans are O(N^2 / p)
QUERIES = 20


def build(viol_frac: float, seed: int = 21):
    clean = ssb_lineorder(N, 128, 16, seed=seed)
    # monotone-consistent clean data: discount decreasing in price
    order = np.argsort(clean["extended_price"])
    d = np.sort(clean["discount"])[::-1]
    clean["discount"] = d[np.argsort(order)].astype(np.float32)
    ds = inject_dc_errors(clean, "discount", viol_frac, 0.3, seed=seed + 1)
    return ds


def price_queries(nq: int):
    edges = np.linspace(1000, 5000, nq + 1)
    return [
        Query("t", preds=(Pred("extended_price", ">=", float(a)),
                          Pred("extended_price", "<", float(b))))
        for a, b in zip(edges[:-1], edges[1:])
    ]


def run(quick: bool = False):
    dc = DC("dc_pd", [Atom("extended_price", "<", "extended_price"),
                      Atom("discount", ">", "discount")])
    fracs = [0.02] if quick else [0.002, 0.02, 0.2]
    nq = 8 if quick else QUERIES
    qs = price_queries(nq)
    rows = []
    for frac in fracs:
        ds = build(frac)
        rel = make_relation(
            ds.data, overlay=["extended_price", "discount"], k=8, rules=["dc_pd"]
        )
        daisy = Daisy({"t": rel}, {"t": [dc]},
                      DaisyConfig(dc_partitions=16, accuracy_threshold=0.3,
                                  expected_queries=nq, use_cost_model=False))
        t0 = time.perf_counter()
        modes = []
        for q in qs:
            res = daisy.execute(q)
            modes.extend(s.mode for s in res.report.steps)
        t_d = time.perf_counter() - t0

        rel = make_relation(
            ds.data, overlay=["extended_price", "discount"], k=8, rules=["dc_pd"]
        )
        off = OfflineCleaner({"t": rel}, {"t": [dc]})
        t0 = time.perf_counter()
        off.clean_all()
        for q in qs:
            off.execute(q)
        t_o = time.perf_counter() - t0
        full_frac = modes.count("full") / max(len(modes), 1)
        rows.append([frac, round(t_d, 3), round(t_o, 3), round(full_frac, 2)])
        print(f"fig12 viol={frac}: daisy {t_d:.2f}s offline {t_o:.2f}s "
              f"(full-clean queries: {full_frac:.0%})")
    return write_csv("fig12", ["viol_frac", "daisy_s", "offline_s", "full_query_frac"], rows)


if __name__ == "__main__":
    run()
