"""Shared benchmark harness: timing, CSV output, workload builders."""

from __future__ import annotations

import csv
import os
import time
from typing import Callable, List, Optional, Sequence

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.offline import OfflineCleaner
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import inject_fd_errors, ssb_lineorder

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def write_csv(name: str, header: Sequence[str], rows: List[Sequence]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def build_lineorder_db(
    n: int = 4096,
    n_orderkeys: int = 512,
    n_suppkeys: int = 64,
    frac_rows: float = 0.1,
    k: int = 8,
    seed: int = 0,
):
    """Dirty lineorder relation + the FD rule (paper §7 setup)."""
    clean = ssb_lineorder(n, n_orderkeys, n_suppkeys, seed=seed)
    ds = inject_fd_errors(
        clean, "orderkey", "suppkey", frac_groups=1.0, frac_rows=frac_rows,
        n_values=n_suppkeys, seed=seed + 1,
    )
    rel = make_relation(
        ds.data, overlay=["orderkey", "suppkey"], k=k, rules=["fd_os"]
    )
    fd = FD("fd_os", "orderkey", "suppkey")
    return rel, fd, ds


def sp_workload(
    n_queries: int,
    col: str,
    values: Sequence,
    ranges: bool = False,
) -> List[Query]:
    """Non-overlapping SP queries (equality or range filters)."""
    qs = []
    for i in range(n_queries):
        if ranges:
            lo, hi = values[i]
            qs.append(
                Query("t", preds=(Pred(col, ">=", lo), Pred(col, "<", hi)))
            )
        else:
            qs.append(Query("t", preds=(Pred(col, "==", values[i]),)))
    return qs


def run_daisy(rel, rules, queries, cfg: Optional[DaisyConfig] = None) -> float:
    daisy = Daisy({"t": rel}, {"t": rules}, cfg or DaisyConfig())
    t0 = time.perf_counter()
    for q in queries:
        daisy.execute(q)
    return time.perf_counter() - t0


def run_offline(rel, rules, queries, cfg: Optional[DaisyConfig] = None) -> float:
    off = OfflineCleaner({"t": rel}, {"t": rules}, cfg)
    t0 = time.perf_counter()
    off.clean_all()
    for q in queries:
        off.execute(q)
    return time.perf_counter() - t0
