"""Fig. 11 — cost with an increasing fraction of erroneous orderkeys
(20% .. 80%); the dirty-group statistics skip clean groups."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_daisy, run_offline, write_csv
from repro.core.constraints import FD
from repro.core.executor import DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import inject_fd_errors, ssb_lineorder

N = 4096
QUERIES = 50


def run(quick: bool = False):
    fracs = [0.2, 0.8] if quick else [0.2, 0.4, 0.6, 0.8]
    nq = 20 if quick else QUERIES
    edges = np.linspace(0, 512, nq + 1).astype(int)
    qs = [
        Query("t", preds=(Pred("orderkey", ">=", int(a)), Pred("orderkey", "<", int(b))))
        for a, b in zip(edges[:-1], edges[1:])
    ]
    fd = FD("r", "orderkey", "suppkey")
    rows = []
    for frac in fracs:
        clean = ssb_lineorder(N, 512, 64, seed=11)
        ds = inject_fd_errors(clean, "orderkey", "suppkey", frac, 0.3, 64, seed=12)
        rel = make_relation(ds.data, overlay=["orderkey", "suppkey"], k=8, rules=["r"])
        t_d = run_daisy(rel, [fd], qs, DaisyConfig(expected_queries=nq))
        rel = make_relation(ds.data, overlay=["orderkey", "suppkey"], k=8, rules=["r"])
        t_o = run_offline(rel, [fd], qs)
        rows.append([frac, round(t_d, 3), round(t_o, 3)])
        print(f"fig11 frac={frac}: daisy {t_d:.2f}s offline {t_o:.2f}s")
    return write_csv("fig11", ["error_frac", "daisy_s", "offline_s"], rows)


if __name__ == "__main__":
    run()
