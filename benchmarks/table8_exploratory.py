"""Table 8 — exploratory analysis scenarios: Nestle-style (category queries
over material->category FD, tiny rhs cardinality) and the training-corpus
metadata pipeline (the framework's own Daisy-in-the-loop use)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv
from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.offline import OfflineCleaner
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import inject_fd_errors
from repro.data.pipeline import PipelineConfig, default_pipeline


def nestle_like(n: int = 4096, seed: int = 41):
    rng = np.random.default_rng(seed)
    n_mat = 256
    material = rng.integers(0, n_mat, n).astype(np.int32)
    cat_of_mat = rng.integers(0, 8, n_mat).astype(np.int32)  # tiny rhs card
    data = {
        "material": material,
        "category": cat_of_mat[material],
        "price": rng.uniform(1, 50, n).astype(np.float32),
    }
    return inject_fd_errors(data, "material", "category", 1.0, 0.1, 8, seed=seed + 1)


def run(quick: bool = False):
    rows = []
    nq = 8 if quick else 37
    ds = nestle_like()
    fd = FD("mc", "material", "category")
    qs = [Query("t", preds=(Pred("category", "==", i % 8),)) for i in range(nq)]
    rel = make_relation(ds.data, overlay=["material", "category"], k=8, rules=["mc"])
    daisy = Daisy({"t": rel}, {"t": [fd]}, DaisyConfig(expected_queries=nq))
    t0 = time.perf_counter()
    for q in qs:
        daisy.execute(q)
    t_d = time.perf_counter() - t0
    rel = make_relation(ds.data, overlay=["material", "category"], k=8, rules=["mc"])
    off = OfflineCleaner({"t": rel}, {"t": [fd]})
    t0 = time.perf_counter()
    off.clean_all()
    for q in qs:
        off.execute(q)
    t_o = time.perf_counter() - t0
    rows.append(["nestle_like", round(t_d, 3), round(t_o, 3)])
    print(f"table8 nestle: daisy {t_d:.2f}s offline {t_o:.2f}s")

    # corpus-metadata pipeline scenario (the paper's technique inside the
    # training data plane)
    pipe, workload = default_pipeline(
        n_docs=1024, cfg=PipelineConfig(batch_docs=8, seq_len=64)
    )
    t0 = time.perf_counter()
    for batch in pipe.batches(workload, steps=8 if quick else 16):
        pass
    t_p = time.perf_counter() - t0
    prog = pipe.cleaning_progress()
    rows.append(["corpus_pipeline", round(t_p, 3), ""])
    print(f"table8 corpus pipeline: {t_p:.2f}s, cleaned: {prog}")
    return write_csv("table8", ["scenario", "daisy_s", "offline_s"], rows)


if __name__ == "__main__":
    run()
