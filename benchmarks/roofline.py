"""Roofline report: assemble experiments/dryrun/*.json into the §Roofline
table (per arch x shape x mesh: the three terms, dominant bottleneck,
useful-FLOPs ratio, memory fit) — plus, when ``benchmarks.kernel_sparsity``
has written measured-launch records (``{"kernel": ...}``), a second table
of MEASURED kernel geometry: tiles launched vs dense, bytes moved,
bytes/tile, and the fraction of the cold work's memory bound the launch
achieved (DESIGN.md §15).  Analytic dryrun estimates and measured launch
records live side by side in the same directory.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
                                                 [--md out.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

HBM_PER_CHIP = 16 * 2**30  # v5e


def load(dir_: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: Dict) -> List[str]:
    rl = r["roofline"]
    peak = r["memory"]["peak_bytes"]
    fits = "Y" if peak <= HBM_PER_CHIP else f"over x{peak/HBM_PER_CHIP:.1f}"
    return [
        r["arch"],
        r["shape"],
        "x".join(str(v) for v in r["mesh"].values()),
        f"{rl['compute_s']:.4f}",
        f"{rl['memory_s']:.4f}",
        f"{rl['collective_s']:.4f}",
        rl["dominant"],
        f"{rl['roofline_fraction']:.3f}",
        f"{r.get('useful_flops_ratio', 0):.2f}",
        f"{peak/2**30:.1f}",
        fits,
    ]


HEADER = [
    "arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
    "dominant", "roofline_frac", "useful_flops", "peak_GiB", "fits_16G",
]

KERNEL_HEADER = [
    "kernel", "n", "block", "sparsity", "tiles", "dense_tiles",
    "launch_frac", "MiB_moved", "MiB_dense", "bytes_per_tile", "mem_bound_frac",
]


def kernel_table(recs: List[Dict]) -> str | None:
    """Measured-launch table from ``kernel_sparsity`` records — launch
    geometry and modeled bytes straight from the scans that actually ran,
    not the analytic dryrun estimator."""
    rows = []
    for r in sorted(recs, key=lambda r: (r["kernel"]["name"],
                                         r["kernel"].get("sparsity", 0))):
        k = r["kernel"]
        rows.append([
            k["name"], str(k["n"]), str(k["block"]),
            f"{k.get('sparsity', 0):.0%}",
            str(k["tiles_launched"]), str(k["tiles_total"]),
            f"{k['tiles_launched'] / max(k['tiles_total'], 1):.3f}",
            f"{k['bytes_moved'] / 2**20:.3f}",
            f"{k.get('bytes_dense', k['bytes_moved']) / 2**20:.3f}",
            str(k["bytes_per_tile"]),
            f"{k.get('memory_bound_fraction', 1.0):.3f}",
        ])
    if not rows:
        return None
    lines = ["| " + " | ".join(KERNEL_HEADER) + " |",
             "|" + "---|" * len(KERNEL_HEADER)]
    lines += ["| " + " | ".join(row) + " |" for row in rows]
    return "\n".join(lines)


def run(quick: bool = False, dir_: str = "experiments/dryrun",
        md_out: str | None = None):
    all_recs = load(dir_)
    kern = kernel_table([r for r in all_recs if "kernel" in r])
    recs = [r for r in all_recs if "roofline" in r]
    if not recs:
        print("no dry-run records found — run repro.launch.dryrun --all first")
        if kern:
            print("\n## Measured kernel launches\n" + kern)
            if md_out:
                with open(md_out, "w") as f:
                    f.write(kern + "\n")
        return
    recs.sort(key=lambda r: (r["arch"], r["shape"], len(r["mesh"])))
    lines = ["| " + " | ".join(HEADER) + " |",
             "|" + "---|" * len(HEADER)]
    for r in recs:
        lines.append("| " + " | ".join(fmt_row(r)) + " |")
    table = "\n".join(lines)
    if kern:
        table += "\n\n## Measured kernel launches\n" + kern
    print(table)
    if md_out:
        with open(md_out, "w") as f:
            f.write(table + "\n")
    # aggregates
    doms = {}
    for r in recs:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    print(f"\ncells: {len(recs)}  dominant-term distribution: {doms}")
    worst = min(recs, key=lambda r: r["roofline"]["roofline_fraction"])
    print(f"worst roofline fraction: {worst['arch']} x {worst['shape']} "
          f"({worst['roofline']['roofline_fraction']:.3f})")
    coll = max(recs, key=lambda r: r["roofline"]["collective_s"]
               / max(r["roofline"]["bound_s"], 1e-12))
    print(f"most collective-bound: {coll['arch']} x {coll['shape']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    run(dir_=args.dir, md_out=args.md)
