"""Fig. 10 — single rule vs two overlapping rules (shared rhs attribute).

phi: orderkey -> suppkey and psi: address -> suppkey over the joined
lineorder x suppliers table; 50 non-overlapping queries.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_daisy, run_offline, write_csv
from repro.core.constraints import FD
from repro.core.executor import DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import inject_fd_errors, ssb_lineorder, suppliers

N = 4096
QUERIES = 50


def build():
    n_sup = 64
    lo = ssb_lineorder(N, 512, n_sup, seed=3)
    sup = suppliers(n_sup, seed=4)
    addr_of_sup = np.zeros(n_sup, np.int32)
    addr_of_sup[sup["suppkey"]] = sup["address"]
    joined = dict(lo)
    joined["address"] = addr_of_sup[lo["suppkey"]]
    ds = inject_fd_errors(joined, "orderkey", "suppkey", 1.0, 0.1, n_sup, seed=5)
    return ds


def queries():
    edges = np.linspace(0, 512, QUERIES + 1).astype(int)
    return [
        Query("t", preds=(Pred("orderkey", ">=", int(a)), Pred("orderkey", "<", int(b))))
        for a, b in zip(edges[:-1], edges[1:])
    ]


def run(quick: bool = False):
    nq = 15 if quick else QUERIES
    qs = queries()[:nq]
    phi = FD("phi", "orderkey", "suppkey")
    psi = FD("psi", "address", "suppkey")
    rows = []
    for label, rules in [("phi", [phi]), ("phi+psi", [phi, psi])]:
        ds = build()
        rel = make_relation(
            ds.data, overlay=["orderkey", "suppkey", "address"], k=8,
            rules=[r.name for r in rules],
        )
        t_d = run_daisy(rel, rules, qs, DaisyConfig(expected_queries=nq))
        rel = make_relation(
            ds.data, overlay=["orderkey", "suppkey", "address"], k=8,
            rules=[r.name for r in rules],
        )
        t_o = run_offline(rel, rules, qs)
        rows.append([label, round(t_d, 3), round(t_o, 3)])
        print(f"fig10 {label}: daisy {t_d:.2f}s offline {t_o:.2f}s")
    return write_csv("fig10", ["rules", "daisy_s", "offline_s"], rows)


if __name__ == "__main__":
    run()
