"""Shared fixtures: the paper's running examples as Relations.

Also installs the hypothesis fallback shim when the real package is
missing (the dev container has no wheel; CI installs the real one).
"""

from __future__ import annotations

import importlib.util
import os
import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover — only in wheel-less environments
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"),
    )
    _fallback = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_fallback)
    sys.modules["hypothesis"], sys.modules["hypothesis.strategies"] = (
        _fallback.build_module()
    )

from repro.core.constraints import FD, DC, Atom
from repro.core.relation import Dictionary, make_relation


# city codes used across the paper's examples
CITY = Dictionary(["Los Angeles", "San Francisco", "New York"])
LA, SF, NY = 0, 1, 2


@pytest.fixture
def cities_rel():
    """Table 2a — the Cities dataset (dirty version).

    row 0: 9001  Los Angeles
    row 1: 9001  San Francisco   <- conflicts with 0, 2
    row 2: 9001  Los Angeles
    row 3: 10001 San Francisco   <- conflicts with 4
    row 4: 10001 New York
    """
    return make_relation(
        {
            "zip": np.array([9001, 9001, 9001, 10001, 10001]),
            "city": np.array([LA, SF, LA, SF, NY]),
        },
        overlay=["zip", "city"],
        k=4,
        rules=["zip_city"],
    )


@pytest.fixture
def fd_zip_city():
    return FD("zip_city", "zip", "city")


@pytest.fixture
def salary_rel():
    """Example 4 — {salary, tax, age} rows t1, t2, t3."""
    return make_relation(
        {
            "salary": np.array([1000.0, 3000.0, 2000.0], dtype=np.float32),
            "tax": np.array([0.1, 0.2, 0.3], dtype=np.float32),
            "age": np.array([31, 32, 43]),
        },
        overlay=["salary", "tax"],
        k=4,
        rules=["dc_sal_tax"],
    )


@pytest.fixture
def dc_sal_tax():
    """phi: forall t1,t2 NOT(t1.salary < t2.salary AND t1.tax > t2.tax)."""
    return DC("dc_sal_tax", [Atom("salary", "<", "salary"), Atom("tax", ">", "tax")])


@pytest.fixture
def join_tables():
    """Example 6 — Cities (C) and Employee (E) of Table 4a/4b."""
    cities = make_relation(
        {
            "zip": np.array([9001, 9001, 10001]),
            "city": np.array([LA, SF, SF]),
        },
        overlay=["zip", "city"],
        k=4,
        rules=["phi1"],
    )
    employee = make_relation(
        {
            "zip": np.array([9001, 10001, 10002]),
            "name": np.array([0, 1, 2]),  # Peter, Mary, Jon
            "phone": np.array([23456, 12345, 12345]),
        },
        overlay=["zip", "phone"],
        k=4,
        rules=["phi2"],
    )
    return {"cities": cities, "employee": employee}
