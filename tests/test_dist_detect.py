"""Sharded detection (dist/detect.py) vs the dense scans — DESIGN.md §8.

The sharded path must be BIT-identical to the dense one: counts, extremal
partner stats, candidate tables, frequencies, flags.  In-process tests use
logical shards on the single CPU device (the routing/scan/un-route math is
the same); the subprocess test repeats the equivalence on a real 8-device
mesh where ``shard_map`` actually partitions the shards.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.constraints import DC, FD, Atom, equality_key_attrs
from repro.core.detect import (
    detect_dc,
    detect_dc_auto,
    detect_fd,
    detect_fd_auto,
    will_shard,
)
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.dist.detect import (
    detect_dc_sharded_info,
    detect_fd_sharded_info,
    pair_count_report,
)


def one_device_mesh():
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def random_rel(n=96, n_keys=7, seed=0):
    rng = np.random.default_rng(seed)
    return make_relation(
        {
            "dept": rng.integers(0, n_keys, n).astype(np.int32),
            "salary": rng.integers(1, 9, n).astype(np.float32),
            "tax": rng.integers(1, 9, n).astype(np.float32) / 10.0,
        },
        overlay=["salary", "tax"],
        k=4,
        rules=["phi"],
    )


DC_EQ = DC(
    "phi",
    [
        Atom("dept", "==", "dept"),
        Atom("salary", "<", "salary"),
        Atom("tax", ">", "tax"),
    ],
)
DC_NO_EQ = DC(
    "phi_noeq", [Atom("salary", "<", "salary"), Atom("tax", ">", "tax")]
)


def assert_dc_equal(dense, shard):
    np.testing.assert_array_equal(np.asarray(dense.t1_count), np.asarray(shard.t1_count))
    np.testing.assert_array_equal(np.asarray(dense.t2_count), np.asarray(shard.t2_count))
    for a in range(len(dense.t1_stat)):
        np.testing.assert_array_equal(
            np.asarray(dense.t1_stat[a]), np.asarray(shard.t1_stat[a])
        )
        np.testing.assert_array_equal(
            np.asarray(dense.t2_stat[a]), np.asarray(shard.t2_stat[a])
        )


class TestDCShardedEquivalence:
    def test_full_scope_bit_identical(self):
        rel = random_rel()
        dense = detect_dc(rel, DC_EQ, rel.valid, rel.valid)
        shard, info = detect_dc_sharded_info(
            rel, DC_EQ, rel.valid, rel.valid, one_device_mesh(), n_shards=4
        )
        assert_dc_equal(dense, shard)
        assert info.n_shards == 4 and info.routed_rows == 96
        # sharding actually shrinks the comparison space
        assert info.sharded_pairs < info.dense_pairs
        assert int(np.asarray(dense.t1_count).sum()) > 0  # non-trivial case

    def test_asymmetric_scopes(self):
        """Incremental-cleaning shape: row_scope (answer) vs col_scope (rest)."""
        rel = random_rel(seed=3)
        rng = np.random.default_rng(4)
        rs = jnp.asarray(rng.random(96) < 0.3) & rel.valid
        cs = jnp.asarray(rng.random(96) < 0.8) & rel.valid
        dense = detect_dc(rel, DC_EQ, rs, cs)
        shard, _ = detect_dc_sharded_info(
            rel, DC_EQ, rs, cs, one_device_mesh(), n_shards=4
        )
        assert_dc_equal(dense, shard)

    def test_overflow_retry_on_skew(self):
        """One key -> one shard: the first shuffle overflows its capacity
        and the driver retries with a doubled factor, still bit-identical."""
        rng = np.random.default_rng(1)
        n = 64
        rel = make_relation(
            {
                "dept": np.zeros(n, np.int32),
                "salary": rng.integers(1, 9, n).astype(np.float32),
                "tax": rng.integers(1, 9, n).astype(np.float32) / 10.0,
            },
            overlay=["salary", "tax"],
            k=4,
            rules=["phi"],
        )
        dense = detect_dc(rel, DC_EQ, rel.valid, rel.valid)
        shard, info = detect_dc_sharded_info(
            rel, DC_EQ, rel.valid, rel.valid, one_device_mesh(), n_shards=4
        )
        assert info.retries >= 1
        assert info.capacity_factor > 2.0
        assert info.per_shard_rows == [64, 0, 0, 0]
        assert_dc_equal(dense, shard)

    def test_negative_zero_key_routes_together(self):
        """-0.0 == 0.0 must share a shard (float keys collapse -0.0)."""
        rel = make_relation(
            {
                "pivot": np.array([0.0, -0.0, 0.0, 1.0], dtype=np.float32),
                "salary": np.array([1.0, 3.0, 2.0, 5.0], dtype=np.float32),
                "tax": np.array([0.1, 0.2, 0.3, 0.1], dtype=np.float32),
            },
            overlay=["salary", "tax"],
            k=4,
        )
        dc = DC(
            "phi0",
            [
                Atom("pivot", "==", "pivot"),
                Atom("salary", "<", "salary"),
                Atom("tax", ">", "tax"),
            ],
        )
        dense = detect_dc(rel, dc, rel.valid, rel.valid)
        shard, _ = detect_dc_sharded_info(
            rel, dc, rel.valid, rel.valid, one_device_mesh(), n_shards=2
        )
        assert int(np.asarray(dense.t1_count).sum()) == 1  # row2 vs row1
        assert_dc_equal(dense, shard)

    def test_sub_one_capacity_factor_clamped(self):
        """factor < 1 must not shrink the un-route scatter target below the
        relation capacity (rows would silently drop)."""
        rel = random_rel(seed=11)
        dense = detect_dc(rel, DC_EQ, rel.valid, rel.valid)
        shard, _ = detect_dc_sharded_info(
            rel, DC_EQ, rel.valid, rel.valid, one_device_mesh(),
            n_shards=4, capacity_factor=0.5,
        )
        assert shard.t1_count.shape == dense.t1_count.shape
        assert_dc_equal(dense, shard)

    def test_no_equality_atom_rejected(self):
        rel = random_rel()
        assert equality_key_attrs(DC_NO_EQ) == ()
        with pytest.raises(ValueError, match="no same-attribute equality atom"):
            detect_dc_sharded_info(
                rel, DC_NO_EQ, rel.valid, rel.valid, one_device_mesh(), n_shards=4
            )


class TestFDShardedEquivalence:
    def test_bit_identical_both_groupings(self):
        rng = np.random.default_rng(5)
        n = 80
        rel = make_relation(
            {
                "zip": rng.integers(0, 9, n).astype(np.int32),
                "city": rng.integers(0, 5, n).astype(np.int32),
            },
            overlay=["zip", "city"],
            k=8,
            rules=["fd"],
        )
        fd = FD("fd", "zip", "city")
        dense = detect_fd(rel, fd, rel.valid, k=8)
        shard, info = detect_fd_sharded_info(
            rel, fd, rel.valid, one_device_mesh(), k=8, n_shards=4
        )
        np.testing.assert_array_equal(np.asarray(dense.violated), np.asarray(shard.violated))
        np.testing.assert_array_equal(np.asarray(dense.rhs_cand), np.asarray(shard.rhs_cand))
        np.testing.assert_array_equal(np.asarray(dense.rhs_count), np.asarray(shard.rhs_count))
        np.testing.assert_array_equal(np.asarray(dense.lhs_cand), np.asarray(shard.lhs_cand))
        np.testing.assert_array_equal(np.asarray(dense.lhs_count), np.asarray(shard.lhs_count))
        assert bool(np.asarray(dense.overflow)) == bool(np.asarray(shard.overflow))
        assert info.routed_rows == n

    def test_multi_attr_lhs(self):
        rng = np.random.default_rng(6)
        n = 60
        rel = make_relation(
            {
                "a": rng.integers(0, 4, n).astype(np.int32),
                "b": rng.integers(0, 3, n).astype(np.int32),
                "y": rng.integers(0, 5, n).astype(np.int32),
            },
            overlay=["y"],
            k=8,
            rules=["fd2"],
        )
        fd = FD("fd2", ("a", "b"), "y")
        dense = detect_fd(rel, fd, rel.valid, k=8)
        shard, _ = detect_fd_sharded_info(
            rel, fd, rel.valid, one_device_mesh(), k=8, n_shards=4
        )
        np.testing.assert_array_equal(np.asarray(dense.violated), np.asarray(shard.violated))
        np.testing.assert_array_equal(np.asarray(dense.rhs_cand), np.asarray(shard.rhs_cand))
        np.testing.assert_array_equal(np.asarray(dense.rhs_count), np.asarray(shard.rhs_count))
        assert dense.lhs_cand is None and shard.lhs_cand is None


class TestDispatch:
    def test_no_mesh_falls_back_dense(self, monkeypatch):
        import repro.dist.detect as ddet

        def boom(*a, **k):  # the sharded path must NOT be taken
            raise AssertionError("sharded path taken without a mesh")

        monkeypatch.setattr(ddet, "detect_dc_sharded", boom)
        rel = random_rel()
        det = detect_dc_auto(rel, DC_EQ, rel.valid, rel.valid, mesh=None)
        dense = detect_dc(rel, DC_EQ, rel.valid, rel.valid)
        assert_dc_equal(dense, det)

    def test_no_equality_atom_falls_back_dense(self, monkeypatch):
        import repro.dist.detect as ddet

        def boom(*a, **k):
            raise AssertionError("sharded path taken for a keyless DC")

        monkeypatch.setattr(ddet, "detect_dc_sharded", boom)
        rel = random_rel()
        assert not will_shard(DC_NO_EQ, one_device_mesh(), 4)
        det = detect_dc_auto(
            rel, DC_NO_EQ, rel.valid, rel.valid, mesh=one_device_mesh(), n_shards=4
        )
        dense = detect_dc(rel, DC_NO_EQ, rel.valid, rel.valid)
        assert_dc_equal(dense, det)

    def test_mesh_with_key_takes_sharded(self):
        rel = random_rel()
        mesh = one_device_mesh()
        assert will_shard(DC_EQ, mesh, 4)
        assert will_shard(FD("f", "dept", "salary"), mesh, 4)
        det = detect_dc_auto(rel, DC_EQ, rel.valid, rel.valid, mesh=mesh, n_shards=4)
        dense = detect_dc(rel, DC_EQ, rel.valid, rel.valid)
        assert_dc_equal(dense, det)

    def test_fd_auto_equivalent(self):
        rel = random_rel()
        fd = FD("f", "dept", "salary")
        dense = detect_fd(rel, fd, rel.valid, k=4)
        auto = detect_fd_auto(rel, fd, rel.valid, k=4, mesh=one_device_mesh(), n_shards=4)
        np.testing.assert_array_equal(np.asarray(dense.rhs_cand), np.asarray(auto.rhs_cand))
        np.testing.assert_array_equal(np.asarray(dense.violated), np.asarray(auto.violated))


class TestExecutorIntegration:
    def test_daisy_sharded_matches_dense(self):
        """End-to-end: the same query workload over a mesh-configured Daisy
        produces the same repairs and reports the sharded path."""
        def build(mesh):
            rel = random_rel(seed=9)
            cfg = DaisyConfig(k=4, mesh=mesh, detect_shards=4)
            return Daisy({"t": rel}, {"t": [DC_EQ]}, cfg)

        q = Query(table="t", preds=(Pred("salary", ">", 2.0),), project=("salary", "tax"))
        d_dense = build(None)
        d_shard = build(one_device_mesh())
        r_dense = d_dense.execute(q)
        r_shard = d_shard.execute(q)
        assert [s.mode for s in r_dense.report.steps] == [
            s.mode for s in r_shard.report.steps
        ]
        assert r_shard.report.steps[0].detect_path == "sharded"
        assert r_dense.report.steps[0].detect_path == "dense"
        np.testing.assert_array_equal(np.asarray(r_dense.mask), np.asarray(r_shard.mask))
        for attr in ("salary", "tax"):
            np.testing.assert_array_equal(
                np.asarray(d_dense.db["t"].cand[attr]),
                np.asarray(d_shard.db["t"].cand[attr]),
            )
            np.testing.assert_array_equal(
                np.asarray(d_dense.db["t"].ccount[attr]),
                np.asarray(d_shard.db["t"].ccount[attr]),
            )


class TestPairCountReport:
    def test_uniform_savings(self):
        rep = pair_count_report(1024, 16)
        assert rep["dense_pairs"] == 1024**2
        assert rep["sharded_pairs_uniform"] == 16 * 64**2
        assert rep["pair_savings_x"] == pytest.approx(16.0)

    def test_single_shard_no_savings(self):
        rep = pair_count_report(100, 1)
        assert rep["pair_savings_x"] == pytest.approx(1.0)


_SUBPROCESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(4, 2), ("data", "model"))

    from repro.core.constraints import DC, Atom
    from repro.core.relation import make_relation
    from repro.core.detect import detect_dc
    from repro.dist.detect import detect_dc_sharded_info

    rng = np.random.default_rng(0)
    n = 128
    rel = make_relation(
        {
            "dept": rng.integers(0, 11, n).astype(np.int32),
            "salary": rng.integers(1, 9, n).astype(np.float32),
            "tax": rng.integers(1, 9, n).astype(np.float32) / 10.0,
        },
        overlay=["salary", "tax"], k=4, rules=["phi"],
    )
    dc = DC("phi", [Atom("dept", "==", "dept"), Atom("salary", "<", "salary"),
                    Atom("tax", ">", "tax")])
    dense = detect_dc(rel, dc, rel.valid, rel.valid)
    # n_shards == the mesh's DP extent (4): shard_map partitions the scans
    shard, info = detect_dc_sharded_info(rel, dc, rel.valid, rel.valid, mesh)
    assert info.n_shards == 4, info
    np.testing.assert_array_equal(np.asarray(dense.t1_count), np.asarray(shard.t1_count))
    np.testing.assert_array_equal(np.asarray(dense.t2_count), np.asarray(shard.t2_count))
    for a in range(3):
        np.testing.assert_array_equal(np.asarray(dense.t1_stat[a]),
                                      np.asarray(shard.t1_stat[a]))
        np.testing.assert_array_equal(np.asarray(dense.t2_stat[a]),
                                      np.asarray(shard.t2_stat[a]))
    assert int(np.asarray(dense.t1_count).sum()) > 0
    print("SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_sharded_detect_on_mesh_subprocess():
    """Dense/sharded equivalence with shard_map on a real 4x2 device mesh."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_TEST],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": os.path.join(repo_root, "src"),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin")},
        cwd=repo_root,
    )
    assert "SUBPROCESS_OK" in res.stdout, res.stdout + "\n" + res.stderr
