"""Distribution layer tests on a small forced-device mesh.

conftest does NOT set XLA_FLAGS (smoke tests must see 1 device), so these
tests spawn a subprocess with 8 forced host devices where needed; pure
logic (specs, plans, compression math) runs in-process.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.collectives import dequantize_int8, quantize_int8
from repro.dist.sharding import _PARAM_RULES
from repro.train.fault_tolerance import (
    HeartbeatTracker,
    RetryPolicy,
    StragglerMonitor,
    elastic_mesh_plan,
    run_with_restarts,
)


class TestQuantization:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
        q, scale = quantize_int8(g)
        dq = dequantize_int8(q, scale)
        max_err = float(jnp.max(jnp.abs(g - dq)))
        assert max_err <= float(scale) / 2 + 1e-6

    def test_zero_gradient(self):
        g = jnp.zeros(16)
        q, scale = quantize_int8(g)
        assert not np.asarray(q).any()


class TestElasticPlan:
    def test_keeps_tp(self):
        plan = elastic_mesh_plan(512 - 16, model_parallel=16)
        assert plan["model"] == 16
        assert plan["data"] == 31
        assert plan["used_devices"] == 496

    def test_rejects_sub_tp(self):
        with pytest.raises(ValueError):
            elastic_mesh_plan(8, model_parallel=16)


class TestStragglerMonitor:
    def test_flags_outlier(self):
        mon = StragglerMonitor(warmup=4, k_sigma=3.0)
        for i in range(20):
            assert not mon.record(i, 1.0 + 0.01 * (i % 3))
        assert mon.record(20, 5.0)
        assert mon.flagged and mon.flagged[0][0] == 20

    def test_mean_resists_stragglers(self):
        mon = StragglerMonitor(warmup=4)
        for i in range(20):
            mon.record(i, 1.0)
        mon.record(20, 50.0)
        assert mon.mean < 1.5


class TestRetry:
    def test_restarts_then_succeeds(self):
        calls = {"n": 0, "restores": 0}

        def step():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("node died")

        restarts = run_with_restarts(
            step, lambda: calls.__setitem__("restores", calls["restores"] + 1),
            RetryPolicy(max_restarts=5, backoff_s=0), sleep=lambda s: None,
        )
        assert restarts == 2 and calls["restores"] == 2

    def test_gives_up(self):
        def step():
            raise RuntimeError("dead")

        with pytest.raises(RuntimeError):
            run_with_restarts(step, lambda: None,
                              RetryPolicy(max_restarts=2, backoff_s=0),
                              sleep=lambda s: None)


class TestHeartbeats:
    def test_dead_host_detection(self):
        hb = HeartbeatTracker(timeout_s=10)
        hb.beat(0, now=0.0)
        hb.beat(1, now=0.0)
        hb.beat(0, now=8.0)
        assert hb.dead_hosts(now=12.0) == [1]


_SUBPROCESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P, NamedSharding

    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(4, 2), ("data", "model"))

    # --- shuffle_by_key: groups end up whole on one shard -----------------
    from repro.dist.shuffle import shuffle_by_key, shuffle_by_key_host
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 13, (4, 32)).astype(np.int32)
    payload = np.stack([keys, rng.integers(0, 99, (4, 32)).astype(np.int32)], -1)
    payload[..., 0] = keys
    valid = rng.random((4, 32)) < 0.9
    k2, p2, v2, src, ovf = shuffle_by_key(
        jnp.asarray(keys), jnp.asarray(payload), jnp.asarray(valid), mesh
    )
    k2, v2, src = np.asarray(k2), np.asarray(v2), np.asarray(src)
    assert not bool(ovf)
    # every key lives on exactly one shard
    for key in np.unique(keys[valid]):
        shards = [s for s in range(4) if (k2[s][v2[s]] == key).any()]
        assert len(shards) == 1, (key, shards)
    # row conservation
    assert v2.sum() == valid.sum()
    # src is the inverse permutation: routed keys match their source rows
    fk = keys.reshape(-1)
    assert (fk[src[v2]] == k2[v2]).all()
    assert len(set(src[v2].tolist())) == int(v2.sum())  # no slot shares a source
    # matches the host reference semantics shard-for-shard
    hk, hp, hv, hsrc, hovf = shuffle_by_key_host(keys, payload, valid, 4)
    for s in range(4):
        assert sorted(k2[s][v2[s]].tolist()) == sorted(hk[s][hv[s]].tolist())

    # --- compressed gradient all-reduce ------------------------------------
    from repro.dist.collectives import grad_allreduce_compressed
    g = {"w": jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32))}
    e = {"w": jnp.zeros((4, 8), jnp.float32)}
    red, new_e = grad_allreduce_compressed(g, e, mesh)
    # replicated input -> mean == input (all shards equal)
    np.testing.assert_allclose(np.asarray(red["w"]), np.asarray(g["w"]), atol=0.05)

    # --- pipeline_apply (GPipe) --------------------------------------------
    from repro.dist.pipeline import pipeline_apply
    smesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4,), ("stage",))
    sp = jnp.asarray(np.arange(4, dtype=np.float32).reshape(4, 1) + 1.0)
    xm = jnp.asarray(rng.standard_normal((6, 3)).astype(np.float32))
    out = pipeline_apply(lambda p, x: x * p[0], sp, xm, smesh, stages=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(xm) * 24.0, rtol=1e-5)

    print("SUBPROCESS_OK")
""")


@pytest.mark.slow
def test_mesh_collectives_subprocess():
    """shuffle / compressed all-reduce / pipeline on an 8-device mesh."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_TEST],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": os.path.join(repo_root, "src"),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin")},
        cwd=repo_root,
    )
    assert "SUBPROCESS_OK" in res.stdout, res.stdout + "\n" + res.stderr


class TestParamRules:
    def test_all_rules_resolve(self):
        for name, rule in _PARAM_RULES.items():
            for entry in rule:
                assert entry in (None, "fsdp", "tp"), (name, entry)
