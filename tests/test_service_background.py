"""The background cleaner (DESIGN.md §10): seeded foreground/background
interleaving stays bit-identical to the PR 3 serial service, preemption
yields to foreground tickets within one increment, and per-scope cache
invalidation evicts exactly the touched fingerprints.

The interleaving tests use cluster-DISJOINT data (each zip group's city
values are unique to the group), where every answer is a pure function of
its own group's cleaning state — so bit-identity must hold for EVERY
schedule, which is what the seeded sweep asserts.
"""

import json
import threading

import numpy as np
import pytest

from repro.core.constraints import FD
from repro.core.cost import (
    CostModel,
    ScopePriority,
    prioritize_scopes,
    sharded_detect_cost,
)
from repro.core.executor import Daisy, DaisyConfig
from repro.core.ledger import TABLE_ROWS_RULE
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.service import BackgroundCleaner, QueryServer, rule_deps

GROUPS = 6
PER = 8
N = GROUPS * PER


def disjoint_factory(seed: int = 5):
    """Disjoint clusters: group g's city values live in [g*8, (g+1)*8);
    row 0 of each group is dirty, row 1 clean (deterministic detect work)."""
    rng = np.random.default_rng(seed)
    zipc = np.repeat(np.arange(GROUPS, dtype=np.int32), PER)
    city = (zipc * 8).astype(np.int32)
    edit = rng.random(N) < 0.3
    edit[0::PER] = True
    edit[1::PER] = False
    city[edit] = (zipc[edit] * 8 + rng.integers(1, 8, int(edit.sum()))).astype(
        np.int32
    )
    return {
        "h": make_relation(
            {"zip": zipc, "city": city}, overlay=["zip", "city"], k=8, rules=["zc"]
        )
    }


RULES = {"h": [FD("zc", "zip", "city")]}


def fresh_daisy(factory=disjoint_factory, rules=RULES):
    return Daisy(factory(), rules, DaisyConfig(use_cost_model=False))


def view(g: int) -> Query:
    """Group g's majority-city view — its answer depends on the group's
    repair candidates, so bit-identity is a real check."""
    return Query("h", preds=(Pred("city", "==", g * 8),))


# ------------------------------------------------------------- interleaving
class TestSeededInterleaving:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_bit_identical_to_serial_service(self, seed):
        """Any seeded interleaving of foreground queries and background
        increments answers bit-identically to the PR 3 serial service
        (no background) over the same query order — and converges on the
        same final candidate state."""
        rng = np.random.default_rng(seed)
        queries = [view(int(g)) for g in rng.integers(0, GROUPS, 18)]

        daisy = fresh_daisy()
        server = QueryServer(daisy)
        cleaner = BackgroundCleaner(daisy, server=server, increment_rows=PER)
        sess = server.open_session("s")
        answers = []
        it = iter(queries)
        pending = next(it, None)
        while pending is not None:
            if rng.random() < 0.5:
                t = server.submit(sess, pending)
                server.drain()
                answers.append(np.asarray(t.result.mask))
                pending = next(it, None)
            else:
                cleaner.drain(max_increments=int(rng.integers(1, 3)))

        serial = fresh_daisy()
        for q, got in zip(queries, answers):
            np.testing.assert_array_equal(
                got, np.asarray(serial.execute(q).mask), err_msg=str(q)
            )

        # converged state: finish background, run every view serially on the
        # reference; overlays must match exactly (Lemma 4 / §10 argument)
        cleaner.drain()
        for g in range(GROUPS):
            serial.execute(view(g))
        for attr in ("zip", "city"):
            np.testing.assert_array_equal(
                np.asarray(daisy.db["h"].cand[attr]),
                np.asarray(serial.db["h"].cand[attr]),
            )
            np.testing.assert_array_equal(
                np.asarray(daisy.db["h"].ccount[attr]),
                np.asarray(serial.db["h"].ccount[attr]),
            )

    def test_warmed_scope_serves_first_touch_without_detect(self):
        daisy = fresh_daisy()
        server = QueryServer(daisy)
        cleaner = BackgroundCleaner(daisy, server=server, increment_rows=N)
        assert cleaner.drain() >= 1
        assert daisy.cold_count("h", "zc") == 0
        sess = server.open_session("s")
        for g in range(GROUPS):
            server.submit(sess, view(g))
        server.drain()
        assert server.metrics.detect_calls == 0  # foreground paid nothing
        assert server.metrics.bg_detect_calls > 0


# --------------------------------------------------------------- preemption
class TestPreemption:
    def test_drain_yields_to_pending_foreground(self):
        daisy = fresh_daisy()
        server = QueryServer(daisy)
        cleaner = BackgroundCleaner(daisy, server=server, increment_rows=PER)
        sess = server.open_session("s")
        server.submit(sess, view(0))
        assert cleaner.preempted()
        assert cleaner.drain() == 0  # yielded before any increment
        assert server.metrics.bg_yields == 1
        server.drain()
        assert not cleaner.preempted()
        assert cleaner.drain(max_increments=1) == 1

    def test_increment_releases_lock_between_steps(self):
        """Preemption points: after every increment the executor lock is
        free — a foreground thread is never blocked across increments."""
        daisy = fresh_daisy()
        cleaner = BackgroundCleaner(daisy, increment_rows=PER)
        while cleaner.step() is not None:
            acquired = daisy.lock.acquire(timeout=1.0)
            assert acquired
            daisy.lock.release()

    def test_latency_bound_under_running_cleaner(self):
        """A query submitted while the cleaner thread churns a large cold
        backlog is answered within a small multiple of one increment."""
        daisy = fresh_daisy()
        server = QueryServer(daisy)
        cleaner = BackgroundCleaner(
            daisy, server=server, increment_rows=PER, idle_wait=0.005
        )
        serving = threading.Thread(target=server.run, daemon=True)
        serving.start()
        cleaner.start()
        try:
            sess = server.open_session("s")
            res = server.query(sess, view(GROUPS - 1), timeout=60)
            assert res.mask is not None
        finally:
            cleaner.stop()
            server.stop()
            serving.join(timeout=30)
        assert not serving.is_alive()


def dc_daisy(n: int = 64, seed: int = 7, block: int = 8):
    """A DC scope with many cold strips (n/block of them): the backlog the
    strip-grained increments must work through with bounded pauses."""
    from repro.core.constraints import DC, Atom
    from repro.core.relation import make_relation

    rng = np.random.default_rng(seed)
    price = rng.uniform(0.0, 50.0, n).astype(np.float32)
    disc = (50.0 - price + rng.normal(0, 4.0, n)).astype(np.float32)
    rel = make_relation(
        {"price": price, "disc": disc}, overlay=["price", "disc"],
        k=8, rules=["pd"],
    )
    dc = DC("pd", [Atom("price", "<", "price"), Atom("disc", ">", "disc")])
    return Daisy(
        {"t": rel}, {"t": [dc]},
        DaisyConfig(use_cost_model=False, dc_block=block, strip_rows=block,
                    dc_partitions=4),
    )


class TestDCPreemption:
    """The §11 bound: background DC cleaning is now per-strip increments
    that release the executor lock between strips — mirroring the FD
    ``increment_rows`` latency tests above."""

    def test_dc_increments_are_strip_bounded_and_release_lock(self):
        daisy = dc_daisy()
        scope = daisy.ledger.scope("t", "pd")
        backlog = len(scope.cold_strips())
        assert backlog >= 8  # a real multi-increment backlog
        cleaner = BackgroundCleaner(daisy, increment_strips=1)
        strip_rows = daisy.ledger.strip_rows
        increments = 0
        while True:
            rep = cleaner.step()
            if rep is None:
                break
            increments += 1
            # bounded: one increment cleans at most one strip of rows
            assert rep.step.answer_size <= strip_rows or rep.step.mode == "full"
            # the lock is free between increments — a foreground ticket
            # waits at most one strip scan, not a full pairwise pass
            assert daisy.lock.acquire(timeout=1.0)
            daisy.lock.release()
        assert increments == backlog
        assert daisy.cold_count("t", "pd") == 0

    def test_dc_drain_yields_between_strips(self):
        """Pending foreground work preempts a DC backlog mid-scope: drain
        stops between strip increments, not after the whole scope."""
        daisy = dc_daisy()
        server = QueryServer(daisy)
        cleaner = BackgroundCleaner(daisy, server=server, increment_strips=1)
        assert cleaner.drain(max_increments=2) == 2
        assert daisy.cold_count("t", "pd") > 0  # mid-scope
        sess = server.open_session("s")
        server.submit(sess, Query("t", preds=(Pred("price", ">=", 0.0),)))
        assert cleaner.preempted()
        assert cleaner.drain() == 0  # yielded with the scope still cold
        assert server.metrics.bg_yields == 1

    def test_dc_latency_bound_under_running_cleaner(self):
        """A DC-touching query submitted while the cleaner thread churns a
        many-strip backlog is answered promptly (within the test timeout,
        i.e. a small multiple of one strip increment — not after a full
        pairwise pass of the whole backlog)."""
        daisy = dc_daisy(n=128)
        server = QueryServer(daisy)
        cleaner = BackgroundCleaner(
            daisy, server=server, increment_strips=1, idle_wait=0.005
        )
        serving = threading.Thread(target=server.run, daemon=True)
        serving.start()
        cleaner.start()
        try:
            sess = server.open_session("s")
            res = server.query(
                sess, Query("t", preds=(Pred("price", ">=", 25.0),)), timeout=60
            )
            assert res.mask is not None
        finally:
            cleaner.stop()
            server.stop()
            serving.join(timeout=30)
        assert not serving.is_alive()


# ----------------------------------------------------------------- the cache
class TestCacheExactness:
    def two_table_db(self):
        db = disjoint_factory()
        db["t2"] = make_relation(
            {"a": np.array([1, 1, 2, 2]), "b": np.array([5, 6, 7, 8])},
            overlay=["a", "b"],
            k=4,
            rules=["ab"],
        )
        return db

    TWO_RULES = {"h": [FD("zc", "zip", "city")], "t2": [FD("ab", "a", "b")]}

    def test_background_bumps_invalidate_exactly_touched_scopes(self):
        daisy = Daisy(self.two_table_db(), self.TWO_RULES,
                      DaisyConfig(use_cost_model=False))
        server = QueryServer(daisy)
        cleaner = BackgroundCleaner(daisy, server=server, increment_rows=4)
        sess = server.open_session("s")
        qa, qb = view(0), Query("t2", preds=(Pred("b", "==", 5),))
        server.submit(sess, qa)
        server.submit(sess, qb)
        server.drain()

        # clean ONLY t2's rule in the background
        assert daisy.clean_scope_increment("t2", "ab") is not None
        server.submit(sess, qa)  # h untouched -> still a hit
        server.submit(sess, qb)  # t2 advanced -> stale, re-executed
        server.drain()
        assert server.cache.stale == 1
        assert [e.cached for e in sess.lineage] == [False, False, True, False]

        # clean h's rule: now qa goes stale exactly once, qb stays cached
        while daisy.clean_scope_increment("h", "zc") is not None:
            pass
        t5 = server.submit(sess, qa)
        t6 = server.submit(sess, qb)
        server.drain()
        assert not t5.cached and t6.cached
        assert server.cache.stale == 2

    def test_no_rule_overlap_never_invalidated(self):
        """A query depending on no rule carries only its table's ``__rows__``
        pseudo-dependency (ingest invalidation, DESIGN.md §12): background
        cleaning bumps rule scopes, never ``__rows__``, so it can never
        evict the entry."""
        daisy = Daisy(self.two_table_db(), self.TWO_RULES,
                      DaisyConfig(use_cost_model=False))
        server = QueryServer(daisy)
        sess = server.open_session("s")
        q = Query("t2", preds=())  # no rule attrs -> only the rows pseudo-dep
        assert rule_deps(q, daisy.rules) == (("t2", TABLE_ROWS_RULE),)
        server.submit(sess, q)
        server.drain()
        BackgroundCleaner(daisy, server=server).drain()
        t = server.submit(sess, q)
        server.drain()
        assert t.cached and server.cache.stale == 0

    def test_equal_vectors_bit_identical_after_background(self):
        """The §10 version contract: with the dependency vector unchanged
        since the entry was stored, a re-execution is bit-identical."""
        daisy = fresh_daisy()
        server = QueryServer(daisy)
        sess = server.open_session("s")
        BackgroundCleaner(daisy, server=server).drain()
        t1 = server.submit(sess, view(2))
        server.drain()
        v = daisy.scope_versions(t1.deps)
        again = daisy.execute(view(2))
        assert daisy.scope_versions(t1.deps) == v
        np.testing.assert_array_equal(
            np.asarray(t1.result.mask), np.asarray(again.mask)
        )


# ------------------------------------------------------------ DC + priority
class TestDCBackground:
    def test_dc_scope_full_cleans_in_one_increment(self, salary_rel, dc_sal_tax):
        daisy = Daisy(
            {"t": salary_rel}, {"t": [dc_sal_tax]},
            DaisyConfig(use_cost_model=False, dc_partitions=4),
        )
        serial = Daisy(
            {"t": salary_rel}, {"t": [dc_sal_tax]},
            DaisyConfig(use_cost_model=False, dc_partitions=4),
        )
        rep = daisy.clean_scope_increment("t", "dc_sal_tax")
        assert rep is not None and rep.mode == "full"
        assert daisy.cold_count("t", "dc_sal_tax") == 0
        d0 = daisy.detect_calls
        q = Query("t", preds=(Pred("salary", ">=", 0.0),))
        got = daisy.execute(q)
        assert got.report.steps[0].mode == "skipped"
        assert daisy.detect_calls == d0
        # serial reference full-cleans via the cost-model switch path
        serial.execute(Query("t", preds=(Pred("salary", ">=", 0.0),)))
        np.testing.assert_array_equal(
            np.asarray(got.mask), np.asarray(serial.execute(q).mask)
        )


class TestPriorityModel:
    def test_touch_probability_orders_scopes(self):
        daisy = Daisy(
            TestCacheExactness().two_table_db(), TestCacheExactness.TWO_RULES,
            DaisyConfig(use_cost_model=False),
        )
        server = QueryServer(daisy)
        cleaner = BackgroundCleaner(daisy, server=server)
        sess = server.open_session("s")
        for _ in range(5):  # demand concentrates on t2's rule
            server.submit(sess, Query("t2", preds=(Pred("b", "==", 5),)))
        server.drain()
        scopes = cleaner.cold_scopes()
        assert [s.table for s in scopes][0] == "t2" or (
            # expected_pairs can outweigh touches; assert the touch signal
            # itself is right instead of the blend
            cleaner.rule_touches()[("t2", "ab")] == 5
        )
        touches = cleaner.rule_touches()
        assert touches == {("t2", "ab"): 5}

    def test_prioritize_scopes_deterministic_and_cold_only(self):
        a = ScopePriority("t", "r1", cold_rows=10, expected_pairs=100.0,
                          touch_probability=0.5)
        b = ScopePriority("t", "r2", cold_rows=10, expected_pairs=100.0,
                          touch_probability=0.5)
        warm = ScopePriority("t", "r0", cold_rows=0, expected_pairs=1e9,
                             touch_probability=1.0)
        hot = ScopePriority("u", "r3", cold_rows=5, expected_pairs=100.0,
                            touch_probability=0.9)
        out = prioritize_scopes([b, warm, hot, a])
        assert [s.rule for s in out] == ["r3", "r1", "r2"]

    def test_sharded_pricing_feeds_df_effective(self):
        class Info:
            n_shards = 4
            per_shard_rows = [2, 2, 2, 2]
            routed_rows = 8
            retries = 1
            sharded_pairs = 16

        cost = sharded_detect_cost(Info(), n_rows=100)
        # uniform at n=100 over 4 shards: 4*25^2 = 2500, no skew, 2 shuffles
        assert cost == 2500 + 2 * 100
        cm = CostModel(n=100, epsilon=10, p=2.0, df=10_000.0)
        assert cm.df_effective == 10_000.0
        cm.observe_detect_cost(cost)
        assert cm.df_effective == cost
        cm.observe_detect_cost(cost * 2)  # never regresses to a worse observation
        assert cm.df_effective == cost


# ------------------------------------------------------------------- metrics
def test_snapshot_background_attribution_serializable():
    daisy = fresh_daisy()
    server = QueryServer(daisy)
    cleaner = BackgroundCleaner(daisy, server=server, increment_rows=PER)
    sess = server.open_session("s")
    server.submit(sess, view(0))
    assert cleaner.drain() == 0  # yield counted
    server.drain()
    cleaner.drain()
    snap = server.snapshot()
    json.dumps(snap)
    assert snap["background"]["yields"] == 1
    assert snap["background"]["increments"] >= 1
    assert snap["background"]["scopes_completed"] == 1
    assert snap["background"]["detect_calls"] > 0
    assert snap["foreground"]["detect_calls"] == snap["detect_calls"]
    assert (
        snap["detect_calls"] + snap["background"]["detect_calls"]
        == daisy.detect_calls
    )
    assert 0.0 <= snap["idle_fraction"] <= 1.0
