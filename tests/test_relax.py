"""Query result relaxation — paper §4.1, Algorithm 1, Examples 2 & 3.

The fixture rows (Table 2a):
    0: 9001  LA    1: 9001 SF    2: 9001 LA    3: 10001 SF    4: 10001 NY
"""

import jax.numpy as jnp
import numpy as np

from repro.core.relax import default_max_iters, lemma2_prob, lemma3_upper_bound, relax_fd
from tests.conftest import LA, SF


def mask_of(rel, rows):
    m = np.zeros(rel.capacity, bool)
    m[list(rows)] = True
    return jnp.asarray(m)


class TestExample2RhsFilter:
    """Query: City == 'Los Angeles' (a filter on the FD's rhs)."""

    def test_lemma1_one_round_lhs_expansion(self, cities_rel, fd_zip_city):
        """Lemma 1: with the rhs expansion disabled (the planner's Lemma-1
        path), one round adds exactly the lhs-sharing tuple {9001, SF}."""
        answer = mask_of(cities_rel, [0, 2])
        res = relax_fd(cities_rel, answer, fd_zip_city, use_rhs=False)
        np.testing.assert_array_equal(
            np.asarray(res.extra), [False, True, False, False, False]
        )
        assert bool(res.converged)
        # one productive round + one round to observe the fixpoint
        assert int(res.iterations) <= 2

    def test_full_closure_reaches_rhs_cluster(self, cities_rel, fd_zip_city):
        """Full transitive closure (the default; see planner.py for why):
        row 1's SF links row 3, whose 10001 links row 4 — the whole
        correlated cluster of Example 3 / Table 3."""
        answer = mask_of(cities_rel, [0, 2])
        res = relax_fd(cities_rel, answer, fd_zip_city, use_rhs=True)
        np.testing.assert_array_equal(
            np.asarray(res.extra), [False, True, False, True, True]
        )
        assert bool(res.converged)


class TestExample3LhsFilter:
    """Query: Zip == 9001 (a filter on the FD's lhs) — Table 3."""

    def test_transitive_closure(self, cities_rel, fd_zip_city):
        answer = mask_of(cities_rel, [0, 1, 2])
        res = relax_fd(cities_rel, answer, fd_zip_city)
        # iteration 1 adds {10001, SF} (shared rhs), iteration 2 adds
        # {10001, NY} (shared lhs with the newly reached tuple)
        np.testing.assert_array_equal(
            np.asarray(res.extra), [False, False, False, True, True]
        )
        assert bool(res.converged)
        assert int(res.iterations) >= 2

    def test_closure_is_monotone(self, cities_rel, fd_zip_city):
        """A larger answer can only produce a larger reached set."""
        small = mask_of(cities_rel, [0])
        large = mask_of(cities_rel, [0, 3])
        r_small = relax_fd(cities_rel, small, fd_zip_city)
        r_large = relax_fd(cities_rel, large, fd_zip_city)
        reached_small = np.asarray(small | r_small.extra)
        reached_large = np.asarray(large | r_large.extra)
        assert (reached_small <= reached_large).all()


class TestEdgeCases:
    def test_empty_answer(self, cities_rel, fd_zip_city):
        res = relax_fd(cities_rel, mask_of(cities_rel, []), fd_zip_city)
        assert not np.asarray(res.extra).any()
        assert bool(res.converged)

    def test_full_answer_adds_nothing(self, cities_rel, fd_zip_city):
        res = relax_fd(cities_rel, cities_rel.valid, fd_zip_city)
        assert not np.asarray(res.extra).any()

    def test_invalid_rows_never_reached(self, fd_zip_city):
        from repro.core.relation import make_relation

        rel = make_relation(
            {"zip": np.array([1, 1, 1]), "city": np.array([LA, SF, LA])},
            capacity=8,
            overlay=["zip", "city"],
        )
        res = relax_fd(rel, mask_of(rel, [0]), fd_zip_city)
        assert not np.asarray(res.extra)[3:].any()

    def test_clean_data_no_extra_from_distinct_groups(self, fd_zip_city):
        from repro.core.relation import make_relation

        rel = make_relation(
            {"zip": np.array([1, 2, 3, 4]), "city": np.array([0, 1, 2, 0])},
            overlay=["zip", "city"],
        )
        # city 0 appears in rows 0 and 3 -> rhs link; zip links none.
        res = relax_fd(rel, mask_of(rel, [0]), fd_zip_city)
        np.testing.assert_array_equal(np.asarray(res.extra), [False, False, False, True])


class TestLemmas:
    def test_lemma2_bounds(self):
        assert lemma2_prob(100, 0, 10) == 0.0
        assert lemma2_prob(100, 5, 0) == 0.0
        assert lemma2_prob(100, 5, 96) == 1.0  # pigeonhole: must contain one
        p = lemma2_prob(1000, 10, 100)
        # 1 - C(990,100)/C(1000,100): about 1 - (0.9)^10
        assert 0.5 < p < 0.7

    def test_lemma2_monotone_in_result_size(self):
        ps = [lemma2_prob(1000, 10, a) for a in (10, 50, 100, 500)]
        assert all(a <= b + 1e-12 for a, b in zip(ps, ps[1:]))

    def test_lemma3_upper_bound(self):
        d = [jnp.array([5.0, 3.0]), jnp.array([4.0])]
        q = [jnp.array([2.0, 1.0]), jnp.array([1.0])]
        # R = (8 - 3) + (4 - 1) = 8
        assert float(lemma3_upper_bound(d, q)) == 8.0

    def test_default_max_iters_logarithmic(self):
        assert default_max_iters(1024) == 12
        assert default_max_iters(2) == 3
