"""In-process unit tests for the distribution layer (no mesh subprocess):

* ``hint`` is an exact no-op on a single device — models can call it
  unconditionally and CPU smoke tests see the same array object.
* ``_PARAM_RULES`` covers every ``abstract_params`` leaf of all 10
  architecture configs, and ``param_specs`` yields full-length specs
  (launch/dryrun.py slices them positionally for optimizer moments).
* spec helpers degrade to fully-replicated on a trivial 1x1 mesh.
* host-side shuffle reference: routing, capacity, and overflow semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.dist.hints import hint
from repro.dist.sharding import (
    batch_specs,
    cache_specs,
    param_specs,
    param_specs_dp_only,
    rule_for,
)
from repro.dist.shuffle import shuffle_by_key_host
from repro.models.params import abstract_params


def _trivial_mesh():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


class TestHintNoop:
    def test_identity_off_mesh(self):
        x = jnp.arange(12.0).reshape(3, 4)
        assert hint(x, "dp", "tp") is x

    def test_identity_under_jit(self):
        @jax.jit
        def f(x):
            return hint(x, "dp", None, "tp") * 2.0

        x = jnp.ones((2, 3, 4))
        np.testing.assert_allclose(np.asarray(f(x)), 2.0)

    def test_identity_on_trivial_mesh(self):
        x = jnp.ones((4, 4))
        with _trivial_mesh():
            assert hint(x, "dp", "tp") is x


class TestParamRulesCoverage:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_every_param_has_rule(self, arch):
        cfg = get_config(arch)
        aparams = abstract_params(cfg)
        leaves = jax.tree_util.tree_flatten_with_path(aparams)[0]
        assert leaves
        for path, leaf in leaves:
            rule = rule_for(path)
            assert rule is not None, (arch, path)
            for entry in rule:
                assert entry in (None, "fsdp", "tp"), (arch, path, entry)

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_specs_full_length_and_replicated_on_one_device(self, arch):
        cfg = get_config(arch)
        aparams = abstract_params(cfg)
        mesh = _trivial_mesh()
        specs = param_specs(aparams, mesh, fsdp=True)
        flat_p = jax.tree_util.tree_leaves(aparams)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            # full-length so dryrun's adafactor vr/vc derivation can slice
            assert len(spec) == leaf.ndim, (arch, leaf.shape, spec)
            # 1x1 mesh: every axis has extent 1 -> nothing to shard
            assert all(e is None for e in spec), (arch, leaf.shape, spec)


class TestSpecHelpersTrivialMesh:
    def test_batch_specs_replicated(self):
        mesh = _trivial_mesh()
        specs = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
        out = batch_specs(specs, mesh)
        assert out["tokens"] == P(None, None)

    def test_cache_specs_scalar_counter(self):
        mesh = _trivial_mesh()
        cache = {"t": jax.ShapeDtypeStruct((), jnp.int32),
                 "block_0": {"k": jax.ShapeDtypeStruct((2, 4, 8, 2, 16),
                                                       jnp.bfloat16)}}
        out = cache_specs(cache, mesh)
        assert out["t"] == P()
        assert len(out["block_0"]["k"]) == 5

    def test_dp_only_no_divisible_dim_replicates(self):
        mesh = _trivial_mesh()
        out = param_specs_dp_only({"w": jax.ShapeDtypeStruct((3, 5), jnp.float32)},
                                  mesh)
        assert len(out["w"]) == 2


class TestShuffleHostReference:
    def test_each_key_on_one_shard(self):
        rng = np.random.default_rng(1)
        keys = rng.integers(0, 17, (4, 24)).astype(np.int32)
        payload = keys[..., None]
        valid = rng.random((4, 24)) < 0.8
        ok, op, ov, osrc, ovf = shuffle_by_key_host(keys, payload, valid, 4)
        assert not ovf
        for key in np.unique(keys[valid]):
            shards = [s for s in range(4) if (ok[s][ov[s]] == key).any()]
            assert shards == [int(key) % 4]
        assert ov.sum() == valid.sum()
        # inverse permutation: every occupied slot points at its source row
        assert (keys.reshape(-1)[osrc[ov]] == ok[ov]).all()
        assert len(set(osrc[ov].tolist())) == int(ov.sum())

    def test_overflow_flagged_and_rows_dropped(self):
        # every row carries the same key -> one shard gets all 32 rows but
        # capacity_factor 0.5 allows only 4
        keys = np.full((4, 8), 3, np.int32)
        payload = keys[..., None]
        valid = np.ones((4, 8), bool)
        ok, op, ov, osrc, ovf = shuffle_by_key_host(keys, payload, valid, 4,
                                                    capacity_factor=0.5)
        assert ovf
        assert ov.sum() == 4 and ov[3].sum() == 4
