"""clean_join — paper §4.4, Example 6 / Table 4.

Cities C: t1=(9001, LA)  t2=(9001, SF)  t3=(10001, SF)     rule phi1: Zip->City
Employee E: (9001, Peter, 23456) (10001, Mary, 12345) (10002, Jon, 12345)
                                                           rule phi2: Phone->Zip
Query: sigma(City=LA)(C) |x|_Zip E.

Expected (Table 4e): 4 qualifying pairs —
  (t1, Peter), (t2, Peter), (t2, Mary), (t2, Jon)
(t2 zip becomes {9001 50%, 10001 50%}; Mary/Jon zips become
{10001 50%, 10002 50%} after phi2, so Jon overlaps t2 at 10001).
"""

import numpy as np

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import JoinClause, Pred, Query
from tests.conftest import LA


def make_engine(join_tables):
    rules = {
        "cities": [FD("phi1", "zip", "city")],
        "employee": [FD("phi2", "phone", "zip")],
    }
    cfg = DaisyConfig(join_capacity=64, use_cost_model=False)
    return Daisy(join_tables, rules, cfg)


def result_pairs(daisy, res):
    li = np.asarray(res.join.rows["cities"])
    ri = np.asarray(res.join.rows["employee"])
    v = np.asarray(res.join.valid)
    return {(int(a), int(b)) for a, b, ok in zip(li, ri, v) if ok}


class TestExample6:
    def test_table4e_pairs(self, join_tables):
        daisy = make_engine(join_tables)
        q = Query(
            table="cities",
            preds=(Pred("city", "==", LA),),
            project=("name", "zip"),
            joins=(JoinClause(right="employee", left_on="zip", right_on="zip"),),
        )
        res = daisy.execute(q)
        assert result_pairs(daisy, res) == {(0, 0), (1, 0), (1, 1), (1, 2)}
        assert not res.report.join_overflow

    def test_table4d_relaxed_select(self, join_tables):
        """After clean_sigma, t2's zip is {9001 50%, 10001 50%} (Table 4d)."""
        daisy = make_engine(join_tables)
        q = Query(
            table="cities",
            preds=(Pred("city", "==", LA),),
            project=("name", "zip"),
            joins=(JoinClause(right="employee", left_on="zip", right_on="zip"),),
        )
        daisy.execute(q)
        rel = daisy.db["cities"]
        probs = np.asarray(rel.probs("zip"))[1]
        vals = np.asarray(rel.cand["zip"])[1]
        got = {int(v): round(float(p), 3) for v, p in zip(vals, probs) if p > 0}
        assert got == {9001: 0.5, 10001: 0.5}

    def test_phi2_repairs_employee(self, join_tables):
        daisy = make_engine(join_tables)
        q = Query(
            table="cities",
            preds=(Pred("city", "==", LA),),
            joins=(JoinClause(right="employee", left_on="zip", right_on="zip"),),
        )
        daisy.execute(q)
        rel = daisy.db["employee"]
        for row in (1, 2):  # Mary, Jon
            probs = np.asarray(rel.probs("zip"))[row]
            vals = np.asarray(rel.cand["zip"])[row]
            got = {int(v): round(float(p), 3) for v, p in zip(vals, probs) if p > 0}
            assert got == {10001: 0.5, 10002: 0.5}

    def test_lemma5_no_new_violations(self, join_tables):
        """Def 3(d) re-check: the stitched result contains no unchecked
        violations (Lemma 5)."""
        daisy = make_engine(join_tables)
        q = Query(
            table="cities",
            preds=(Pred("city", "==", LA),),
            joins=(JoinClause(right="employee", left_on="zip", right_on="zip"),),
        )
        res = daisy.execute(q)
        assert res.report.recheck_violations == 0

    def test_join_groupby(self, join_tables):
        daisy = make_engine(join_tables)
        q = Query(
            table="cities",
            preds=(Pred("city", "==", LA),),
            joins=(JoinClause(right="employee", left_on="zip", right_on="zip"),),
            groupby=__import__("repro.core.operators", fromlist=["GroupBySpec"]).GroupBySpec(
                keys=("name",), agg="count", table="employee"
            ),
        )
        res = daisy.execute(q)
        counts = np.asarray(res.groups["count"])
        keys = np.asarray(res.groups["key_name"])
        got = {int(k): float(c) for k, c in zip(keys, counts) if c > 0}
        # Peter appears in 2 pairs, Mary and Jon in 1 each
        assert got == {0: 2.0, 1: 1.0, 2: 1.0}
