"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container this repo develops in has no hypothesis wheel and cannot
install one; CI installs the real package, so this shim only activates as
a fallback (see conftest.py).  It implements exactly the surface the test
suite uses — ``given`` / ``settings`` / ``strategies.{integers, lists,
booleans, sampled_from, composite}`` — by running each property ``max_examples`` times
against seeded-random draws.  No shrinking, no database: failures report
the drawn values via the assertion itself.
"""

from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng):
        return self._draw_fn(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw)


def composite(fn):
    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def draw(rng):
            return fn(lambda s: s.draw(rng), *args, **kwargs)

        return _Strategy(draw)

    return builder


def settings(**kwargs):
    def deco(fn):
        fn._fallback_settings = kwargs
        return fn

    return deco


def given(*strategies_):
    def deco(fn):
        max_examples = getattr(fn, "_fallback_settings", {}).get(
            "max_examples", 20
        )

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            for example in range(max_examples):
                rng = random.Random(f"{fn.__qualname__}:{example}")
                drawn = [s.draw(rng) for s in strategies_]
                fn(*args, *drawn, **kwargs)

        # hide the drawn parameters from pytest's fixture resolution: the
        # last len(strategies_) positional params are strategy-filled
        params = list(inspect.signature(fn).parameters.values())
        wrapper.__signature__ = inspect.Signature(
            params[: len(params) - len(strategies_)]
        )
        del wrapper.__wrapped__  # pytest would unwrap to the raw signature
        return wrapper

    return deco


def build_module():
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.lists = lists
    st.composite = composite
    mod.strategies = st
    mod.__is_fallback__ = True
    return mod, st
