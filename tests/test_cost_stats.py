"""Cost model (Inequality 1, §5.2) and Algorithm 2 statistics."""

import numpy as np

from repro.core.constraints import DC, FD, Atom
from repro.core.cost import CostModel
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.core.stats import algorithm2_decide, dc_stats, fd_stats


class TestCostModel:
    def test_single_full_query_equals_offline(self):
        """The paper's sanity check: q=1 accessing the whole dataset makes
        the two sides equal (eps*n <= eps*n)."""
        cm = CostModel(n=100, epsilon=10, p=2.0, df=100.0, expected_queries=1)
        cm.record(q_i=100, e_i=0, d_i=100.0, eps_i=10)
        assert not cm.should_switch_to_full()  # no remaining queries

    def test_no_switch_with_cheap_queries(self):
        """Few errors, nearly all already repaired: future updates are empty
        deltas, so continuing incrementally beats a full-clean switch."""
        cm = CostModel(n=10_000, epsilon=10, p=2.0, df=10_000.0, expected_queries=50)
        for _ in range(5):
            cm.record(q_i=200, e_i=5, d_i=205.0, eps_i=2)
        assert not cm.should_switch_to_full()

    def test_switch_with_expensive_updates(self):
        """Fig. 9's regime: large candidate sets (p) make the per-query
        update dominate, so the model flips to full cleaning."""
        cm = CostModel(n=10_000, epsilon=5_000, p=200.0, df=10_000.0, expected_queries=90)
        for _ in range(10):
            cm.record(q_i=100, e_i=2_000, d_i=2_100.0, eps_i=400)
        assert cm.should_switch_to_full()

    def test_switch_only_once(self):
        cm = CostModel(n=1_000, epsilon=900, p=50.0, df=1_000.0, expected_queries=50)
        for _ in range(5):
            cm.record(q_i=10, e_i=900, d_i=910.0, eps_i=150)
        if cm.should_switch_to_full():
            cm.mark_switched()
            assert not cm.should_switch_to_full()

    def test_incremental_cost_decreases_with_coverage(self):
        """Relaxation cost shrinks as queries cover the dataset (n - sum q_j)."""
        cm = CostModel(n=1_000, epsilon=10, p=2.0, df=1_000.0, expected_queries=10)
        c1 = cm.incremental_query_cost(q_i=100, e_i=0, d_i=100.0, eps_i=0)
        cm.record(q_i=500, e_i=0, d_i=500.0, eps_i=5)
        c2 = cm.incremental_query_cost(q_i=100, e_i=0, d_i=100.0, eps_i=0)
        assert c2 < c1


class TestFDStats:
    def test_dirty_rows_and_epsilon(self):
        rel = make_relation(
            {"a": np.array([1, 1, 2, 2, 3]), "b": np.array([5, 6, 7, 7, 9])},
            overlay=["a", "b"],
        )
        st = fd_stats(rel, FD("r", "a", "b"))
        np.testing.assert_array_equal(st.dirty_row, [True, True, False, False, False])
        assert st.epsilon == 2
        assert st.p_est == 2.0


class TestAlgorithm2:
    def _stats(self):
        rng = np.random.default_rng(0)
        sal = rng.uniform(1000, 5000, 256).astype(np.float32)
        tax = rng.uniform(0.1, 0.5, 256).astype(np.float32)
        rel = make_relation({"salary": sal, "tax": tax}, overlay=["salary", "tax"])
        dc = DC("d", [Atom("salary", "<", "salary"), Atom("tax", ">", "tax")])
        return dc_stats(rel, dc, p=16), sal

    def test_estimate_errors_positive_for_random_data(self):
        st, _ = self._stats()
        # random (salary, tax) pairs produce inversions in most partitions
        assert st.range_vio.sum() > 0
        assert len(st.part_rows) == 16
        assert st.part_rows.sum() == 256

    def test_decision_narrow_query_high_accuracy(self):
        st, sal = self._stats()
        vals = sal[(sal >= 1000) & (sal <= 1100)]
        dec = algorithm2_decide(st, vals, len(vals), 0.0, threshold=0.001)
        assert 0 <= dec.accuracy <= 1
        assert not dec.full_clean  # tiny threshold -> stay partial

    def test_decision_low_accuracy_forces_full(self):
        st, sal = self._stats()
        vals = sal[:5]
        dec = algorithm2_decide(st, vals, 5, 0.0, threshold=0.999)
        # with a tiny answer and many estimated external errors, accuracy
        # falls below the (extreme) threshold -> full cleaning (Fig. 12)
        assert dec.full_clean

    def test_support_is_the_ledger_coverage_fraction(self):
        """Since the work ledger (DESIGN.md §11) the caller passes its
        strip-coverage fraction straight through (clamped to [0, 1])."""
        st, sal = self._stats()
        d0 = algorithm2_decide(st, sal[:10], 10, 0.0, 0.5)
        d1 = algorithm2_decide(st, sal[:10], 10, 0.5, 0.5)
        d2 = algorithm2_decide(st, sal[:10], 10, 7.0, 0.5)
        assert d1.support > d0.support
        assert d1.support == 0.5
        assert d2.support == 1.0


class TestCostModelIntegration:
    def test_executor_switches_strategy(self):
        """A workload with huge candidate sets triggers the mid-workload
        switch (Fig. 9): later queries run in mode 'full' and afterwards the
        whole relation is checked."""
        rng = np.random.default_rng(1)
        n = 512
        # 128 disjoint dirty groups of 4 rows; b ranges don't overlap across
        # groups, so each query's closure stays inside its group and errors
        # keep arriving query after query (sustained update cost -> switch)
        a = (np.arange(n) // 4).astype(np.int32)
        b = (a * 100 + rng.integers(0, 90, n)).astype(np.int32)
        rel = make_relation({"a": a, "b": b}, overlay=["a", "b"], k=8, rules=["r"])
        daisy = Daisy(
            {"t": rel},
            {"t": [FD("r", "a", "b")]},
            DaisyConfig(use_cost_model=True, expected_queries=40, k=8),
        )
        modes = []
        for i in range(12):
            res = daisy.execute(Query("t", preds=(Pred("a", "==", i),)))
            modes.append(res.report.steps[0].mode)
        assert "full" in modes, modes
        # after the switch everything is checked -> later steps skip/no-op
        from repro.core.update import unchecked

        assert int(np.asarray(unchecked(daisy.db["t"], "r")).sum()) == 0
