"""The work ledger and strip semantics (DESIGN.md §11).

The load-bearing property: cleaning a scope as a union of partition-strip
increments leaves the relation row-for-row identical to one full pass —
for DCs (strip x rest scans through the strip-scoped kernel entry) and
FDs (whole-lhs-group sweeps).  That identity is what makes background
strip increments, foreground partial-work reuse and the serial reference
interchangeable, so it is property-tested over random relations and strip
schedules, not just spot-checked.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.constraints import DC, FD, Atom
from repro.core.executor import Daisy, DaisyConfig
from repro.core.ledger import StripLedger, WorkLedger, resolve_strip_rows
from repro.core.operators import Pred, Query
from repro.core.planner import strip_step
from repro.core.relation import make_relation
from repro.kernels import ops as kops

SETTINGS = dict(max_examples=15, deadline=None)


def dc_relation(n: int, seed: int):
    rng = np.random.default_rng(seed)
    price = rng.uniform(0.0, 50.0, n).astype(np.float32)
    disc = (50.0 - price + rng.normal(0, 4.0, n)).astype(np.float32)
    return make_relation(
        {"price": price, "disc": disc}, overlay=["price", "disc"],
        k=8, rules=["pd"],
    )


DC_PD = DC("pd", [Atom("price", "<", "price"), Atom("disc", ">", "disc")])


def fd_relation(n: int, seed: int, groups: int = 6):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, groups, n).astype(np.int32)
    b = (a * 10 + rng.integers(0, 3, n)).astype(np.int32)
    return make_relation({"a": a, "b": b}, overlay=["a", "b"], k=8, rules=["r"])


FD_AB = FD("r", "a", "b")


def dc_daisy(n: int, seed: int, block: int = 8):
    return Daisy(
        {"t": dc_relation(n, seed)}, {"t": [DC_PD]},
        DaisyConfig(use_cost_model=False, dc_block=block, strip_rows=block,
                    dc_partitions=4),
    )


def assert_same_state(a: Daisy, b: Daisy, table: str, attrs):
    for attr in attrs:
        np.testing.assert_array_equal(
            np.asarray(a.db[table].cand[attr]), np.asarray(b.db[table].cand[attr])
        )
        np.testing.assert_array_equal(
            np.asarray(a.db[table].ccount[attr]),
            np.asarray(b.db[table].ccount[attr]),
        )
    for rule, checked in a.db[table].checked.items():
        np.testing.assert_array_equal(
            np.asarray(checked), np.asarray(b.db[table].checked[rule])
        )


# ----------------------------------------------------- strip-union property
class TestStripUnionIdentity:
    @given(st.integers(10, 60), st.integers(0, 10**6), st.integers(1, 3))
    @settings(**SETTINGS)
    def test_dc_strip_union_equals_full_pass(self, n, seed, per_call):
        """Union of bounded DC strip increments == one full pass, row for
        row (candidates, counts, checked bits) — any strip batch size."""
        inc = dc_daisy(n, seed)
        full = dc_daisy(n, seed)
        steps = 0
        while inc.clean_scope_increment("t", "pd", max_strips=per_call):
            steps += 1
            assert steps < 100
        assert full.clean_scope_increment("t", "pd") is not None
        assert inc.cold_count("t", "pd") == 0
        assert_same_state(inc, full, "t", ("price", "disc"))

    @given(st.integers(12, 60), st.integers(0, 10**6), st.integers(4, 16))
    @settings(**SETTINGS)
    def test_fd_increment_union_equals_full_pass(self, n, seed, max_rows):
        """Union of bounded FD group-sweep increments == one unbounded
        sweep (the §11 identity on the FD side)."""
        cfg = lambda: DaisyConfig(use_cost_model=False)  # noqa: E731
        inc = Daisy({"t": fd_relation(n, seed)}, {"t": [FD_AB]}, cfg())
        full = Daisy({"t": fd_relation(n, seed)}, {"t": [FD_AB]}, cfg())
        steps = 0
        while inc.clean_scope_increment("t", "r", max_rows=max_rows):
            steps += 1
            assert steps < 100
        while full.clean_scope_increment("t", "r"):
            pass
        assert inc.cold_count("t", "r") == 0
        assert_same_state(inc, full, "t", ("a", "b"))

    def test_interleaved_query_and_strips_match_serial(self):
        """Strip increments interleaved with a foreground DC query converge
        on the serial reference's state — the §11 ledger-equal argument:
        when every row's evidence is merged exactly once (full-coverage
        scopes; the strip schedule only permutes WHICH pass merges it),
        the final overlay is schedule-independent.  The query spans the
        whole relation so its cleaning step is itself a cold-strip sweep
        (the §4.2 partner strip is empty; answer-overlap partner evidence
        is intentionally out of scope — it repeats per schedule)."""
        n, seed = 48, 3
        inter = dc_daisy(n, seed)
        serial = dc_daisy(n, seed)
        q = Query("t", preds=(Pred("price", ">=", -1.0),))
        inter.clean_scope_increment("t", "pd", max_strips=2)
        a = inter.execute(q)
        b = serial.execute(q)
        np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
        while inter.clean_scope_increment("t", "pd", max_strips=1):
            pass
        while serial.clean_scope_increment("t", "pd"):
            pass
        assert inter.cold_count("t", "pd") == 0
        assert_same_state(inter, serial, "t", ("price", "disc"))


# ------------------------------------------------- strip-scoped kernel entry
class TestStripScopedScan:
    @pytest.mark.parametrize("force", ["ref", "interpret"])
    def test_row_blocks_matches_masked_full_scan(self, force):
        rng = np.random.default_rng(0)
        n, block = 40, 8
        cols = [rng.integers(0, 9, n).astype(np.int32) for _ in range(2)]
        scope = np.ones(n, bool)
        for lo, hi in ((0, 1), (1, 3), (3, 5), (0, 5)):
            strip_mask = np.zeros(n, bool)
            strip_mask[lo * block : hi * block] = True
            want_c, want_s = kops.dc_role_scan(
                [cols[0]], [cols[0]], ["<"],
                scope & strip_mask, scope, ["max"], block=block, force=force,
            )
            got_c, got_s = kops.dc_role_scan(
                [cols[0]], [cols[0]], ["<"],
                scope & strip_mask, scope, ["max"], block=block, force=force,
                row_blocks=(lo, hi),
            )
            np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
            np.testing.assert_array_equal(
                np.asarray(got_s[0]), np.asarray(want_s[0])
            )

    def test_row_blocks_validation(self):
        rng = np.random.default_rng(1)
        col = rng.integers(0, 5, 16).astype(np.int32)
        scope = np.ones(16, bool)
        with pytest.raises(ValueError):
            kops.dc_role_scan(
                [col], [col], ["<"], scope, scope, ["max"], block=8,
                force="ref", row_blocks=(1, 5),
            )


# --------------------------------------------------------------- the ledger
class TestWorkLedger:
    def test_resolve_strip_rows_alignment(self):
        assert resolve_strip_rows(None, 256) == 256
        assert resolve_strip_rows(300, 256) == 512
        assert resolve_strip_rows(8, 8) == 8
        with pytest.raises(ValueError):
            resolve_strip_rows(-4, 8)

    def test_strip_geometry_and_coverage(self):
        scope = StripLedger("t", "r", capacity=40, strip_rows=8)
        assert scope.n_strips == 5
        cold = np.zeros(40, bool)
        cold[3] = cold[17] = True
        scope.observe_cold(cold)
        assert list(scope.cold_strips()) == [0, 2]
        assert scope.cold_count == 2
        assert scope.strips_done == 3
        assert scope.support == pytest.approx(0.6)
        mask = scope.strip_mask([0, 2])
        assert mask[:8].all() and mask[16:24].all() and not mask[8:16].any()
        assert scope.strip_blocks([2], block=8) == (2, 3)
        assert scope.strip_blocks([0, 2], block=4) == (0, 6)

    def test_versions_and_progress(self):
        ledger = WorkLedger(strip_rows=8, block=8)
        ledger.register("t", "r", 16, np.ones(16, bool))
        assert ledger.version("t", "r") == 0
        assert ledger.versions([("t", "r"), ("u", "x")]) == (0, 0)
        ledger.bump("t", "r")
        ledger.commit("t", "r", np.zeros(16, bool))
        assert ledger.version("t", "r") == 2
        prog = ledger.progress()
        assert prog == {
            "t/r": {
                "strips_done": 2,
                "strips_total": 2,
                "cold_rows": 0,
                "tiles_launched": 0,
                "tiles_skipped": 0,
            }
        }
        assert ledger.support("t", "r") == 1.0
        assert ledger.support("nope", "x") == 1.0  # unknown scopes read warm

    def test_daisy_ledger_tracks_checked_commits(self):
        daisy = dc_daisy(32, seed=9)
        scope = daisy.ledger.scope("t", "pd")
        assert scope.cold_count == 32 and scope.strips_done == scope.n_strips - 4
        v0 = daisy.scope_version("t", "pd")
        rep = daisy.clean_scope_increment("t", "pd", max_strips=1)
        assert rep.mode == "strip"
        assert daisy.scope_version("t", "pd") > v0
        assert scope.cold_count == 24
        assert len(scope.cold_strips()) == 3

    def test_bump_then_grow_seeds_all_cold(self):
        """A scope first seen through a bare version bump (capacity 0) and
        later grown without a cold mask must read ALL-COLD — a warm-seeded
        unknown scope would skip every clean forever."""
        ledger = WorkLedger(strip_rows=8, block=8)
        ledger.bump("t", "r")
        scope = ledger.register("t", "r", 32)
        assert scope.version == 1  # the bump survived the growth
        assert scope.cold_count == 32
        assert scope.support == 0.0
        assert list(scope.cold_strips()) == [0, 1, 2, 3]

    def test_dc_rule_added_to_live_daisy_stays_cleanable(self):
        """The table5 dynamic-rule pattern, DC edition: a rule appended to
        a running Daisy (ledger scope created lazily) must still clean —
        its first full step may not resolve to an empty strip set."""
        daisy = dc_daisy(32, seed=9)
        daisy.rules["t"].append(
            DC("pd2", [Atom("disc", "<", "disc"), Atom("price", ">", "price")])
        )
        daisy._collect_stats()
        rep = daisy.clean_scope_increment("t", "pd2")
        assert rep is not None and rep.mode == "full"
        assert daisy.cold_count("t", "pd2") == 0
        q = Query("t", preds=(Pred("disc", ">=", 0.0),))
        assert daisy.execute(q).report.steps[1].mode == "skipped"

    def test_planner_strip_step_carries_strips(self):
        step = strip_step("t", DC_PD, np.array([1, 3]))
        assert step.mode == "strip" and step.strips == (1, 3)

    def test_foreground_full_skips_background_strips(self):
        """Partial-work reuse: the detect-pair cost of a full clean shrinks
        strictly with background strip progress (the ledger gate)."""
        cold = dc_daisy(64, seed=4)
        half = dc_daisy(64, seed=4)
        for _ in range(4):
            assert half.clean_scope_increment("t", "pd", max_strips=1)
        q = Query("t", preds=(Pred("price", ">=", 0.0),))
        cold.config.accuracy_threshold = 2.0  # force full cleaning
        half.config.accuracy_threshold = 2.0
        p0 = cold.detect_pairs
        mask_cold = np.asarray(cold.execute(q).mask)
        cold_pairs = cold.detect_pairs - p0
        p0 = half.detect_pairs
        mask_half = np.asarray(half.execute(q).mask)
        half_pairs = half.detect_pairs - p0
        assert half_pairs < cold_pairs
        np.testing.assert_array_equal(mask_cold, mask_half)
        assert_same_state(cold, half, "t", ("price", "disc"))
