"""Thread-based concurrency smoke for the query server (DESIGN.md §9).

Eight client threads hammer one server step-loop with overlapping
exploratory queries over a shared Daisy instance.  The check that matters:
NO LOST UPDATES in the candidate overlays — the final probabilistic
instance must carry exactly the candidate distributions a serial
fresh-instance run produces (Lemma 4 makes the merge order irrelevant;
the executor's lock and the checked-bit bookkeeping must make concurrent
scheduling irrelevant too).
"""

import threading

import numpy as np

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import hospital_like
from repro.service import QueryServer

N_ROWS = 128
N_THREADS = 8
QUERIES_PER_THREAD = 6


def build_daisy():
    ds = hospital_like(N_ROWS, error_frac=0.15, seed=11)
    rel = make_relation(ds.data, overlay=["zip", "city"], k=8, rules=["zc"])
    return Daisy(
        {"h": rel}, {"h": [FD("zc", "zip", "city")]},
        DaisyConfig(use_cost_model=False),
    )


def query_pool():
    # hospital_like(128) has 6 zip groups; every thread cycles all of them
    return [Query("h", preds=(Pred("zip", "==", g),)) for g in range(6)]


def candidate_state(rel):
    """Per-row candidate distributions as comparable value->prob maps."""
    state = {}
    for attr in ("zip", "city"):
        vals = np.asarray(rel.cand[attr])
        probs = np.asarray(rel.probs(attr))
        state[attr] = [
            {
                (int(v), round(float(p), 5))
                for v, p in zip(vals[r], probs[r])
                if p > 0
            }
            for r in range(N_ROWS)
        ]
    return state


def test_eight_threads_no_lost_updates():
    daisy = build_daisy()
    server = QueryServer(daisy, max_batch=8)
    pool = query_pool()

    serving = threading.Thread(target=server.run, name="serving")
    serving.start()

    errors = []

    def client(tid: int):
        session = server.open_session(f"user{tid}")
        try:
            for i in range(QUERIES_PER_THREAD):
                q = pool[(tid + i) % len(pool)]
                res = server.query(session, q, timeout=300)
                assert res.mask is not None
        except BaseException as exc:  # propagate to the main thread
            errors.append((tid, exc))

    clients = [
        threading.Thread(target=client, args=(tid,), name=f"client{tid}")
        for tid in range(N_THREADS)
    ]
    for t in clients:
        t.start()
    for t in clients:
        t.join(timeout=600)
    server.stop()
    serving.join(timeout=60)
    assert not serving.is_alive()
    assert not errors, f"client failures: {errors}"

    snap = server.snapshot()
    assert snap["queries"] == N_THREADS * QUERIES_PER_THREAD
    assert snap["errors"] == 0
    # the shared instance advanced monotonically and then froze: every
    # cluster cleaned exactly once, repeats served by skip or cache
    assert 0 < daisy.clean_version
    assert snap["executions"] < snap["queries"]

    # no lost updates: overlays equal a serial fresh-instance run over the
    # distinct queries (merge order is irrelevant by Lemma 4, so ANY
    # concurrent interleaving must land on this exact state)
    serial = build_daisy()
    for q in pool:
        serial.execute(q)
    got = candidate_state(daisy.db["h"])
    want = candidate_state(serial.db["h"])
    for attr in ("zip", "city"):
        for r in range(N_ROWS):
            assert got[attr][r] == want[attr][r], (
                f"{attr} row {r}: {got[attr][r]} != {want[attr][r]}"
            )

    # and the frozen instance keeps the cache contract: equal versions,
    # bit-identical answers
    v = daisy.clean_version
    a1 = np.asarray(daisy.execute(pool[0]).mask)
    a2 = np.asarray(daisy.execute(pool[0]).mask)
    assert daisy.clean_version == v
    np.testing.assert_array_equal(a1, a2)
