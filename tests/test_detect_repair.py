"""FD detection + probabilistic repair — paper §4.1, Example 2 / Table 2b.

Candidate probabilities are frequency-based: P(rhs|lhs) and P(lhs|rhs).
"""

import jax.numpy as jnp
import numpy as np

from repro.core.detect import detect_fd
from repro.core.repair import fd_repair_candidates, repaired_value
from repro.core.update import apply_candidates, mark_checked, unchecked
from tests.conftest import LA, NY, SF


def probs_for(rel, attr, row):
    """{value: prob} for a row's candidate overlay (concrete values only)."""
    vals = np.asarray(rel.cand[attr])[row]
    ps = np.asarray(rel.probs(attr))[row]
    return {int(v): float(p) for v, p in zip(vals, ps) if p > 0}


class TestDetectFD:
    def test_violated_groups(self, cities_rel, fd_zip_city):
        det = detect_fd(cities_rel, fd_zip_city, cities_rel.valid)
        # both zip groups contain two distinct cities
        np.testing.assert_array_equal(
            np.asarray(det.violated), [True, True, True, True, True]
        )
        assert not bool(det.overflow)

    def test_scoped_detection(self, cities_rel, fd_zip_city):
        scope = jnp.asarray(np.array([True, True, True, False, False]))
        det = detect_fd(cities_rel, fd_zip_city, scope)
        np.testing.assert_array_equal(
            np.asarray(det.violated), [True, True, True, False, False]
        )

    def test_rhs_candidate_frequencies(self, cities_rel, fd_zip_city):
        """P(City|Zip=9001) = {LA 2/3, SF 1/3} — Table 2b's 67%/33%."""
        det = detect_fd(cities_rel, fd_zip_city, cities_rel.valid)
        cand = np.asarray(det.rhs_cand)[0]
        count = np.asarray(det.rhs_count)[0]
        got = {int(v): float(c) for v, c in zip(cand, count) if c > 0}
        assert got == {LA: 2.0, SF: 1.0}

    def test_lhs_candidate_frequencies(self, cities_rel, fd_zip_city):
        """P(Zip|City=SF) = {9001 50%, 10001 50%} — Table 2b row 2's pair."""
        det = detect_fd(cities_rel, fd_zip_city, cities_rel.valid)
        cand = np.asarray(det.lhs_cand)[1]  # row 1 = (9001, SF)
        count = np.asarray(det.lhs_count)[1]
        got = {int(v): float(c) for v, c in zip(cand, count) if c > 0}
        assert got == {9001: 1.0, 10001: 1.0}

    def test_clean_relation_no_violations(self, fd_zip_city):
        from repro.core.relation import make_relation

        rel = make_relation(
            {"zip": np.array([1, 1, 2]), "city": np.array([LA, LA, NY])},
            overlay=["zip", "city"],
        )
        det = detect_fd(rel, fd_zip_city, rel.valid)
        assert not np.asarray(det.violated).any()


class TestRepairTable2b:
    def test_probabilistic_update(self, cities_rel, fd_zip_city):
        """After repairing the 9001 cluster the overlay matches Table 2b."""
        scope = jnp.asarray(np.array([True, True, True, False, False]))
        det = detect_fd(cities_rel, fd_zip_city, scope)
        deltas = fd_repair_candidates(cities_rel, fd_zip_city, det, scope)
        rel = apply_candidates(cities_rel, deltas)

        # rows 0..2 City candidates: {LA 67%, SF 33%}
        for row in (0, 1, 2):
            got = probs_for(rel, "city", row)
            assert got.keys() == {LA, SF}
            np.testing.assert_allclose(got[LA], 2 / 3, atol=1e-6)
            np.testing.assert_allclose(got[SF], 1 / 3, atol=1e-6)
        # rows 0..2 Zip candidates: P(Zip|City) within the scope
        got = probs_for(rel, "zip", 1)  # City=SF within scope -> only 9001
        assert got == {9001: 1.0}
        # untouched rows keep empty overlays
        assert not np.asarray(rel.is_uncertain("city"))[3:].any()

    def test_full_scope_matches_table2b_lhs_pair(self, cities_rel, fd_zip_city):
        """With the full closure scope (all 5 rows — see planner.py note),
        row 1's Zip candidates are Table 2b's {9001 50%, 10001 50%}."""
        det = detect_fd(cities_rel, fd_zip_city, cities_rel.valid)
        deltas = fd_repair_candidates(cities_rel, fd_zip_city, det, cities_rel.valid)
        rel = apply_candidates(cities_rel, deltas)
        got = probs_for(rel, "zip", 1)
        assert got.keys() == {9001, 10001}
        np.testing.assert_allclose(got[9001], 0.5, atol=1e-6)
        np.testing.assert_allclose(got[10001], 0.5, atol=1e-6)
        # Table 3's 10001 rows: City candidates {SF 50%, NY 50%}
        got = probs_for(rel, "city", 3)
        assert got.keys() == {SF, NY}
        np.testing.assert_allclose(got[SF], 0.5, atol=1e-6)

    def test_repaired_value_majority(self, cities_rel, fd_zip_city):
        det = detect_fd(cities_rel, fd_zip_city, cities_rel.valid)
        deltas = fd_repair_candidates(cities_rel, fd_zip_city, det, cities_rel.valid)
        rel = apply_candidates(cities_rel, deltas)
        fixed = np.asarray(repaired_value(rel, "city"))
        # majority fix for the 9001 group is LA (2 vs 1)
        assert fixed[1] == LA


class TestCheckedFlags:
    def test_mark_and_query(self, cities_rel):
        scope = jnp.asarray(np.array([True, False, True, False, False]))
        rel = mark_checked(cities_rel, "zip_city", scope)
        np.testing.assert_array_equal(
            np.asarray(unchecked(rel, "zip_city")), [False, True, False, True, True]
        )
        # marking accumulates
        rel = mark_checked(rel, "zip_city", jnp.asarray(np.array([False, True, False, False, False])))
        np.testing.assert_array_equal(
            np.asarray(unchecked(rel, "zip_city")), [False, False, False, True, True]
        )

    def test_unknown_rule_all_unchecked(self, cities_rel):
        np.testing.assert_array_equal(
            np.asarray(unchecked(cities_rel, "nope")), np.asarray(cities_rel.valid)
        )
