"""General-DC detection + range repairs — paper §4.2, Example 4.

phi: forall t1,t2 NOT(t1.salary < t2.salary AND t1.tax > t2.tax)
rows: t1=(1000, 0.1, 31)  t2=(3000, 0.2, 32)  t3=(2000, 0.3, 43)
The only violating ordered pair is (t1=t3, t2=t2row): 2000<3000 and 0.3>0.2.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.constraints import DC, Atom
from repro.core.detect import detect_dc, dc_violation_count
from repro.core.relation import CAND_GT, CAND_LT, CAND_VALUE, make_relation
from repro.core.repair import dc_repair_candidates
from repro.core.update import apply_candidates


class TestDetectDC:
    def test_example4_pair(self, salary_rel, dc_sal_tax):
        det = detect_dc(salary_rel, dc_sal_tax, salary_rel.valid, salary_rel.valid)
        # t3 (row 2) is the only t1-role violator; t2 (row 1) the only t2-role
        np.testing.assert_array_equal(np.asarray(det.t1_count), [0, 0, 1])
        np.testing.assert_array_equal(np.asarray(det.t2_count), [0, 1, 0])
        assert int(dc_violation_count(det)) == 1
        # extremal partner stats feeding the range fixes:
        # t3's partner (role t1, atom salary '<'): max partner salary = 3000
        assert np.asarray(det.t1_stat[0])[2] == 3000.0
        # t3's partner tax (atom '>'): min partner tax = 0.2
        np.testing.assert_allclose(np.asarray(det.t1_stat[1])[2], 0.2)
        # t2row's partner (role t2): min partner salary 2000, max partner tax 0.3
        assert np.asarray(det.t2_stat[0])[1] == 2000.0
        np.testing.assert_allclose(np.asarray(det.t2_stat[1])[1], 0.3)

    def test_row_scope_restricts_t1_role(self, salary_rel, dc_sal_tax):
        scope = jnp.asarray(np.array([True, False, False]))
        det = detect_dc(salary_rel, dc_sal_tax, scope, salary_rel.valid)
        assert int(np.asarray(det.t1_count).sum()) == 0

    def test_self_pair_excluded(self, dc_sal_tax):
        rel = make_relation(
            {
                "salary": np.array([1000.0, 1000.0], dtype=np.float32),
                "tax": np.array([0.3, 0.3], dtype=np.float32),
                "age": np.array([30, 30]),
            },
            overlay=["salary", "tax"],
        )
        det = detect_dc(rel, dc_sal_tax, rel.valid, rel.valid)
        assert int(dc_violation_count(det)) == 0

    def test_three_atom_dc(self):
        """phi2 of Example 4: adds t1.age < t2.age."""
        rel = make_relation(
            {
                "salary": np.array([1000.0, 3000.0, 2000.0], dtype=np.float32),
                "tax": np.array([0.1, 0.2, 0.3], dtype=np.float32),
                "age": np.array([31.0, 32.0, 43.0], dtype=np.float32),
            },
            overlay=["salary", "tax", "age"],
        )
        dc2 = DC(
            "phi2",
            [Atom("salary", "<", "salary"), Atom("age", "<", "age"), Atom("tax", ">", "tax")],
        )
        det = detect_dc(rel, dc2, rel.valid, rel.valid)
        # t3 vs t2: salary 2000<3000 ok, age 43<32 FALSE -> no violation
        assert int(dc_violation_count(det)) == 0


class TestDCRepairExample4:
    def test_candidate_ranges(self, salary_rel, dc_sal_tax):
        det = detect_dc(salary_rel, dc_sal_tax, salary_rel.valid, salary_rel.valid)
        deltas = dc_repair_candidates(salary_rel, dc_sal_tax, det, salary_rel.valid)
        rel = apply_candidates(salary_rel, deltas)

        # --- t2row (row 1) fixes, exactly Example 4's candidates:
        # salary: {3000 (orig) 50%, <2000 50%}
        sv = np.asarray(rel.cand["salary"])[1]
        sc = np.asarray(rel.ccount["salary"])[1]
        sk = np.asarray(rel.ckind["salary"])[1]
        live = {(float(v), int(k)) for v, c, k in zip(sv, sc, sk) if c > 0}
        assert (3000.0, int(CAND_VALUE)) in live
        assert (2000.0, int(CAND_LT)) in live
        p = np.asarray(rel.probs("salary"))[1]
        np.testing.assert_allclose(p[sc > 0], 0.5, atol=1e-6)

        # tax: {0.2 (orig) 50%, >0.3 50%}
        tv = np.asarray(rel.cand["tax"])[1]
        tc = np.asarray(rel.ccount["tax"])[1]
        tk = np.asarray(rel.ckind["tax"])[1]
        live = {(round(float(v), 4), int(k)) for v, c, k in zip(tv, tc, tk) if c > 0}
        assert (0.2, int(CAND_VALUE)) in live
        assert (0.3, int(CAND_GT)) in live

        # --- t3 (row 2) symmetric fixes: salary {2000, >3000}, tax {0.3, <0.2}
        sv = np.asarray(rel.cand["salary"])[2]
        sc = np.asarray(rel.ccount["salary"])[2]
        sk = np.asarray(rel.ckind["salary"])[2]
        live = {(float(v), int(k)) for v, c, k in zip(sv, sc, sk) if c > 0}
        assert (2000.0, int(CAND_VALUE)) in live
        assert (3000.0, int(CAND_GT)) in live

        # --- t1 (row 0) untouched
        assert not np.asarray(rel.is_uncertain("salary"))[0]
        assert not np.asarray(rel.is_uncertain("tax"))[0]

    def test_range_candidates_qualify_filters(self, salary_rel, dc_sal_tax):
        """Possible-world semantics: the (bound, inf) candidate makes a
        range filter qualify (paper §4: a tuple qualifies iff >= 1 candidate
        qualifies)."""
        det = detect_dc(salary_rel, dc_sal_tax, salary_rel.valid, salary_rel.valid)
        deltas = dc_repair_candidates(salary_rel, dc_sal_tax, det, salary_rel.valid)
        rel = apply_candidates(salary_rel, deltas)
        # t2row's tax candidate (0.3, +inf) overlaps tax > 0.5
        m = np.asarray(rel.candidate_matches("tax", ">", 0.5))
        assert m[1] and not m[0]
        # t3's salary candidate (3000, +inf) overlaps salary >= 5000
        m = np.asarray(rel.candidate_matches("salary", ">=", 5000.0))
        assert m[2] and not m[0] and not m[1]
