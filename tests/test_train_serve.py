"""Training substrate + serving engine + cleaning data pipeline tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.params import init_params
from repro.train.checkpoint import (
    latest_step,
    prune_checkpoints,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.optim import OptConfig, apply_updates, init_opt_state, lr_at
from repro.train.steps import make_train_step


def tiny_cfg():
    return get_config("qwen3-4b", reduced=True).canonicalize(tp=1)


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "adamw_bf16", "adafactor"])
    def test_step_reduces_quadratic(self, name):
        params = {"w": jnp.asarray(np.ones(8, np.float32) * 3.0)}
        cfg = OptConfig(name=name, lr=0.1, warmup_steps=0, weight_decay=0.0,
                        total_steps=100)
        state = init_opt_state(params, cfg)
        for _ in range(50):
            grads = {"w": params["w"]}  # d/dw of w^2/2
            params, state, m = apply_updates(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1.5
        assert int(state["step"]) == 50

    def test_grad_clip(self):
        params = {"w": jnp.zeros(4)}
        cfg = OptConfig(grad_clip=1.0, warmup_steps=0)
        state = init_opt_state(params, cfg)
        _, _, metrics = apply_updates(
            params, {"w": jnp.full((4,), 100.0)}, state, cfg
        )
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_lr_schedule(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
        assert float(lr_at(cfg, jnp.int32(0))) == 0.0
        assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(lr_at(cfg, jnp.int32(110))) == pytest.approx(0.1, abs=1e-5)

    def test_microbatched_grads_match_full(self):
        """Accumulated microbatch gradients == full-batch gradients.

        (Comparing post-Adam params would be sign-sensitive near g=0, so we
        compare the gradients themselves.)"""
        import dataclasses

        from repro.models.transformer import loss_fn

        cfg = tiny_cfg()
        cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
        params = init_params(jax.random.key(0), cfg)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16))),
        }
        gfun = jax.grad(
            lambda p, b: loss_fn(p, cfg, b, mamba_chunk=8)[0]
        )
        g_full = gfun(params, batch)
        g_acc = jax.tree.map(jnp.zeros_like, params)
        for i in range(4):
            mb = jax.tree.map(lambda x: x[2 * i : 2 * i + 2], batch)
            g = gfun(params, mb)
            g_acc = jax.tree.map(lambda a, x: a + x / 4, g_acc, g)
        for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-3
            )


class TestGradCompressOptIn:
    def test_step_carries_residual_and_stays_close(self):
        """The grad_compress flag wires the int8 error-feedback all-reduce
        into the train step: ``gerr`` persists through opt_state and the
        compressed step tracks the uncompressed one (ROADMAP wiring)."""
        from jax.sharding import Mesh

        cfg = tiny_cfg()
        opt_cfg = OptConfig(name="adamw", warmup_steps=0)
        params = init_params(jax.random.key(0), cfg)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16))),
        }
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

        opt_c = init_opt_state(params, opt_cfg, grad_compress=True)
        assert "gerr" in opt_c
        with mesh:
            step_c = make_train_step(cfg, opt_cfg, mamba_chunk=8,
                                     grad_compress=True, mesh=mesh)
            p_c, o_c, m_c = jax.jit(step_c)(params, opt_c, batch)
        assert "gerr" in o_c
        # the residual is the quantization error — nonzero for real grads
        assert any(
            float(jnp.abs(e).max()) > 0 for e in jax.tree.leaves(o_c["gerr"])
        )

        opt_u = init_opt_state(params, opt_cfg)
        step_u = make_train_step(cfg, opt_cfg, mamba_chunk=8)
        p_u, o_u, m_u = jax.jit(step_u)(params, opt_u, batch)
        assert "gerr" not in o_u
        assert float(m_c["loss"]) == pytest.approx(float(m_u["loss"]))
        # int8 mean-reduce keeps gradient scale: same-magnitude updates
        d_c = sum(float(jnp.abs(a - b).sum())
                  for a, b in zip(jax.tree.leaves(p_c), jax.tree.leaves(params)))
        d_u = sum(float(jnp.abs(a - b).sum())
                  for a, b in zip(jax.tree.leaves(p_u), jax.tree.leaves(params)))
        assert d_c == pytest.approx(d_u, rel=0.2)

    def test_requires_mesh(self):
        with pytest.raises(ValueError, match="requires a mesh"):
            make_train_step(tiny_cfg(), OptConfig(), grad_compress=True)

    def test_requires_gerr_in_opt_state(self):
        """A plain opt_state (no residual) must fail loudly, not silently
        substitute zeros."""
        from jax.sharding import Mesh

        cfg = tiny_cfg()
        opt_cfg = OptConfig(name="adamw")
        params = init_params(jax.random.key(0), cfg)
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        step = make_train_step(cfg, opt_cfg, mamba_chunk=8,
                               grad_compress=True, mesh=mesh)
        batch = {
            "tokens": jnp.zeros((2, 16), jnp.int32),
            "labels": jnp.zeros((2, 16), jnp.int32),
        }
        with pytest.raises(ValueError, match="gerr"):
            step(params, init_opt_state(params, opt_cfg), batch)


class TestCheckpoint:
    def test_roundtrip_and_latest(self, tmp_path):
        params = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        opt = {"step": jnp.int32(7), "m": {"a": jnp.zeros((2, 3)), "b": {"c": jnp.zeros(4)}}}
        d = str(tmp_path)
        save_checkpoint(d, 7, {"params": params, "opt": opt, "extra": {"x": 1}})
        assert latest_step(d) == 7
        like = jax.tree.map(jnp.zeros_like, {"params": params, "opt": opt})
        state, step = restore_checkpoint(d, like)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(state["params"]["a"]),
                                      np.asarray(params["a"]))
        assert state["extra"] == {"x": 1}

    def test_atomic_overwrite_and_prune(self, tmp_path):
        d = str(tmp_path)
        params = {"a": jnp.ones(2)}
        for s in (1, 2, 3, 4):
            save_checkpoint(d, s, {"params": params})
        prune_checkpoints(d, keep=2)
        names = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert names == ["step_000003", "step_000004"]
        assert latest_step(d) == 4

    def test_restore_missing_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(str(tmp_path), {"params": {}})


class TestServeEngine:
    def test_continuous_batching_completes(self):
        from repro.serve.engine import Request, ServeEngine

        cfg = tiny_cfg()
        params = init_params(jax.random.key(1), cfg)
        engine = ServeEngine(cfg, params, max_batch=2, max_seq=64)
        rng = np.random.default_rng(1)
        reqs = [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                    max_new=6)
            for i in range(5)  # 5 requests through 2 slots
        ]
        for r in reqs:
            engine.submit(r)
        engine.run(max_steps=500)
        assert all(r.done for r in reqs)
        assert all(len(r.out) == 6 for r in reqs)


class TestCleanPipeline:
    def test_batches_and_cleaning_progress(self):
        from repro.core.operators import Pred
        from repro.data.pipeline import CleanDataPipeline, PipelineConfig
        from repro.data.generators import token_metadata_relation
        from repro.core.constraints import FD

        meta = token_metadata_relation(256, error_frac=0.2, seed=9)
        pipe = CleanDataPipeline(
            meta, [FD("sl", "source", "language")],
            PipelineConfig(batch_docs=4, seq_len=32, vocab_size=128),
        )
        batches = list(
            pipe.batches([[Pred("language", "==", lang)] for lang in range(4)], steps=6)
        )
        assert len(batches) == 6
        for b in batches:
            assert b["tokens"].shape == (4, 32)
        prog = pipe.cleaning_progress()
        assert 0 < prog["sl"] <= 1.0

    def test_repairs_recover_dirty_docs(self):
        """Docs whose language label was corrupted become reachable again
        through their candidate values (possible-world qualification)."""
        from repro.core.operators import Pred
        from repro.data.pipeline import CleanDataPipeline, PipelineConfig
        from repro.data.generators import token_metadata_relation
        from repro.core.constraints import FD

        meta = token_metadata_relation(512, error_frac=0.3, seed=3)
        pipe = CleanDataPipeline(
            meta, [FD("sl", "source", "language")],
            PipelineConfig(batch_docs=4, seq_len=16, vocab_size=64),
        )
        total_recovered = 0
        for lang in range(16):
            docs = pipe.request([Pred("language", "==", lang)])
            truth_docs = np.flatnonzero(meta.truth["language"] == lang)
            total_recovered += len(np.intersect1d(docs, truth_docs))
        # after cleaning, most truly-lang-L docs qualify for query L again
        truth_total = sum(
            (meta.truth["language"] == lang).sum() for lang in range(16)
        )
        assert total_recovered / truth_total > 0.9
