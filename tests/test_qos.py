"""Traffic shaping (DESIGN.md §14): WFQ starvation bound, stale-serve
soundness, cancellation, deadline accounting, and the overload stress.

The property that anchors the tier: ``qos.FairQueue``'s documented
starvation bound — a ticket that is its session's ``q``-th pending
ticket at arrival is served after at most ``q * ceil(W / w) + N`` other
tickets, where ``W``/``N`` are the total weight / count of sessions
that ever pushed.  Hypothesis drives adversarial weights and arrival
interleavings against it; no drawn schedule may starve anyone.

The soundness half: an overload shed answer is bit-identical to the
cache's stored entry at the version its ``staleness`` tag names, the
tag equals the version-vector distance exactly, an un-shed answer is
never tagged, and nothing sheds with the policy disabled — freshness
degrades *visibly* before latency does, never silently.

The concurrency half (slow lane, ``-m qos``): sixteen client threads
drive interactive + batch + ingest traffic past the overload depth and
the final overlays must still equal a Lemma-4 serial reference — the
shaping layer reorders and sheds, but never loses an update.
"""

import math
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query, query_fingerprint
from repro.core.relation import make_relation
from repro.data.generators import hospital_like
from repro.service import (
    BackgroundCleaner,
    FairQueue,
    QoSPolicy,
    QueryServer,
    SLOClass,
    Session,
    Ticket,
    batch_tickets,
    rule_deps,
    vector_staleness,
)

pytestmark = pytest.mark.qos


# --------------------------------------------------------------------- helpers
def make_ticket(seq, session, weight=1.0, slo="interactive", kind="query"):
    """A queue-level ticket: FairQueue needs only seq/session/weight/slo."""
    return Ticket(
        seq=seq, session=session, query=None, fingerprint=f"q{seq}",
        slo=slo, weight=float(weight), kind=kind,
    )


def build_server(qos=None, rows=96, max_batch=4, seed=7):
    ds = hospital_like(rows, error_frac=0.15, seed=seed)
    rel = make_relation(ds.data, overlay=["zip", "city"], k=8, rules=["zc"])
    daisy = Daisy(
        {"h": rel}, {"h": [FD("zc", "zip", "city")]},
        DaisyConfig(use_cost_model=False),
    )
    return QueryServer(daisy, max_batch=max_batch, qos=qos)


# ----------------------------------------------------- WFQ starvation property
@st.composite
def wfq_case(draw):
    """Adversarial weights + arrival order + pop interleaving."""
    n_sessions = draw(st.integers(min_value=1, max_value=5))
    weights = [
        draw(st.integers(min_value=1, max_value=8)) for _ in range(n_sessions)
    ]
    arrivals = draw(
        st.lists(
            st.integers(min_value=0, max_value=n_sessions - 1),
            min_size=1, max_size=32,
        )
    )
    # one drawn bit per arrival: pop a ticket right after this push?
    pops = [draw(st.booleans()) for _ in arrivals]
    return weights, arrivals, pops


@given(wfq_case())
@settings(max_examples=60)
def test_wfq_starvation_bound(case):
    """delay <= q * ceil(W / w_i) + N for every ticket under every drawn
    schedule (the qos module docstring's bound, popped one at a time —
    the per-pick regime the proof covers)."""
    weights, arrivals, pops = case
    sessions = [
        Session(sid=f"w{i}", max_inflight=10**6) for i in range(len(weights))
    ]
    queue = FairQueue(QoSPolicy())
    tickets, q_at_arrival, pops_at_push, popped = [], [], [], []
    pending_per_session = [0] * len(sessions)

    def pop_one():
        batch, dropped = queue.pop_batch(1)
        assert not dropped
        for t in batch:
            pending_per_session[sessions.index(t.session)] -= 1
            popped.append(t)

    for seq, (j, do_pop) in enumerate(zip(arrivals, pops)):
        t = make_ticket(seq, sessions[j], weight=weights[j])
        pending_per_session[j] += 1
        q_at_arrival.append(pending_per_session[j])
        pops_at_push.append(len(popped))
        tickets.append(t)
        queue.push(t)
        if do_pop:
            pop_one()
    while len(queue):
        pop_one()

    assert len(popped) == len(tickets)  # nothing starved or lost
    pop_pos = {t.seq: i for i, t in enumerate(popped)}
    ever_pushed = set(arrivals)
    W = sum(weights[j] for j in ever_pushed)
    N = len(ever_pushed)
    for t, q, j, pre in zip(tickets, q_at_arrival, arrivals, pops_at_push):
        # tickets served between this ticket's arrival and its own pick —
        # the delay the bound speaks about (pops before its arrival are
        # another ticket's history, not this one's wait)
        before = pop_pos[t.seq] - pre
        bound = q * math.ceil(W / weights[j]) + N
        assert before <= bound, (
            f"ticket {t.seq} (session {j}, weight {weights[j]}, q={q}) "
            f"waited {before} picks > bound {bound} "
            f"(weights={weights}, arrivals={arrivals}, pops={pops})"
        )


def test_fifo_mode_is_arrival_order():
    """policy=None keeps the PR 3 deque behavior bit-for-bit: pops come
    back in arrival order no matter the weights."""
    queue = FairQueue(None)
    s = Session(sid="fifo", max_inflight=100)
    tickets = [make_ticket(i, s, weight=(8.0 if i % 2 else 1.0)) for i in range(9)]
    for t in tickets:
        queue.push(t)
    batch, dropped = queue.pop_batch(100)
    assert not dropped
    assert [t.seq for t in batch] == list(range(9))
    # FIFO mode never stamps virtual-time tags
    assert all(t.start_tag == 0.0 and t.finish_tag == 0.0 for t in tickets)


def test_ingest_barrier_blocks_fair_reordering():
    """A later light-weight ticket must NOT jump an ingest barrier, even
    when its virtual start tag is smaller than every queued tag."""
    queue = FairQueue(QoSPolicy())
    heavy = Session(sid="heavy", max_inflight=100)
    light = Session(sid="light", max_inflight=100)
    pre = [make_ticket(i, heavy, weight=1.0) for i in range(4)]
    for t in pre:
        queue.push(t)
    barrier = make_ticket(4, None, kind="ingest")
    queue.push(barrier)
    late = make_ticket(5, light, weight=8.0)
    queue.push(late)  # start tag 0.0 — smaller than pre[1:]'s tags
    assert late.start_tag < pre[-1].start_tag
    order, _ = queue.pop_batch(100)
    seqs = [t.seq for t in order]
    assert set(seqs[:4]) == {0, 1, 2, 3}  # whole pre-segment first
    assert seqs[4] == 4  # then the barrier
    assert seqs[5] == 5  # the late ticket never crossed it


def test_singleton_cluster_not_deferred_by_batching():
    """Cluster batching composes with fairness without starving an orphan
    cluster: a weight-1 session's lone off-cluster ticket is picked
    within its starvation bound even while three weight-8 sessions flood
    one hot cluster, and same-cluster grouping survives inside batches."""
    rules = {"h": [FD("zc", "zip", "city")]}
    hot = Query("h", preds=(Pred("zip", "==", 0),))
    orphan = Query("h", preds=(Pred("beds", ">=", 400),))  # no rule overlap
    queue = FairQueue(QoSPolicy())
    heavies = [Session(sid=f"h{i}", max_inflight=100) for i in range(3)]
    seq = 0
    tickets = []
    for burst in range(12):
        for s in heavies:
            t = Ticket(
                seq=seq, session=s, query=hot,
                fingerprint=query_fingerprint(hot),
                deps=rule_deps(hot, rules), weight=8.0,
            )
            queue.push(t)
            tickets.append(t)
            seq += 1
    lone = Ticket(
        seq=seq, session=Session(sid="solo", max_inflight=100), query=orphan,
        fingerprint=query_fingerprint(orphan),
        deps=rule_deps(orphan, rules), weight=1.0,
    )
    queue.push(lone)

    # q=1, w=1, W=25, N=4 -> the orphan waits at most 29 picks
    bound = 1 * math.ceil(25 / 1) + 4
    picked = []
    while len(queue):
        batch, _ = queue.pop_batch(8)
        groups = batch_tickets(batch, rules)
        # same-cluster tickets stay grouped: one group per distinct cluster
        assert len(groups) <= 2
        assert sum(len(g) for g in groups) == len(batch)
        picked.extend(batch)
    pos = next(i for i, t in enumerate(picked) if t.seq == lone.seq)
    assert pos <= bound


# -------------------------------------------------------------- vector_staleness
def test_vector_staleness_contract():
    assert vector_staleness(3, 5) == 2
    assert vector_staleness(5, 5) == 0
    assert vector_staleness(5, 3) is None  # non-monotone
    assert vector_staleness((1, 2), (3, 2)) == 2
    assert vector_staleness((1, 2), (1, 2)) == 0
    assert vector_staleness((1, 2), (0, 9)) is None  # component regressed
    assert vector_staleness((1, 2), (1, 2, 3)) is None  # shape changed
    assert vector_staleness((1, 2), 7) is None  # mixed types
    assert vector_staleness(None, (1, 2)) is None


# ------------------------------------------------------- stale-serve soundness
def test_shed_answer_is_tagged_and_bit_identical():
    policy = QoSPolicy(overload_depth=1)
    server = build_server(qos=policy)
    s = server.open_session("u", max_inflight=100)
    qa = Query("h", preds=(Pred("zip", "==", 0),))
    qb = Query("h", preds=(Pred("zip", "==", 1),))

    server.submit(s, qa)
    server.drain()  # qa cached at its post-execution vector
    fp = query_fingerprint(qa)
    stored_version, stored_result = server.cache.peek(fp)
    baseline_mask = np.asarray(stored_result.mask).copy()

    server.submit(s, qb)
    server.drain()  # cleaning qb's cluster advances (h, zc) -> qa entry stale
    deps = rule_deps(qa, server.daisy.rules)
    current = server.daisy.scope_versions(deps)
    expected = vector_staleness(stored_version, current)
    assert expected is not None and expected > 0

    # overload the queue (batch is not sheddable, so these stay queued),
    # then submit the cached interactive fingerprint past the depth
    t1 = server.submit(s, qb, slo="batch")
    t2 = server.submit(s, qb, slo="batch")
    shed = server.submit(s, qa, slo="interactive")

    assert shed.shed and shed.event.is_set()  # answered AT submit
    assert shed.cached
    assert shed.staleness == expected  # tag == exact vector distance
    assert shed.result is stored_result  # the cache entry itself
    np.testing.assert_array_equal(np.asarray(shed.result.mask), baseline_mask)
    # shedding consumed no executor work and the entry was not dropped
    assert server.cache.peek(fp)[0] == stored_version

    server.drain()
    # un-shed answers are NEVER tagged
    for t in (t1, t2):
        assert t.event.is_set() and not t.shed and t.staleness is None

    snap = server.snapshot()
    assert snap["qos"]["shed"] == 1
    assert snap["qos"]["shed_stale"] == 1
    assert snap["qos"]["shed_staleness_total"] == expected
    assert snap["qos"]["by_class"]["interactive"]["shed"] == 1
    assert snap["answered"] == snap["queries"] + 1
    # session accounting balanced: the shed ticket completed its slot
    assert s.snapshot()["inflight"] == 0


def test_no_shed_without_policy_or_depth():
    """Disabled shedding never sheds, whatever the queue depth."""
    for qos in (None, QoSPolicy(overload_depth=0)):
        server = build_server(qos=qos)
        s = server.open_session("u", max_inflight=100)
        qa = Query("h", preds=(Pred("zip", "==", 0),))
        server.submit(s, qa)
        server.drain()  # cached — a shed would have an entry to serve
        tickets = [server.submit(s, qa) for _ in range(6)]
        assert all(not t.shed and t.staleness is None for t in tickets)
        assert all(not t.event.is_set() for t in tickets)  # queued, not answered
        server.drain()
        assert server.snapshot().get("qos", {"shed": 0})["shed"] == 0


def test_uncached_fingerprint_cannot_shed():
    policy = QoSPolicy(overload_depth=1)
    server = build_server(qos=policy)
    s = server.open_session("u", max_inflight=100)
    qa = Query("h", preds=(Pred("zip", "==", 0),))
    qb = Query("h", preds=(Pred("zip", "==", 1),))
    server.submit(s, qa)
    server.submit(s, qa)
    fresh = server.submit(s, qb)  # depth 2 > 1, sheddable class, no entry
    assert not fresh.shed and not fresh.event.is_set()
    server.drain()
    assert fresh.result is not None and fresh.staleness is None


def test_shed_after_ingest_refuses_incomparable_vector():
    """An append changes the dependency vector's __rows__ component; the
    stored entry is then *comparable* (same shape, bumped) — but a shape
    change (e.g. a new rule) must refuse.  Exercise the monotone-bump
    path end-to-end and the refusal unit-level."""
    policy = QoSPolicy(overload_depth=1)
    server = build_server(qos=policy)
    s = server.open_session("u", max_inflight=100)
    qa = Query("h", preds=(Pred("zip", "==", 0),))
    server.submit(s, qa)
    server.drain()
    fp = query_fingerprint(qa)
    stored_version, _ = server.cache.peek(fp)
    # stream an append: bumps (h, __rows__) inside qa's dependency vector
    rows = {
        k: np.asarray(v[:2]).copy()
        for k, v in hospital_like(8, error_frac=0.0, seed=1).data.items()
    }
    server.ingest("h", rows)
    server.drain()
    current = server.daisy.scope_versions(rule_deps(qa, server.daisy.rules))
    assert vector_staleness(stored_version, current) >= 1
    server.submit(s, qa, slo="batch")
    server.submit(s, qa, slo="batch")
    shed = server.submit(s, qa)
    assert shed.shed and shed.staleness >= 1
    server.drain()


# ----------------------------------------------------------------- cancellation
def test_timed_out_wait_cancels_no_work_is_done():
    """The abandonment fix: a timed-out wait() cancels the ticket, the
    slot releases immediately, and the server does ZERO detect/repair
    work for it — the regression the PR closes."""
    server = build_server()
    daisy = server.daisy
    s = server.open_session("u", max_inflight=4)
    qa = Query("h", preds=(Pred("zip", "==", 0),))
    t = server.submit(s, qa)
    with pytest.raises(TimeoutError):
        t.wait(timeout=0.02)
    assert t.is_cancelled()
    assert s.snapshot()["inflight"] == 0  # slot released at cancel time
    d0, r0 = daisy.detect_calls, daisy.repair_calls
    assert server.drain() == 0  # discarded at pick, never served
    assert (daisy.detect_calls, daisy.repair_calls) == (d0, r0)
    snap = server.snapshot()
    assert snap["queries"] == 0 and snap["executions"] == 0
    assert snap["qos"]["cancelled"] == 1
    # the session can submit again: no slot leak
    t2 = server.submit(s, qa)
    server.drain()
    assert t2.result is not None


def test_wait_after_serve_still_returns_result():
    """cancel() loses the race once serving finished: wait() returns the
    answer instead of raising."""
    server = build_server()
    s = server.open_session("u", max_inflight=4)
    qa = Query("h", preds=(Pred("zip", "==", 0),))
    t = server.submit(s, qa)
    server.drain()
    assert t.wait(timeout=0.01) is not None  # served; no TimeoutError
    assert not t.is_cancelled()


def test_cancelled_ticket_honored_at_serve_time():
    """A ticket cancelled after the pick (begin_serve race) is skipped
    without executor work — the serve-time half of the fix."""
    server = build_server()
    s = server.open_session("u", max_inflight=4)
    qa = Query("h", preds=(Pred("zip", "==", 0),))
    t = server.submit(s, qa)
    assert t.cancel()
    assert not t.begin_serve()  # the serving thread's claim must fail
    server.drain()
    assert t.result is None and not t.event.is_set()


# ------------------------------------------------------------ deadline + budget
def test_deadline_miss_accounting_and_class_latency():
    server = build_server(qos=QoSPolicy())
    s = server.open_session("u", max_inflight=4)
    qa = Query("h", preds=(Pred("zip", "==", 0),))
    server.submit(s, qa, deadline=0.0)  # already past when served
    server.submit(s, qa, slo="batch", deadline=60.0)  # comfortably met
    server.drain()
    snap = server.snapshot()
    assert snap["qos"]["deadline_misses"] == 1
    assert snap["qos"]["by_class"]["interactive"]["deadline_misses"] == 1
    assert "batch" not in snap["qos"]["by_class"] or (
        "deadline_misses" not in snap["qos"]["by_class"]["batch"]
    )
    # per-class latency histograms appear under a policy
    assert snap["latency"]["interactive"]["count"] == 1
    assert snap["latency"]["batch"]["count"] == 1


def test_unknown_slo_class_is_a_submit_error():
    server = build_server(qos=QoSPolicy())
    s = server.open_session("u", max_inflight=4)
    qa = Query("h", preds=(Pred("zip", "==", 0),))
    with pytest.raises(KeyError):
        server.submit(s, qa, slo="platinum")
    assert s.snapshot()["inflight"] == 0  # refused before admission


def test_per_class_session_limits():
    server = build_server(qos=QoSPolicy())
    s = server.open_session("u", max_inflight=10, class_limits={"batch": 1})
    qa = Query("h", preds=(Pred("zip", "==", 0),))
    server.submit(s, qa, slo="batch")
    from repro.service import SessionLimitError

    with pytest.raises(SessionLimitError):
        server.submit(s, qa, slo="batch")
    server.submit(s, qa, slo="interactive")  # other classes unaffected
    server.drain()
    server.submit(s, qa, slo="batch")  # slot came back after completion
    server.drain()


def test_cleaner_budget_control_loop():
    """Policy-level budget arithmetic plus the cleaner integration: an
    interactive arrival inside the quiet window shrinks the next
    increment; a quiet queue restores the configured base."""
    policy = QoSPolicy()
    now = time.perf_counter()
    # allowance: tightest target among recently-active classes
    assert policy.latency_allowance(now, {}) is None
    assert policy.latency_allowance(now, {"interactive": now - 0.01}) == 0.1
    assert policy.latency_allowance(now, {"batch": now - 0.01}) == 2.0
    assert (
        policy.latency_allowance(
            now, {"interactive": now - 0.01, "batch": now - 0.01}
        )
        == 0.1
    )
    assert policy.latency_allowance(now, {"interactive": now - 10.0}) is None
    # budget: no allowance -> base; no estimate -> minimal first bite;
    # slow estimate -> shrink by allowance/estimate; fast -> back to base
    assert policy.cleaner_budget(None, 1.0, 512, 4) == (512, 4)
    assert policy.cleaner_budget(0.1, None, 512, 4) == (128, 1)
    assert policy.cleaner_budget(0.1, 1.0, 512, 4) == (128, 1)
    assert policy.cleaner_budget(10.0, 0.01, 512, 4) == (512, 4)
    floor = policy.min_increment_rows
    assert policy.cleaner_budget(0.001, 1.0, 64, 1) == (min(64, floor), 1)

    server = build_server(qos=policy)
    cleaner = BackgroundCleaner(
        server.daisy, server=server, increment_rows=512, increment_strips=4
    )
    assert cleaner.policy is policy  # wired from the server's qos
    assert cleaner.budget() == (512, 4)  # nothing arrived yet
    s = server.open_session("u", max_inflight=4)
    cleaner._inc_ewma = 1.0  # pretend increments take 1s vs the 0.1s target
    server.submit(s, Query("h", preds=(Pred("zip", "==", 0),)))
    rows, strips = cleaner.budget()  # the arrival is inside quiet_s right now
    assert rows == 128 and strips == 1
    server.drain()
    time.sleep(policy.quiet_s + 0.05)
    assert cleaner.budget() == (512, 4)  # quiet again: full base


def test_default_policy_validation():
    with pytest.raises(ValueError):
        SLOClass("bad", weight=0.0)
    with pytest.raises(ValueError):
        QoSPolicy(classes=(SLOClass("a", 1.0), SLOClass("a", 2.0)))
    with pytest.raises(KeyError):
        QoSPolicy().slo("nope")
    with pytest.raises(ValueError):
        Session(sid="w", weight=0.0)


# -------------------------------------------------------- overload stress (slow)
N_SEED = 192
CHUNK = 16
N_CHUNKS = 4
N_CLIENTS = 16
QUERIES_PER_CLIENT = 6


def _build_daisy(data):
    rel = make_relation(data, overlay=["zip", "city"], k=8, rules=["zc"])
    return Daisy(
        {"h": rel}, {"h": [FD("zc", "zip", "city")]},
        DaisyConfig(use_cost_model=False),
    )


def _candidate_state(rel, n_rows):
    state = {}
    for attr in ("zip", "city"):
        vals = np.asarray(rel.cand[attr])
        probs = np.asarray(rel.probs(attr))
        state[attr] = [
            {
                (int(v), round(float(p), 5))
                for v, p in zip(vals[r], probs[r])
                if p > 0
            }
            for r in range(n_rows)
        ]
    return state


@pytest.mark.slow
def test_overload_stress_no_lost_updates():
    """16 client threads past capacity, mixing interactive (sheddable),
    batch, and streamed ingest.  Must hold simultaneously: every ticket
    is served or *explicitly* shed (tagged), ingest barriers keep arrival
    order (a query queued behind its append sees the appended rows), and
    the final overlays equal the Lemma-4 serial reference — shaping never
    loses an update."""
    total_rows = N_SEED + N_CHUNKS * CHUNK
    ds = hospital_like(total_rows, error_frac=0.15, seed=23)
    data = dict(ds.data)
    seed_data = {k: v[:N_SEED] for k, v in data.items()}
    chunks = [
        {
            k: v[N_SEED + c * CHUNK: N_SEED + (c + 1) * CHUNK]
            for k, v in data.items()
        }
        for c in range(N_CHUNKS)
    ]
    daisy = _build_daisy(seed_data)
    policy = QoSPolicy(overload_depth=6)
    server = QueryServer(daisy, max_batch=4, qos=policy)
    serving = threading.Thread(target=server.run, name="serving")
    serving.start()

    pool = [Query("h", preds=(Pred("zip", "==", g),)) for g in range(6)]
    errors = []
    submitted = []
    submitted_lock = threading.Lock()
    # one dedicated ingest client keeps chunk order deterministic so the
    # serial reference sees the same final row layout
    barrier_checks = []

    def ingest_client():
        session = server.open_session("ingestor", max_inflight=64)
        try:
            for c, chunk in enumerate(chunks):
                ing = server.ingest("h", chunk)
                # submitted BEHIND the append without waiting: the barrier
                # must serve it over the appended instance
                after = server.submit(session, pool[c % len(pool)], slo="batch")
                with submitted_lock:
                    submitted.append(after)
                rep = ing.wait(timeout=300)
                assert rep.rows == CHUNK
                res = after.wait(timeout=300)
                barrier_checks.append(
                    (len(np.asarray(res.mask)), N_SEED + (c + 1) * CHUNK)
                )
        except BaseException as exc:
            errors.append(("ingestor", exc))

    def client(tid):
        session = server.open_session(f"c{tid}", max_inflight=64)
        try:
            for i in range(QUERIES_PER_CLIENT):
                q = pool[(tid + i) % len(pool)]
                slo = "batch" if (tid + i) % 3 == 0 else "interactive"
                t = server.submit(session, q, slo=slo)
                with submitted_lock:
                    submitted.append(t)
                t.wait(timeout=300)
        except BaseException as exc:
            errors.append((tid, exc))

    threads = [threading.Thread(target=ingest_client, name="ingest-client")]
    threads += [
        threading.Thread(target=client, args=(tid,), name=f"client{tid}")
        for tid in range(N_CLIENTS - 1)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=600)
    assert not any(t.is_alive() for t in threads)
    assert not errors, f"client failures: {errors}"

    # every cluster fully cleaned over the final instance before comparing
    sweep = server.open_session("sweep", max_inflight=64)
    final = [server.submit(sweep, q, slo="batch") for q in pool]
    for t in final:
        t.wait(timeout=300)
    server.stop()
    serving.join(timeout=60)
    assert not serving.is_alive()

    # --- every ticket served or explicitly shed, none starved, none lost
    n_queries = len(submitted) + len(final)
    for t in submitted + final:
        assert t.event.is_set()
        if t.shed:
            assert t.staleness is not None  # shed => always tagged
        else:
            assert t.staleness is None  # served fresh => never tagged
        assert t.error is None
    snap = server.snapshot()
    assert snap["answered"] == n_queries
    assert snap["qos"]["cancelled"] == 0
    assert snap["errors"] == 0
    assert snap["ingests"] == N_CHUNKS

    # --- ingest barriers kept arrival order
    for got_rows, min_rows in barrier_checks:
        assert got_rows >= min_rows

    # --- no lost overlay updates: Lemma-4 serial reference
    serial = _build_daisy(seed_data)
    for chunk in chunks:
        serial.ingest("h", chunk)
    for q in pool:
        serial.execute(q)
    got = _candidate_state(daisy.db["h"], total_rows)
    want = _candidate_state(serial.db["h"], total_rows)
    for attr in ("zip", "city"):
        for r in range(total_rows):
            assert got[attr][r] == want[attr][r], (
                f"{attr} row {r}: {got[attr][r]} != {want[attr][r]}"
            )
