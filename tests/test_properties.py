"""Property-based tests (hypothesis) for the system's invariants.

* Lemma 4: the candidate merge is commutative & associative.
* Relaxation: monotone, idempotent at the fixpoint, never reaches invalid
  rows, converges within the logarithmic bound.
* Possible-world filters: candidate qualification is a superset of the
  certain (primary-value) qualification for rows with overlays.
* group_distinct_candidates: counts sum to the group size.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.constraints import FD
from repro.core.relation import make_relation
from repro.core.relax import default_max_iters, relax_fd
from repro.core.setops import group_distinct_candidates, member_in
from repro.core.update import merge_candidates

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def small_relation(draw):
    n = draw(st.integers(2, 24))
    a = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    b = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    rel = make_relation(
        {"a": np.array(a, np.int32), "b": np.array(b, np.int32)},
        overlay=["a", "b"],
        k=8,
    )
    mask = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    return rel, jnp.asarray(np.array(mask))


@st.composite
def cand_sets(draw):
    rows = draw(st.integers(1, 6))
    k = draw(st.integers(1, 4))

    def one():
        vals = draw(
            st.lists(
                st.integers(0, 3), min_size=rows * k, max_size=rows * k
            )
        )
        cnts = draw(
            st.lists(
                st.integers(0, 3), min_size=rows * k, max_size=rows * k
            )
        )
        v = jnp.asarray(np.array(vals, np.int32).reshape(rows, k))
        c = jnp.asarray(np.array(cnts, np.float32).reshape(rows, k))
        kk = jnp.zeros((rows, k), jnp.int8)
        return v, c, kk

    return one(), one(), draw(st.integers(2, 6))


def dist_of(v, c, row):
    """Canonical value->count map for one row."""
    out = {}
    for val, cnt in zip(np.asarray(v)[row], np.asarray(c)[row]):
        if cnt > 0:
            out[int(val)] = out.get(int(val), 0.0) + float(cnt)
    return out


class TestLemma4MergeProperties:
    @given(cand_sets())
    @settings(**SETTINGS)
    def test_commutative(self, data):
        (av, ac, ak), (bv, bc, bk), k = data
        v1, c1, k1 = merge_candidates(av, ac, ak, bv, bc, bk, k)
        v2, c2, k2 = merge_candidates(bv, bc, bk, av, ac, ak, k)
        for r in range(av.shape[0]):
            d1, d2 = dist_of(v1, c1, r), dist_of(v2, c2, r)
            # top-k truncation can only differ when > k distinct values exist;
            # with <= k distinct the merged multisets must be identical
            if len(dist_of(jnp.concatenate([av, bv], 1), jnp.concatenate([ac, bc], 1), r)) <= k:
                assert d1 == d2

    @given(cand_sets())
    @settings(**SETTINGS)
    def test_mass_conserved(self, data):
        (av, ac, ak), (bv, bc, bk), k = data
        distinct = max(
            len(dist_of(jnp.concatenate([av, bv], 1), jnp.concatenate([ac, bc], 1), r))
            for r in range(av.shape[0])
        )
        v, c, _ = merge_candidates(av, ac, ak, bv, bc, bk, k)
        if distinct <= k:
            np.testing.assert_allclose(
                np.asarray(c).sum(), np.asarray(ac).sum() + np.asarray(bc).sum(),
                rtol=1e-6,
            )

    @given(cand_sets())
    @settings(**SETTINGS)
    def test_merge_with_empty_is_identity(self, data):
        (av, ac, ak), _, k = data
        zv = jnp.zeros_like(av)
        zc = jnp.zeros_like(ac)
        zk = jnp.zeros_like(ak)
        v, c, kk = merge_candidates(av, ac, ak, zv, zc, zk, max(k, av.shape[1]))
        for r in range(av.shape[0]):
            assert dist_of(v, c, r) == dist_of(av, ac, r)


class TestRelaxationProperties:
    @given(small_relation())
    @settings(**SETTINGS)
    def test_monotone_and_bounded(self, data):
        rel, answer = data
        fd = FD("r", "a", "b")
        res = relax_fd(rel, answer, fd)
        extra = np.asarray(res.extra)
        ans = np.asarray(answer & rel.valid)
        assert not (extra & ans).any()  # extras disjoint from the answer
        assert bool(res.converged)
        assert int(res.iterations) <= default_max_iters(rel.capacity)

    @given(small_relation())
    @settings(**SETTINGS)
    def test_idempotent_at_fixpoint(self, data):
        rel, answer = data
        fd = FD("r", "a", "b")
        res1 = relax_fd(rel, answer, fd)
        reached = (answer & rel.valid) | res1.extra
        res2 = relax_fd(rel, reached, fd)
        assert not np.asarray(res2.extra).any()

    @given(small_relation())
    @settings(**SETTINGS)
    def test_closure_closed_under_key_sharing(self, data):
        """No unvisited tuple shares an (a) or (b) value with the closure."""
        rel, answer = data
        fd = FD("r", "a", "b")
        res = relax_fd(rel, answer, fd)
        reached = np.asarray((answer & rel.valid) | res.extra)
        outside = np.asarray(rel.valid) & ~reached
        a = np.asarray(rel.columns["a"])
        b = np.asarray(rel.columns["b"])
        if reached.any() and outside.any():
            assert not np.isin(a[outside], a[reached]).any()
            assert not np.isin(b[outside], b[reached]).any()


class TestSetopsProperties:
    @given(small_relation())
    @settings(**SETTINGS)
    def test_member_in_matches_numpy(self, data):
        rel, mask = data
        a = rel.columns["a"]
        got = np.asarray(member_in([a], rel.valid, [a], mask))
        av = np.asarray(a)
        expect = np.isin(av, av[np.asarray(mask & rel.valid)]) & np.asarray(rel.valid)
        np.testing.assert_array_equal(got, expect)

    @given(small_relation())
    @settings(**SETTINGS)
    def test_group_counts_sum_to_group_size(self, data):
        rel, mask = data
        mask = mask & rel.valid
        a, b = rel.columns["a"], rel.columns["b"]
        cand, count, violated, overflow = group_distinct_candidates([a], b, mask, k=8)
        av, cv = np.asarray(a), np.asarray(count)
        m = np.asarray(mask)
        for i in range(rel.capacity):
            if not m[i]:
                continue
            gsize = (av[m] == av[i]).sum()
            assert cv[i].sum() == gsize

    @given(small_relation())
    @settings(**SETTINGS)
    def test_violated_iff_two_distinct(self, data):
        rel, mask = data
        mask = mask & rel.valid
        a, b = rel.columns["a"], rel.columns["b"]
        _, _, violated, _ = group_distinct_candidates([a], b, mask, k=8)
        av, bv, m = np.asarray(a), np.asarray(b), np.asarray(mask)
        for i in range(rel.capacity):
            exp = m[i] and len(set(bv[m & (av == av[i])])) >= 2
            assert bool(np.asarray(violated)[i]) == exp


class TestPossibleWorldFilters:
    @given(small_relation(), st.integers(0, 5))
    @settings(**SETTINGS)
    def test_candidate_match_superset_after_repair(self, data, val):
        """After repairing, every row that qualified on its primary value
        still qualifies (the overlay always includes the original value's
        group candidates)."""
        from repro.core.detect import detect_fd
        from repro.core.repair import fd_repair_candidates
        from repro.core.update import apply_candidates

        rel, _ = data
        fd = FD("r", "a", "b")
        det = detect_fd(rel, fd, rel.valid)
        deltas = fd_repair_candidates(rel, fd, det, rel.valid)
        rel2 = apply_candidates(rel, deltas)
        before = np.asarray(rel.columns["b"] == val) & np.asarray(rel.valid)
        after = np.asarray(rel2.candidate_matches("b", "==", val)) & np.asarray(rel2.valid)
        assert (before <= after).all()
