"""Docs cannot rot: every ``DESIGN.md §N`` citation in src/ must resolve
to a real section header, every markdown link/anchor in README.md and
DESIGN.md must resolve, and every public ``repro.service`` symbol must
carry a docstring (tools/check_docs.py — also a CI docs job)."""

import importlib.util
import os

_spec = importlib.util.spec_from_file_location(
    "check_docs",
    os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "tools",
        "check_docs.py",
    ),
)
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_design_sections_resolve():
    assert check_docs.check() == []


def test_design_citations_exist_at_all():
    """The checker is not vacuous: src/ really does cite DESIGN.md."""
    cites = check_docs.cited_sections()
    assert cites, "no DESIGN.md citations found under src/"
    # the sections past PRs wrote for the long-standing citations, plus
    # this PR's background-cleaning section
    assert {"2", "4", "7", "8", "9", "10"} <= set(cites)


def test_link_checker_catches_dangling_targets(tmp_path):
    """The anchor/link check really fails on rot (synthetic document)."""
    (tmp_path / "real.md").write_text("# §10 Background cleaning\ntext\n")
    text = (
        "[ok](real.md) [ok-anchor](real.md#10-background-cleaning) "
        "[gone](missing.md) [bad-anchor](real.md#nope) "
        "[external](https://example.com/x#y)"
    )
    problems = check_docs.link_problems(text, "fake.md", tmp_path)
    assert len(problems) == 2
    assert any("missing.md" in p for p in problems)
    assert any("#nope" in p for p in problems)


def test_heading_slugs_github_style():
    slugs = check_docs.heading_slugs("## §9 Service layer\n### A B-C `d`\n")
    assert "9-service-layer" in slugs
    assert "a-b-c-d" in slugs


def test_service_docstring_check_not_vacuous():
    """The ast audit really scans the service layer: there are plenty of
    public symbols, and a synthetic undocumented one is flagged."""
    assert check_docs.public_service_symbols() > 20
    import ast

    tree = ast.parse("def public_fn():\n    pass\n")
    missing = check_docs._missing_docstrings(tree, "fake.py")
    assert any("public_fn" in m for m in missing)
    assert any("module" in m for m in missing)
