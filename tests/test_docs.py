"""Docs cannot rot: every ``DESIGN.md §N`` citation in src/ must resolve
to a real section header (tools/check_docs.py — also a CI docs job)."""

import importlib.util
import os

_spec = importlib.util.spec_from_file_location(
    "check_docs",
    os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "tools",
        "check_docs.py",
    ),
)
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_design_sections_resolve():
    assert check_docs.check() == []


def test_design_citations_exist_at_all():
    """The checker is not vacuous: src/ really does cite DESIGN.md."""
    cites = check_docs.cited_sections()
    assert cites, "no DESIGN.md citations found under src/"
    # the sections this PR wrote for the long-standing citations
    assert {"2", "4", "7", "8"} <= set(cites)
