"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-style grad step + a prefill->decode consistency probe, on CPU.

Assert output shapes and no NaNs.  The FULL configs are exercised only via
the dry-run (ShapeDtypeStructs, no allocation) — see launch/dryrun.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.params import init_params
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    loss_fn,
    prefill,
)

B, S = 2, 32


def make_batch(cfg, rng):
    s_text = S - cfg.vis_tokens if cfg.frontend == "vision" else S
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_text))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_text))),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vis_tokens, cfg.d_model)), jnp.float32
        )
    if cfg.frontend == "audio":
        batch["enc_frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch):
        cfg = get_config(arch, reduced=True).canonicalize(tp=2)
        params = init_params(jax.random.key(0), cfg)
        batch = make_batch(cfg, np.random.default_rng(0))
        logits, aux = jax.jit(lambda p, b: forward(p, cfg, b, mamba_chunk=8))(
            params, batch
        )
        vocab = cfg.vocab_padded or cfg.vocab_size
        assert logits.shape == (B, S, vocab)
        assert np.isfinite(np.asarray(logits)).all()
        assert np.isfinite(float(aux))

    def test_train_grad_step(self, arch):
        cfg = get_config(arch, reduced=True).canonicalize(tp=2)
        params = init_params(jax.random.key(1), cfg)
        batch = make_batch(cfg, np.random.default_rng(1))

        def step(p, b):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, b, mamba_chunk=8), has_aux=True
            )(p)
            return loss, metrics, grads

        loss, metrics, grads = jax.jit(step)(params, batch)
        assert np.isfinite(float(loss))
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    def test_decode_step_shapes(self, arch):
        cfg = get_config(arch, reduced=True).canonicalize(tp=2)
        params = init_params(jax.random.key(2), cfg)
        cache = init_cache(cfg, B, S, jnp.float32)
        tok = jnp.zeros((B, 1), jnp.int32)
        logits, cache2 = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))(
            params, cache, tok
        )
        vocab = cfg.vocab_padded or cfg.vocab_size
        assert logits.shape == (B, vocab)
        assert np.isfinite(np.asarray(logits)).all()
        assert int(cache2["t"]) == 1


@pytest.mark.parametrize(
    "arch", ["qwen3-4b", "gemma3-12b", "falcon-mamba-7b", "whisper-large-v3"]
)
def test_prefill_decode_matches_forward(arch):
    """prefill(s tokens) then decode(token s) must equal forward(s+1 tokens)
    at the last position — the KV cache/stream state is exact.  Run in f32
    so the comparison is numerics-tight, not bf16-rounding-limited."""
    import dataclasses

    cfg = get_config(arch, reduced=True).canonicalize(tp=2)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_params(jax.random.key(3), cfg)
    rng = np.random.default_rng(3)
    s = 16
    toks = rng.integers(0, cfg.vocab_size, (B, s + 1))
    batch_full = {"tokens": jnp.asarray(toks)}
    batch_pre = {"tokens": jnp.asarray(toks[:, :s])}
    if cfg.frontend == "audio":
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.float32
        )
        batch_full["enc_frames"] = frames
        batch_pre["enc_frames"] = frames

    logits_full, _ = jax.jit(lambda p, b: forward(p, cfg, b, mamba_chunk=8))(
        params, batch_full
    )
    _, cache = jax.jit(
        lambda p, b: prefill(p, cfg, b, s_max=s + 8, cache_dtype=jnp.float32,
                             mamba_chunk=8)
    )(params, batch_pre)
    logits_dec, _ = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))(
        params, cache, jnp.asarray(toks[:, s : s + 1])
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec),
        np.asarray(logits_full[:, -1]),
        atol=2e-3, rtol=2e-3,
    )


def test_param_count_matches_tree():
    """config.param_count() agrees with the constructed tree (unpadded)."""
    for arch in ("qwen3-4b", "olmoe-1b-7b", "falcon-mamba-7b"):
        cfg = get_config(arch, reduced=True)
        cfg_c = cfg.canonicalize(tp=1)  # tp=1: no padding
        params = init_params(jax.random.key(0), cfg_c)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        expected = cfg.param_count()
        # allow small bookkeeping drift (norm biases etc.) but not layers
        assert abs(actual - expected) / expected < 0.05, (
            f"{arch}: tree {actual} vs param_count {expected}"
        )
