"""repro.service — the concurrent query-serving subsystem (DESIGN.md §9).

Covers the cache soundness contract (equal clean_version => bit-identical
answers), stable query fingerprints, scheduler batching (one detect/repair
pass per cluster; answers bit-identical to a serial fresh-instance run),
session limits/lineage, serializable step reports, and the quickstart
example.
"""

import json
import os
import runpy

import numpy as np
import pytest

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import (
    GroupBySpec,
    JoinClause,
    Pred,
    Query,
    query_fingerprint,
)
from repro.core.relation import make_relation
from repro.service import (
    QueryServer,
    ResultCache,
    Session,
    SessionLimitError,
    batch_tickets,
    cluster_key,
)
from tests.conftest import LA, NY, SF

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fresh_daisy(rel_factory, rules):
    return Daisy(rel_factory(), rules, DaisyConfig(use_cost_model=False))


def cities_factory():
    return {
        "cities": make_relation(
            {
                "zip": np.array([9001, 9001, 9001, 10001, 10001]),
                "city": np.array([LA, SF, LA, SF, NY]),
            },
            overlay=["zip", "city"],
            k=4,
            rules=["zip_city"],
        )
    }


CITY_RULES = {"cities": [FD("zip_city", "zip", "city")]}


def two_cluster_factory():
    """Two disjoint dirty zip groups (no shared city values, so relaxation
    closures never bridge them)."""
    return {
        "t": make_relation(
            {
                "zip": np.array([1, 1, 2, 2]),
                "city": np.array([10, 11, 20, 21]),
            },
            overlay=["zip", "city"],
            k=4,
            rules=["zc"],
        )
    }


TWO_CLUSTER_RULES = {"t": [FD("zc", "zip", "city")]}


# ---------------------------------------------------------------- fingerprint
class TestFingerprint:
    def test_stable_and_order_normalized(self):
        a = Query("t", preds=(Pred("x", "==", 1), Pred("y", ">", 2.5)))
        b = Query("t", preds=(Pred("y", ">", 2.5), Pred("x", "==", 1)))
        assert query_fingerprint(a) == query_fingerprint(b)
        assert len(query_fingerprint(a)) == 16
        int(query_fingerprint(a), 16)  # hex digest

    def test_discriminates(self):
        base = Query("t", preds=(Pred("x", "==", 1),))
        assert query_fingerprint(base) != query_fingerprint(
            Query("t", preds=(Pred("x", "==", 2),))
        )
        assert query_fingerprint(base) != query_fingerprint(
            Query("t", preds=(Pred("x", ">=", 1),))
        )
        assert query_fingerprint(base) != query_fingerprint(
            Query("u", preds=(Pred("x", "==", 1),))
        )
        assert query_fingerprint(base) != query_fingerprint(
            Query("t", preds=(Pred("x", "==", 1),), groupby=GroupBySpec(keys=("x",)))
        )
        assert query_fingerprint(base) != query_fingerprint(
            Query("t", preds=(Pred("x", "==", 1),),
                  joins=(JoinClause("u", "x", "x"),))
        )
        # projection feeds the planner's rule-overlap decision, so it is
        # cache-key-relevant (its order is not)
        assert query_fingerprint(base) != query_fingerprint(
            Query("t", preds=(Pred("x", "==", 1),), project=("y",))
        )
        assert query_fingerprint(
            Query("t", project=("y", "z"))
        ) == query_fingerprint(Query("t", project=("z", "y")))

    def test_int_float_distinct(self):
        # 1 and 1.0 select the same rows but must not be forced to collide
        # with 1.0000001; exact-bit float canonicalization keeps both stable.
        qa = Query("t", preds=(Pred("x", "==", 1.0),))
        qb = Query("t", preds=(Pred("x", "==", 1.0000001),))
        assert query_fingerprint(qa) != query_fingerprint(qb)
        assert query_fingerprint(qa) == query_fingerprint(
            Query("t", preds=(Pred("x", "==", 1.0),))
        )


# --------------------------------------------------------------- clean version
class TestCleanVersion:
    def test_bumps_on_mutation_and_stabilizes(self):
        daisy = fresh_daisy(cities_factory, CITY_RULES)
        assert daisy.clean_version == 0
        q = Query("cities", preds=(Pred("city", "==", LA),))
        daisy.execute(q)
        v1 = daisy.clean_version
        assert v1 > 0  # apply_candidates + mark_checked both bumped
        daisy.execute(q)
        assert daisy.clean_version == v1  # checked scope => skip, no commit

    def test_equal_versions_bit_identical_answers(self):
        """The cache soundness contract: same fingerprint at the same
        clean_version answers bit-identically."""
        daisy = fresh_daisy(cities_factory, CITY_RULES)
        q = Query("cities", preds=(Pred("zip", "==", 9001),))
        first = daisy.execute(q)
        v = daisy.clean_version
        for _ in range(3):
            again = daisy.execute(q)
            assert daisy.clean_version == v
            np.testing.assert_array_equal(
                np.asarray(first.mask), np.asarray(again.mask)
            )

    def test_dc_repeat_skips_without_bump(self, salary_rel, dc_sal_tax):
        daisy = Daisy(
            {"t": salary_rel},
            {"t": [dc_sal_tax]},
            DaisyConfig(use_cost_model=False, dc_partitions=4),
        )
        q = Query("t", preds=(Pred("salary", ">=", 0.0),))
        r1 = daisy.execute(q)
        assert r1.report.steps[0].mode in ("incremental", "full")
        v = daisy.clean_version
        d = daisy.detect_calls
        r2 = daisy.execute(q)
        assert r2.report.steps[0].mode == "skipped"
        assert daisy.clean_version == v
        assert daisy.detect_calls == d
        np.testing.assert_array_equal(np.asarray(r1.mask), np.asarray(r2.mask))


# -------------------------------------------------------------------- reports
class TestSerializableReports:
    def test_exec_report_json_round_trip(self):
        daisy = fresh_daisy(cities_factory, CITY_RULES)
        res = daisy.execute(Query("cities", preds=(Pred("city", "==", LA),)))
        blob = json.dumps(res.report.asdict())
        back = json.loads(blob)
        assert back["steps"][0]["rule"] == "zip_city"
        assert back["result_size"] == res.report.result_size


# ---------------------------------------------------------------------- cache
class TestResultCache:
    def test_hit_requires_matching_version(self):
        cache = ResultCache(capacity=4)
        cache.put("fp", 3, "answer")
        assert cache.get("fp", 3) == "answer"
        assert cache.get("fp", 4) is None  # instance advanced -> stale
        assert cache.stale == 1
        assert cache.get("fp", 3) is None  # stale entries are dropped

    def test_lru_eviction(self):
        cache = ResultCache(capacity=2)
        cache.put("a", 0, 1)
        cache.put("b", 0, 2)
        assert cache.get("a", 0) == 1  # refresh a
        cache.put("c", 0, 3)  # evicts b
        assert cache.get("b", 0) is None
        assert cache.get("a", 0) == 1
        assert cache.get("c", 0) == 3
        assert cache.evictions == 1


# ------------------------------------------------------------------- sessions
class TestSession:
    def test_limits(self):
        s = Session("u0", max_inflight=1, max_queries=2)
        s.admit()
        with pytest.raises(SessionLimitError):
            s.admit()  # inflight bound
        s.fail()
        s.admit()
        s.fail()
        with pytest.raises(SessionLimitError):
            s.admit()  # lifetime quota

    def test_lineage_records_cache_provenance(self):
        daisy = fresh_daisy(cities_factory, CITY_RULES)
        srv = QueryServer(daisy)
        sess = srv.open_session("analyst")
        q = Query("cities", preds=(Pred("city", "==", LA),))
        srv.submit(sess, q)
        srv.submit(sess, q)
        srv.drain()
        assert [e.cached for e in sess.lineage] == [False, True]
        assert sess.lineage[0].clean_version == sess.lineage[1].clean_version
        snap = sess.snapshot()
        assert snap["answered"] == 2 and snap["cached_answers"] == 1


# ----------------------------------------------------------- scheduler batches
class TestSchedulerBatching:
    def test_cluster_key_groups_overlapping_sigma(self):
        rules = TWO_CLUSTER_RULES
        qa = Query("t", preds=(Pred("zip", "==", 1),))
        qb = Query("t", preds=(Pred("zip", "==", 1), Pred("city", ">=", 0)))
        qc = Query("t", preds=(Pred("zip", "==", 2),))
        assert cluster_key(qa, rules) == cluster_key(qb, rules)
        assert cluster_key(qa, rules) != cluster_key(qc, rules)

    def test_one_detect_pass_per_cluster(self):
        """N sessions issuing overlapping-σ queries: one detect/repair pass
        per cluster, answers bit-identical to a serial fresh Daisy."""
        daisy = fresh_daisy(two_cluster_factory, TWO_CLUSTER_RULES)
        srv = QueryServer(daisy, max_batch=16)
        sessions = [srv.open_session() for _ in range(6)]
        # cluster 1 twice per session (same σ), cluster 2 once per session
        queries = [
            Query("t", preds=(Pred("zip", "==", 1),)),
            Query("t", preds=(Pred("zip", "==", 1), Pred("city", ">=", 0))),
            Query("t", preds=(Pred("zip", "==", 2),)),
        ]
        tickets = []
        for sess in sessions:
            for q in queries:
                tickets.append(srv.submit(sess, q))
        assert srv.drain() == len(tickets)

        assert daisy.detect_calls == 2  # exactly one pass per cluster
        assert daisy.repair_calls == 2
        # batching grouped the two same-cluster fingerprints ahead of cluster 2
        groups = batch_tickets(tickets, daisy.rules)
        assert [len(g) for g in groups] == [12, 6]

        # bit-identical to running the same queries serially through a fresh
        # Daisy (the offline-equivalence harness's comparison, per ticket)
        serial = fresh_daisy(two_cluster_factory, TWO_CLUSTER_RULES)
        for ticket in tickets:
            ref = serial.execute(ticket.query)
            np.testing.assert_array_equal(
                np.asarray(ticket.result.mask),
                np.asarray(ref.mask),
                err_msg=str(ticket.query),
            )

    def test_stale_hits_reexecute_like_serial(self):
        """A cached answer is invalidated exactly when the instance advances;
        the re-execution matches the serial fresh-instance answer."""
        daisy = fresh_daisy(two_cluster_factory, TWO_CLUSTER_RULES)
        srv = QueryServer(daisy)
        sess = srv.open_session()
        qa = Query("t", preds=(Pred("zip", "==", 1),))
        qb = Query("t", preds=(Pred("zip", "==", 2),))
        t1 = srv.submit(sess, qa)
        srv.drain()
        v1 = t1.clean_version
        t2 = srv.submit(sess, qb)  # cleans cluster 2 -> version moves
        srv.drain()
        assert t2.clean_version > v1
        t3 = srv.submit(sess, qa)  # stale entry -> re-execute
        srv.drain()
        assert not t3.cached and srv.cache.stale == 1
        t4 = srv.submit(sess, qa)  # version now stable -> hit
        srv.drain()
        assert t4.cached
        serial = fresh_daisy(two_cluster_factory, TWO_CLUSTER_RULES)
        for q in (qa, qb, qa, qa):
            ref = serial.execute(q)
        np.testing.assert_array_equal(np.asarray(t4.result.mask), np.asarray(ref.mask))


# ------------------------------------------------------------------- metrics
class TestMetrics:
    def test_snapshot_serializable_and_consistent(self):
        daisy = fresh_daisy(cities_factory, CITY_RULES)
        srv = QueryServer(daisy)
        sess = srv.open_session()
        q = Query("cities", preds=(Pred("city", "==", LA),))
        for _ in range(4):
            srv.submit(sess, q)
        srv.drain()
        snap = srv.snapshot()
        json.dumps(snap)  # everything host-serializable
        assert snap["queries"] == 4
        assert snap["executions"] == 1
        assert snap["cache_hits"] == 3
        assert snap["cache"]["hits"] == 3
        assert snap["clean_version"] == daisy.clean_version
        assert snap["recent_reports"][0]["steps"][0]["rule"] == "zip_city"


# -------------------------------------------------------------------- example
def test_example_serve_queries_runs(capsys):
    runpy.run_path(
        os.path.join(ROOT, "examples", "serve_queries.py"), run_name="__main__"
    )
    out = capsys.readouterr().out
    assert "cache" in out
