"""Streaming-ingest equivalence (hypothesis, DESIGN.md §12).

The property the ingest path stands on: appending rows in chunks and
cleaning after each append converges to EXACTLY the state a fresh instance
built from all rows reaches in one clean — canonical per-row candidate
sets (value, kind, count) bit-identical over the valid prefix.  That holds
because ingest-deltas carry the same pair counts a full scan would have
produced for the checked rows (core/repair.py pair-count semantics) and
candidate merges are commutative/associative (Lemma 4).

Also pinned here, per append:

* checked bits of pre-existing rows are NEVER invalidated by an append —
  new rows land cold, old warm rows stay warm;
* version bumps touch only the ``(table, __rows__)`` pseudo-scope — rule
  scope versions move when cleaning merges the delta, never on the append
  itself, so cached answers for other tables/rules stay valid.

The equivalence regime (DESIGN.md §12 lists the caveats, the same ones
benchmarks/serve_bg_warmup.py gates under):

* rules are attribute-disjoint (FD on zip/city, DC on beds/quality);
* value ranges are small relative to k, so candidate sets never hit the
  top-k truncation;
* the FD data is cluster-DISJOINT (a city value appears in exactly one
  zip group): lhs candidates (P(lhs | rhs), Example 2) are grouped by
  rhs value, so an rhs value shared ACROSS lhs groups couples groups that
  partitioned scans — background increments since PR 5, ingest deltas
  here — visit at different times with different scopes.  Disjoint data
  makes the lhs grouping group-local and the partitioning exact.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.constraints import DC, FD, Atom
from repro.core.executor import Daisy, DaisyConfig
from repro.core.ledger import TABLE_ROWS_RULE
from repro.core.operators import GroupBySpec, Pred, Query
from repro.core.relation import append_rows, make_relation

SETTINGS = dict(max_examples=6, deadline=None)

OVERLAY = ["zip", "city", "beds", "quality"]
RULES = [
    FD("zc", "zip", "city"),
    DC("bq", [Atom("beds", "<", "beds"), Atom("quality", ">", "quality")]),
]


def _cfg():
    # accuracy_threshold=2.0: auto DC steps always resolve to full cleans,
    # so the streamed and rebuilt runs execute the same plan shape
    return DaisyConfig(use_cost_model=False, accuracy_threshold=2.0)


def _make(data):
    rel = make_relation(data, overlay=OVERLAY, k=8, rules=["zc", "bq"])
    return Daisy({"h": rel}, {"h": RULES}, _cfg())


def _full_clean(daisy):
    """Two full-scope queries: a bare group-by (FD pushdown full) and an
    everything-qualifies selection on the DC's attribute (full DC clean
    with an empty partner scope)."""
    daisy.execute(Query("h", groupby=GroupBySpec(keys=("city",), agg="count")))
    daisy.execute(Query("h", preds=(Pred("beds", ">=", 0),)))


def _canonical(daisy, n_rows):
    """Per-attr, per-row sorted (value, kind, count) candidate sets over
    the first ``n_rows`` rows — capacity-independent state signature."""
    rel = daisy.db["h"]
    out = {}
    for attr in OVERLAY:
        vals = np.asarray(rel.cand[attr])[:n_rows]
        cnts = np.asarray(rel.ccount[attr])[:n_rows]
        kinds = np.asarray(rel.ckind[attr])[:n_rows]
        out[attr] = [
            sorted(
                (int(v), int(kk), round(float(c), 3))
                for v, c, kk in zip(vals[r], cnts[r], kinds[r])
                if c > 1e-9
            )
            for r in range(n_rows)
        ]
    return out


@st.composite
def ingest_case(draw):
    n_seed = draw(st.integers(4, 12))
    sizes = draw(st.lists(st.integers(1, 6), min_size=1, max_size=3))
    total = n_seed + sum(sizes)

    def col(lo, hi):
        vs = draw(st.lists(st.integers(lo, hi), min_size=total, max_size=total))
        return np.array(vs, np.int32)

    zips = col(0, 3)
    # cluster-disjoint cities: city values live in [zip*8, zip*8 + 6), so no
    # city value bridges zip groups (see module docstring)
    data = {
        "zip": zips,
        "city": zips * 8 + col(0, 5),
        "beds": col(0, 40),
        "quality": col(0, 40),
    }
    return n_seed, sizes, data


class TestIngestEquivalence:
    @given(ingest_case())
    @settings(**SETTINGS)
    def test_chunked_ingest_matches_rebuild(self, case):
        n_seed, sizes, data = case
        total = n_seed + sum(sizes)

        streamed = _make({k: v[:n_seed] for k, v in data.items()})
        _full_clean(streamed)
        lo = n_seed
        for size in sizes:
            chunk = {k: v[lo: lo + size] for k, v in data.items()}
            before = int(streamed.db["h"].num_rows())
            checked_before = {
                r.name: np.asarray(streamed.db["h"].checked[r.name])[:before].copy()
                for r in RULES
            }
            rule_v = {r.name: streamed.ledger.version("h", r.name) for r in RULES}
            rows_v = streamed.ledger.version("h", TABLE_ROWS_RULE)

            report = streamed.ingest("h", chunk)
            assert report.rows == size and report.start == before

            # checked bits never invalidated by the append itself
            for r in RULES:
                np.testing.assert_array_equal(
                    np.asarray(streamed.db["h"].checked[r.name])[:before],
                    checked_before[r.name],
                )
            # only the __rows__ pseudo-scope bumps; rule scopes move when
            # cleaning merges the delta, not on append
            assert streamed.ledger.version("h", TABLE_ROWS_RULE) == rows_v + 1
            for r in RULES:
                assert streamed.ledger.version("h", r.name) == rule_v[r.name]

            _full_clean(streamed)
            lo += size

        rebuilt = _make(dict(data))
        _full_clean(rebuilt)

        sig_s = _canonical(streamed, total)
        sig_r = _canonical(rebuilt, total)
        for attr in OVERLAY:
            assert sig_s[attr] == sig_r[attr], (
                f"streamed candidate state diverged from rebuild on {attr!r}"
            )
        for r in RULES:
            np.testing.assert_array_equal(
                np.asarray(streamed.db["h"].checked[r.name])[:total],
                np.asarray(rebuilt.db["h"].checked[r.name])[:total],
            )

    @given(ingest_case())
    @settings(**SETTINGS)
    def test_untouched_table_versions_stable(self, case):
        n_seed, sizes, data = case
        rel_h = make_relation(
            {k: v[:n_seed] for k, v in data.items()},
            overlay=OVERLAY, k=8, rules=["zc", "bq"],
        )
        rel_u = make_relation(
            {"zip": data["zip"][:n_seed], "city": data["city"][:n_seed]},
            overlay=["zip", "city"], k=8, rules=["zc2"],
        )
        daisy = Daisy(
            {"h": rel_h, "u": rel_u},
            {"h": RULES, "u": [FD("zc2", "zip", "city")]},
            _cfg(),
        )
        u_deps = [("u", "zc2"), ("u", TABLE_ROWS_RULE)]
        u_vector = daisy.scope_versions(u_deps)
        daisy.ingest("h", {k: v[n_seed: n_seed + sizes[0]] for k, v in data.items()})
        assert daisy.scope_versions(u_deps) == u_vector, (
            "append into 'h' moved version state of untouched table 'u'"
        )


def test_append_rows_preserves_state_bit_for_bit():
    """Growing the backing arrays must not perturb existing rows: columns,
    overlay, counts, kinds, checked, valid — all bit-identical."""
    data = {
        "zip": np.array([1, 1, 2, 2], np.int32),
        "city": np.array([5, 6, 7, 7], np.int32),
    }
    daisy = Daisy(
        {"h": make_relation(data, overlay=["zip", "city"], k=4, rules=["zc"])},
        {"h": [FD("zc", "zip", "city")]},
        DaisyConfig(use_cost_model=False),
    )
    daisy.execute(Query("h", groupby=GroupBySpec(keys=("city",), agg="count")))
    rel = daisy.db["h"]
    snap = {
        "cols": {k: np.asarray(v).copy() for k, v in rel.columns.items()},
        "cand": {k: np.asarray(v).copy() for k, v in rel.cand.items()},
        "ccount": {k: np.asarray(v).copy() for k, v in rel.ccount.items()},
        "checked": {k: np.asarray(v).copy() for k, v in rel.checked.items()},
        "valid": np.asarray(rel.valid).copy(),
    }
    n = rel.capacity
    # force a growth: append more rows than the spare capacity holds
    grown, start = append_rows(
        rel,
        {"zip": np.full(n + 1, 3, np.int32), "city": np.full(n + 1, 9, np.int32)},
    )
    assert start == 4 and grown.capacity > n
    for k, v in snap["cols"].items():
        np.testing.assert_array_equal(np.asarray(grown.columns[k])[:n], v)
    for k, v in snap["cand"].items():
        np.testing.assert_array_equal(np.asarray(grown.cand[k])[:n], v)
    for k, v in snap["ccount"].items():
        np.testing.assert_array_equal(np.asarray(grown.ccount[k])[:n], v)
    for k, v in snap["checked"].items():
        np.testing.assert_array_equal(np.asarray(grown.checked[k])[:n], v)
    np.testing.assert_array_equal(np.asarray(grown.valid)[:n], snap["valid"])
    assert not np.asarray(grown.checked["zc"])[start:].any(), (
        "appended rows must land cold (unchecked)"
    )
