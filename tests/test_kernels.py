"""Pallas kernel validation: interpret-mode kernel vs pure-jnp oracle.

Every kernel sweeps shapes/dtypes and asserts allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.dc_pairs import dc_role_scan_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.semijoin import semijoin_pallas

SETTINGS = dict(max_examples=10, deadline=None)


# ------------------------------------------------------------------ dc_pairs
class TestDCPairsKernel:
    @pytest.mark.parametrize("n", [7, 64, 130, 300])
    @pytest.mark.parametrize("block", [64, 128])
    def test_matches_ref_int(self, n, block):
        rng = np.random.default_rng(n)
        a = jnp.asarray(rng.integers(0, 50, n).astype(np.int32))
        b = jnp.asarray(rng.integers(0, 50, n).astype(np.int32))
        rs = jnp.asarray(rng.random(n) < 0.7)
        cs = jnp.asarray(rng.random(n) < 0.7)
        args = ([a, b], [a, b], ["<", ">"], rs, cs, ["max", "min"])
        c_ref, s_ref = ref.dc_role_scan(*args, block=block)
        c_pal, s_pal = dc_role_scan_pallas(*args, block=block, interpret=True)
        np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
        for r, p in zip(s_ref, s_pal):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(p))

    @pytest.mark.parametrize("ops", [["<"], ["<=", ">="], ["==", "!="]])
    def test_op_sweep_float(self, ops):
        rng = np.random.default_rng(3)
        n = 96
        cols = [jnp.asarray(rng.uniform(0, 10, n).astype(np.float32)) for _ in ops]
        rs = jnp.asarray(np.ones(n, bool))
        cs = jnp.asarray(np.ones(n, bool))
        reduces = ["max" if o in ("<", "<=") else "min" for o in ops]
        args = (cols, cols, ops, rs, cs, reduces)
        c_ref, s_ref = ref.dc_role_scan(*args, block=32)
        c_pal, s_pal = dc_role_scan_pallas(*args, block=32, interpret=True)
        np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
        for r, p in zip(s_ref, s_pal):
            np.testing.assert_allclose(np.asarray(r), np.asarray(p))

    def test_count_matches_brute_force(self):
        rng = np.random.default_rng(9)
        n = 48
        a = rng.integers(0, 20, n).astype(np.int32)
        b = rng.integers(0, 20, n).astype(np.int32)
        count, _ = ref.dc_role_scan(
            [jnp.asarray(a), jnp.asarray(b)],
            [jnp.asarray(a), jnp.asarray(b)],
            ["<", ">"],
            jnp.ones(n, bool),
            jnp.ones(n, bool),
            ["max", "min"],
            block=16,
        )
        expect = np.zeros(n, np.int32)
        for i in range(n):
            for j in range(n):
                if i != j and a[i] < a[j] and b[i] > b[j]:
                    expect[i] += 1
        np.testing.assert_array_equal(np.asarray(count), expect)

    @given(st.integers(4, 80), st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_property_random(self, n, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.integers(0, 8, n).astype(np.int32))
        rs = jnp.asarray(rng.random(n) < 0.5)
        cs = jnp.asarray(rng.random(n) < 0.5)
        args = ([a], [a], ["<"], rs, cs, ["max"])
        c_ref, s_ref = ref.dc_role_scan(*args, block=32)
        c_pal, s_pal = dc_role_scan_pallas(*args, block=32, interpret=True)
        np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
        np.testing.assert_array_equal(np.asarray(s_ref[0]), np.asarray(s_pal[0]))


# ------------------------------------------------------------------ semijoin
class TestSemijoinKernel:
    @pytest.mark.parametrize("n,m", [(5, 7), (64, 64), (100, 257), (513, 100)])
    @pytest.mark.parametrize("block", [64, 256])
    def test_matches_ref(self, n, m, block):
        rng = np.random.default_rng(n * m)
        q = jnp.asarray(rng.integers(0, 40, n).astype(np.int32))
        k = jnp.asarray(rng.integers(0, 40, m).astype(np.int32))
        qm = jnp.asarray(rng.random(n) < 0.8)
        km = jnp.asarray(rng.random(m) < 0.8)
        r = ref.semijoin(q, qm, k, km, block=block)
        p = semijoin_pallas(q, qm, k, km, block=block, interpret=True)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(p))

    def test_matches_numpy(self):
        rng = np.random.default_rng(0)
        q = rng.integers(0, 10, 50).astype(np.int32)
        k = rng.integers(0, 10, 30).astype(np.int32)
        km = rng.random(30) < 0.5
        got = ref.semijoin(
            jnp.asarray(q), jnp.ones(50, bool), jnp.asarray(k), jnp.asarray(km)
        )
        np.testing.assert_array_equal(np.asarray(got), np.isin(q, k[km]))

    def test_empty_key_set(self):
        q = jnp.arange(10, dtype=jnp.int32)
        k = jnp.arange(10, dtype=jnp.int32)
        got = semijoin_pallas(
            q, jnp.ones(10, bool), k, jnp.zeros(10, bool), interpret=True
        )
        assert not np.asarray(got).any()


# ----------------------------------------------------------- flash attention
class TestFlashAttention:
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
    @pytest.mark.parametrize("sq,sk", [(128, 128), (256, 256)])
    def test_causal_matches_ref(self, hq, hkv, sq, sk):
        rng = np.random.default_rng(hq * sq)
        d = 64
        q = jnp.asarray(rng.standard_normal((2, hq, sq, d)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((2, hkv, sk, d)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((2, hkv, sk, d)).astype(np.float32))
        r = ref.attention(q, k, v, causal=True)
        p = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=2e-5, rtol=2e-5)

    def test_sliding_window(self):
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
        r = ref.attention(q, k, v, causal=True, window=64)
        p = flash_attention_pallas(
            q, k, v, causal=True, window=64, block_q=64, block_kv=64, interpret=True
        )
        np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=2e-5, rtol=2e-5)

    def test_noncausal(self):
        rng = np.random.default_rng(6)
        q = jnp.asarray(rng.standard_normal((1, 2, 128, 32)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 2, 128, 32)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((1, 2, 128, 32)).astype(np.float32))
        r = ref.attention(q, k, v, causal=False)
        p = flash_attention_pallas(q, k, v, causal=False, block_q=64, block_kv=64,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=2e-5, rtol=2e-5)

    def test_bf16_io(self):
        rng = np.random.default_rng(7)
        q = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
        r = ref.attention(q, k, v, causal=True)
        p = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64,
                                   interpret=True)
        np.testing.assert_allclose(
            np.asarray(r, np.float32), np.asarray(p, np.float32), atol=3e-2
        )

    def test_softmax_rows_sum_to_one_effect(self):
        """Uniform V must pass through attention unchanged."""
        q = jnp.ones((1, 1, 128, 32), jnp.float32)
        k = jnp.ones((1, 1, 128, 32), jnp.float32)
        v = jnp.full((1, 1, 128, 32), 3.0, jnp.float32)
        p = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(p), 3.0, rtol=1e-6)
