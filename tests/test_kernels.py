"""Pallas kernel validation: interpret-mode kernel vs pure-jnp oracle.

Every kernel sweeps shapes/dtypes and asserts allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.constraints import DC, Atom, flip_op
from repro.core.detect import _T1_REDUCE
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.dc_pairs import dc_role_scan_pallas, resolve_block_ids
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.semijoin import semijoin_pallas

SETTINGS = dict(max_examples=10, deadline=None)


# ------------------------------------------------------------------ dc_pairs
class TestDCPairsKernel:
    @pytest.mark.parametrize("n", [7, 64, 130, 300])
    @pytest.mark.parametrize("block", [64, 128])
    def test_matches_ref_int(self, n, block):
        rng = np.random.default_rng(n)
        a = jnp.asarray(rng.integers(0, 50, n).astype(np.int32))
        b = jnp.asarray(rng.integers(0, 50, n).astype(np.int32))
        rs = jnp.asarray(rng.random(n) < 0.7)
        cs = jnp.asarray(rng.random(n) < 0.7)
        args = ([a, b], [a, b], ["<", ">"], rs, cs, ["max", "min"])
        c_ref, s_ref = ref.dc_role_scan(*args, block=block)
        c_pal, s_pal = dc_role_scan_pallas(*args, block=block, interpret=True)
        np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
        for r, p in zip(s_ref, s_pal):
            np.testing.assert_array_equal(np.asarray(r), np.asarray(p))

    @pytest.mark.parametrize("ops", [["<"], ["<=", ">="], ["==", "!="]])
    def test_op_sweep_float(self, ops):
        rng = np.random.default_rng(3)
        n = 96
        cols = [jnp.asarray(rng.uniform(0, 10, n).astype(np.float32)) for _ in ops]
        rs = jnp.asarray(np.ones(n, bool))
        cs = jnp.asarray(np.ones(n, bool))
        reduces = ["max" if o in ("<", "<=") else "min" for o in ops]
        args = (cols, cols, ops, rs, cs, reduces)
        c_ref, s_ref = ref.dc_role_scan(*args, block=32)
        c_pal, s_pal = dc_role_scan_pallas(*args, block=32, interpret=True)
        np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
        for r, p in zip(s_ref, s_pal):
            np.testing.assert_allclose(np.asarray(r), np.asarray(p))

    def test_count_matches_brute_force(self):
        rng = np.random.default_rng(9)
        n = 48
        a = rng.integers(0, 20, n).astype(np.int32)
        b = rng.integers(0, 20, n).astype(np.int32)
        count, _ = ref.dc_role_scan(
            [jnp.asarray(a), jnp.asarray(b)],
            [jnp.asarray(a), jnp.asarray(b)],
            ["<", ">"],
            jnp.ones(n, bool),
            jnp.ones(n, bool),
            ["max", "min"],
            block=16,
        )
        expect = np.zeros(n, np.int32)
        for i in range(n):
            for j in range(n):
                if i != j and a[i] < a[j] and b[i] > b[j]:
                    expect[i] += 1
        np.testing.assert_array_equal(np.asarray(count), expect)

    @given(st.integers(4, 80), st.integers(0, 2**31 - 1))
    @settings(**SETTINGS)
    def test_property_random(self, n, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.integers(0, 8, n).astype(np.int32))
        rs = jnp.asarray(rng.random(n) < 0.5)
        cs = jnp.asarray(rng.random(n) < 0.5)
        args = ([a], [a], ["<"], rs, cs, ["max"])
        c_ref, s_ref = ref.dc_role_scan(*args, block=32)
        c_pal, s_pal = dc_role_scan_pallas(*args, block=32, interpret=True)
        np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
        np.testing.assert_array_equal(np.asarray(s_ref[0]), np.asarray(s_pal[0]))


# ------------------------------------------------- block-sparse worklist (§15)
def _pair_scan(a, op, rs, cs, force, block=16, **restr):
    flipped = (flip_op(op),)
    return kops.dc_pair_scan(
        [a], [a], (op,), flipped, rs, cs,
        (_T1_REDUCE[op],), (_T1_REDUCE[flip_op(op)],),
        block=block, force=force, **restr,
    )


class TestDCPairsBlockSparse:
    """The ledger-masked worklist contract (DESIGN.md §15): restricting the
    row side to a block worklist is EXACTLY the dense scan with the
    non-worklist rows scoped out — both roles, counts and stats — and the
    launch geometry matches the worklist."""

    @given(
        st.integers(4, 96),
        st.integers(0, 2**31 - 1),
        st.sampled_from(["<", "<=", ">", ">=", "==", "!="]),
        st.sampled_from(["ref", "interpret"]),
    )
    @settings(**SETTINGS)
    def test_masked_equals_dense_on_cold_subset(self, n, seed, op, force):
        block = 16
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.integers(0, 6, n).astype(np.int32))
        rs = jnp.asarray(rng.random(n) < 0.7)
        cs = jnp.asarray(rng.random(n) < 0.7)
        nb = -(-n // block)
        ids = np.flatnonzero(rng.random(nb) < 0.5).astype(np.int32)
        cold_rows = np.zeros(nb * block, bool)
        for b in ids:
            cold_rows[b * block : (b + 1) * block] = True
        sparse = _pair_scan(a, op, rs, cs, force, row_block_ids=ids)
        dense = _pair_scan(
            a, op, rs & jnp.asarray(cold_rows[:n]), cs, "ref"
        )
        assert sparse.tiles.launched == int(ids.size) * nb
        np.testing.assert_array_equal(
            np.asarray(sparse.t1_count), np.asarray(dense.t1_count)
        )
        np.testing.assert_array_equal(
            np.asarray(sparse.t2_count), np.asarray(dense.t2_count)
        )
        np.testing.assert_array_equal(
            np.asarray(sparse.t1_stat[0]), np.asarray(dense.t1_stat[0])
        )
        np.testing.assert_array_equal(
            np.asarray(sparse.t2_stat[0]), np.asarray(dense.t2_stat[0])
        )

    @pytest.mark.parametrize("force", ["ref", "interpret"])
    def test_all_checked_zero_launches(self, force):
        """A fully converged scope launches nothing and returns zeros and
        reduce identities — with no kernel call at all."""
        n = 48
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.integers(0, 6, n).astype(np.int32))
        scope = jnp.ones(n, bool)
        res = _pair_scan(
            a, "<", scope, scope, force,
            row_block_ids=np.array([], dtype=np.int32),
        )
        assert res.tiles.launched == 0
        assert not np.asarray(res.t1_count).any()
        assert not np.asarray(res.t2_count).any()
        np.testing.assert_array_equal(
            np.asarray(res.t1_stat[0]), np.iinfo(np.int32).min
        )
        np.testing.assert_array_equal(
            np.asarray(res.t2_stat[0]), np.iinfo(np.int32).max
        )

    @pytest.mark.parametrize("force", ["ref", "interpret"])
    def test_all_cold_matches_unrestricted(self, force):
        n, block = 80, 16
        rng = np.random.default_rng(2)
        a = jnp.asarray(rng.integers(0, 9, n).astype(np.int32))
        rs = jnp.asarray(rng.random(n) < 0.8)
        cs = jnp.asarray(rng.random(n) < 0.8)
        nb = -(-n // block)
        full = _pair_scan(
            a, "<=", rs, cs, force, row_block_ids=np.arange(nb, dtype=np.int32)
        )
        dense = _pair_scan(a, "<=", rs, cs, "ref")
        assert full.tiles.launched == dense.tiles.launched == nb * nb
        np.testing.assert_array_equal(
            np.asarray(full.t1_count), np.asarray(dense.t1_count)
        )
        np.testing.assert_array_equal(
            np.asarray(full.t1_stat[0]), np.asarray(dense.t1_stat[0])
        )

    def test_resolve_block_ids(self):
        np.testing.assert_array_equal(resolve_block_ids(4), [0, 1, 2, 3])
        np.testing.assert_array_equal(resolve_block_ids(4, blocks=(1, 3)), [1, 2])
        np.testing.assert_array_equal(
            resolve_block_ids(4, block_ids=np.array([3, 1, 3])), [1, 3]
        )
        with pytest.raises(ValueError):
            resolve_block_ids(4, block_ids=np.array([4]))


# ------------------------------------------------- compressed encodings (§15)
class TestEncodings:
    def test_boundary_columns_fall_back(self):
        """Columns straddling the exactness boundary must demote: int8
        overflow, non-integral floats, NaN."""
        overflow = np.arange(200, dtype=np.int32)  # max 199 > 127
        # 0.1f32 etc. do NOT round-trip through bf16, and are not integral
        nonint = np.array([0.1, 0.2, 0.3], dtype=np.float32)
        nanny = np.array([1.0, np.nan], dtype=np.float32)
        small = np.arange(-5, 6, dtype=np.int32)
        plan = kops.plan_dc_encodings(
            {"o": jnp.asarray(overflow), "s": jnp.asarray(small)},
            [("o", "o", "<"), ("s", "s", ">")],
        )
        assert plan["o"].kind == "orig" and plan["s"].kind == "int8"
        assert kops.plan_dc_encodings(
            {"x": jnp.asarray(nonint)}, [("x", "x", "<")]
        ) is None
        plan_nan = kops.plan_dc_encodings(
            {"x": jnp.asarray(nanny)}, [("x", "x", "==")]
        )
        assert plan_nan is None or plan_nan["x"].kind == "orig"

    def test_atom_sides_share_kind(self):
        """Both sides of an atom must land on one kind — an int8-able column
        compared against an overflow column demotes to orig."""
        plan = kops.plan_dc_encodings(
            {
                "a": jnp.asarray(np.arange(10, dtype=np.int32)),
                "b": jnp.asarray(np.arange(1000, 1010, dtype=np.int32)),
            },
            [("a", "b", "<")],
        )
        assert plan is None

    def test_encode_decode_roundtrip(self):
        vals = np.array([3.0, -7.0, 3.0, 100.0], dtype=np.float32)
        plan = kops.plan_dc_encodings(
            {"v": jnp.asarray(vals)}, [("v", "v", "==")]
        )
        assert plan["v"].kind == "code"
        codes = kops.encode_column(jnp.asarray(vals), plan["v"])
        dec = kops.decode_stat(
            codes, jnp.ones(4, jnp.int32), plan["v"], np.float32, "min"
        )
        np.testing.assert_array_equal(np.asarray(dec), vals)

    @pytest.mark.parametrize("encode", [True, False])
    def test_bit_identical_through_daisy(self, encode):
        """A DC mixing an encodable column with a boundary one produces the
        same answers and candidate state with the planner on or off."""
        n = 96
        rng = np.random.default_rng(23)
        qty = rng.integers(0, 100, n).astype(np.float32)  # int8-able
        price = rng.uniform(0.0, 500.0, n).astype(np.float32)  # orig
        rel = make_relation(
            {"qty": qty, "price": price}, overlay=["qty", "price"],
            k=8, rules=["qp"],
        )
        dc = DC("qp", [Atom("qty", "<", "qty"), Atom("price", ">", "price")])
        cfg = DaisyConfig(
            use_cost_model=False, accuracy_threshold=2.0,
            dc_block=16, strip_rows=16, dc_partitions=4,
            kernel_encodings=encode,
        )
        daisy = Daisy({"t": rel}, {"t": [dc]}, cfg)
        res = daisy.execute(Query("t", preds=(Pred("qty", ">=", 0.0),)))
        if not hasattr(TestEncodings, "_baseline"):
            TestEncodings._baseline = {}
        base = TestEncodings._baseline
        key_mask = np.asarray(res.mask)
        cand = {
            a: np.asarray(daisy.db["t"].cand[a]) for a in ("qty", "price")
        }
        if "mask" in base:
            np.testing.assert_array_equal(key_mask, base["mask"])
            for a in cand:
                np.testing.assert_array_equal(cand[a], base["cand"][a])
        else:
            base["mask"] = key_mask
            base["cand"] = cand


# ------------------------------------------------------------------ semijoin
class TestSemijoinKernel:
    @pytest.mark.parametrize("n,m", [(5, 7), (64, 64), (100, 257), (513, 100)])
    @pytest.mark.parametrize("block", [64, 256])
    def test_matches_ref(self, n, m, block):
        rng = np.random.default_rng(n * m)
        q = jnp.asarray(rng.integers(0, 40, n).astype(np.int32))
        k = jnp.asarray(rng.integers(0, 40, m).astype(np.int32))
        qm = jnp.asarray(rng.random(n) < 0.8)
        km = jnp.asarray(rng.random(m) < 0.8)
        r = ref.semijoin(q, qm, k, km, block=block)
        p = semijoin_pallas(q, qm, k, km, block=block, interpret=True)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(p))

    def test_matches_numpy(self):
        rng = np.random.default_rng(0)
        q = rng.integers(0, 10, 50).astype(np.int32)
        k = rng.integers(0, 10, 30).astype(np.int32)
        km = rng.random(30) < 0.5
        got = ref.semijoin(
            jnp.asarray(q), jnp.ones(50, bool), jnp.asarray(k), jnp.asarray(km)
        )
        np.testing.assert_array_equal(np.asarray(got), np.isin(q, k[km]))

    def test_empty_key_set(self):
        q = jnp.arange(10, dtype=jnp.int32)
        k = jnp.arange(10, dtype=jnp.int32)
        got = semijoin_pallas(
            q, jnp.ones(10, bool), k, jnp.zeros(10, bool), interpret=True
        )
        assert not np.asarray(got).any()


# ----------------------------------------------------------- flash attention
class TestFlashAttention:
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
    @pytest.mark.parametrize("sq,sk", [(128, 128), (256, 256)])
    def test_causal_matches_ref(self, hq, hkv, sq, sk):
        rng = np.random.default_rng(hq * sq)
        d = 64
        q = jnp.asarray(rng.standard_normal((2, hq, sq, d)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((2, hkv, sk, d)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((2, hkv, sk, d)).astype(np.float32))
        r = ref.attention(q, k, v, causal=True)
        p = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=2e-5, rtol=2e-5)

    def test_sliding_window(self):
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((1, 2, 256, 32)).astype(np.float32))
        r = ref.attention(q, k, v, causal=True, window=64)
        p = flash_attention_pallas(
            q, k, v, causal=True, window=64, block_q=64, block_kv=64, interpret=True
        )
        np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=2e-5, rtol=2e-5)

    def test_noncausal(self):
        rng = np.random.default_rng(6)
        q = jnp.asarray(rng.standard_normal((1, 2, 128, 32)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 2, 128, 32)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((1, 2, 128, 32)).astype(np.float32))
        r = ref.attention(q, k, v, causal=False)
        p = flash_attention_pallas(q, k, v, causal=False, block_q=64, block_kv=64,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=2e-5, rtol=2e-5)

    def test_bf16_io(self):
        rng = np.random.default_rng(7)
        q = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
        r = ref.attention(q, k, v, causal=True)
        p = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64,
                                   interpret=True)
        np.testing.assert_allclose(
            np.asarray(r, np.float32), np.asarray(p, np.float32), atol=3e-2
        )

    def test_softmax_rows_sum_to_one_effect(self):
        """Uniform V must pass through attention unchanged."""
        q = jnp.ones((1, 1, 128, 32), jnp.float32)
        k = jnp.ones((1, 1, 128, 32), jnp.float32)
        v = jnp.full((1, 1, 128, 32), 3.0, jnp.float32)
        p = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_kv=64,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(p), 3.0, rtol=1e-6)
