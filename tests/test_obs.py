"""repro.obs — span tracing, trace export, latency histograms
(DESIGN.md §13).

Covers the histogram's one-bucket percentile bound against a
sorted-sample reference (property-based), the tracer's ring-buffer
bounding and thread-safety under a writer race, the disabled-mode
overhead gate (<= 3% of a cache-hit serve), the Chrome trace-event
schema round-trip, and the bit-neutrality contract: serving answers are
bit-identical with tracing on vs off.
"""

import importlib.util
import json
import math
import os
import threading
import time

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import GroupBySpec, Pred, Query
from repro.core.relation import make_relation
from repro.obs import (
    LatencyHistogram,
    NULL_TRACER,
    SpanEvent,
    Tracer,
    chrome_trace,
    coverage,
    events_from_chrome,
    load_trace,
    rollup,
    top_spans,
    write_trace,
)
from repro.service import QueryServer

SETTINGS = dict(max_examples=25, deadline=None)

# one bucket's width at the default 16 buckets/decade — the histogram's
# documented relative error bound
BUCKET_RATIO = 10.0 ** (1.0 / 16.0)


# ------------------------------------------------------------------ histogram
@settings(**SETTINGS)
@given(
    st.lists(st.integers(1, 10_000_000), min_size=1, max_size=200),
    st.integers(0, 100),
)
def test_histogram_percentile_vs_sorted_reference(micros, q):
    """Reported percentile is >= the true order statistic (upper-edge
    reporting) and within one bucket's width of it."""
    hist = LatencyHistogram()
    samples = [v * 1e-6 for v in micros]  # 1us .. 10s, inside [lo, hi)
    for s in samples:
        hist.observe(s)
    # the order statistic numpy's percentile(method='lower') picks
    ref = sorted(samples)[int(q / 100.0 * (len(samples) - 1))]
    got = hist.percentile(q)
    assert got >= ref * (1.0 - 1e-9)
    assert got <= ref * BUCKET_RATIO * (1.0 + 1e-9)


def test_histogram_edges_and_snapshot():
    hist = LatencyHistogram(lo=1e-3, hi=1.0, buckets_per_decade=4)
    assert hist.percentile(50) == 0.0  # empty
    hist.observe(1e-5)  # underflow reports lo
    assert hist.percentile(0) == hist.lo
    hist.observe(5.0)  # overflow reports the exact observed max
    assert hist.percentile(100) == 5.0
    assert hist.max == 5.0
    assert math.isclose(hist.mean, (1e-5 + 5.0) / 2)
    snap = hist.snapshot()
    assert set(snap) == {"count", "mean_s", "p50_s", "p95_s", "p99_s", "max_s"}
    assert snap["count"] == 2
    json.dumps(snap)  # JSON-serializable


def test_histogram_merge():
    a, b = LatencyHistogram(), LatencyHistogram()
    for v in (0.001, 0.002, 0.004):
        a.observe(v)
    for v in (0.1, 0.2):
        b.observe(v)
    a.merge(b)
    assert a.count == 5
    assert a.max == 0.2
    assert a.percentile(100) >= 0.2 * (1 - 1e-9)
    mismatched = LatencyHistogram(buckets_per_decade=8)
    try:
        a.merge(mismatched)
        raise AssertionError("merge across bucket layouts must fail")
    except ValueError:
        pass


# --------------------------------------------------------------- ring buffer
def test_ring_buffer_keeps_newest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.record("s", float(i), 1.0, seq=i)
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [e.attrs["seq"] for e in tr.events()] == [6, 7, 8, 9]


def test_ring_buffer_thread_safety_under_writer_race():
    tr = Tracer(capacity=64)
    per_thread = 100

    def writer(tag):
        for i in range(per_thread):
            with tr.span("race", tag=tag, i=i):
                pass

    threads = [
        threading.Thread(target=writer, args=(t,), name=f"writer-{t}")
        for t in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    events = tr.events()
    assert len(tr) == 64 and len(events) == 64
    assert tr.dropped == 4 * per_thread - 64
    for ev in events:  # no torn records
        assert ev.name == "race" and ev.dur >= 0.0
        assert ev.thread.startswith("writer-")
        assert 0 <= ev.attrs["i"] < per_thread


def test_null_tracer_strict_noop():
    span = NULL_TRACER.span("x", a=1)
    assert span is NULL_TRACER.span("y")  # one shared context manager
    with span as sp:
        sp.set(late=True)
    NULL_TRACER.record("x", 0.0, 1.0)
    NULL_TRACER.instant("x")
    assert len(NULL_TRACER) == 0 and not NULL_TRACER
    assert NULL_TRACER.events() == []


def test_late_set_attrs_recorded():
    tr = Tracer()
    with tr.span("phase", early=1) as sp:
        sp.set(late=2)
    (ev,) = tr.events()
    assert ev.attrs == {"early": 1, "late": 2}


# ------------------------------------------------------------------- export
def _synthetic_events():
    return [
        SpanEvent("serve.execute", 1.0, 0.5, "serving", {"seq": 0}),
        SpanEvent("clean.detect", 1.1, 0.2, "serving", {"pairs": 42}),
        SpanEvent("bg.yield", 1.3, 0.0, "background-cleaner", {}),
        SpanEvent("serve.queue_wait", 0.9, 0.7, "queue", {"kind": "query"}),
    ]


def test_chrome_trace_schema_and_roundtrip(tmp_path):
    events = _synthetic_events()
    trace = chrome_trace(events, origin=0.5)
    json.dumps(trace)  # Perfetto needs plain JSON
    recs = trace["traceEvents"]
    metas = [r for r in recs if r["ph"] == "M"]
    assert {m["args"]["name"] for m in metas} == {
        "serving", "background-cleaner", "queue",
    }
    complete = [r for r in recs if r["ph"] == "X"]
    assert all(r["ts"] >= 0 and r["dur"] > 0 for r in complete)
    assert [r for r in recs if r["ph"] == "i"]  # the instant survives
    # round-trip back to events: origin-relative, same order/attrs
    back = events_from_chrome(trace)
    assert [e.name for e in back] == [e.name for e in events]
    for orig, rt in zip(events, back):
        assert rt.thread == orig.thread and rt.attrs == orig.attrs
        assert abs(rt.t0 - (orig.t0 - 0.5)) < 1e-9
        assert abs(rt.dur - orig.dur) < 1e-9
    # and through the file API
    path = str(tmp_path / "t.json")
    write_trace(path, events, origin=0.5)
    assert [e.name for e in load_trace(path)] == [e.name for e in events]


def test_rollup_self_time_stack_subtraction():
    events = [
        SpanEvent("parent", 0.0, 10.0, "t1", {}),
        SpanEvent("child", 2.0, 3.0, "t1", {}),
        SpanEvent("child", 6.0, 1.0, "t1", {}),
        # same interval on another thread must NOT subtract from t1's parent
        SpanEvent("other", 2.0, 3.0, "t2", {}),
    ]
    roll = rollup(events)
    assert roll["parent"]["count"] == 1
    assert math.isclose(roll["parent"]["total_s"], 10.0)
    assert math.isclose(roll["parent"]["self_s"], 6.0)  # 10 - 3 - 1
    assert roll["child"]["count"] == 2
    assert math.isclose(roll["child"]["self_s"], 4.0)
    assert math.isclose(roll["other"]["self_s"], 3.0)
    # self-times partition each thread's covered wall-clock
    assert math.isclose(
        sum(a["self_s"] for a in roll.values()), 10.0 + 3.0
    )


def test_coverage_windows_and_exclusion():
    events = [
        SpanEvent("a", 0.0, 1.0, "serving", {}),
        SpanEvent("b", 0.5, 1.0, "serving", {}),  # overlap counted once
        SpanEvent("q", 0.0, 4.0, "queue", {}),
    ]
    assert math.isclose(
        coverage(events, [(0.0, 2.0)], exclude_threads=("queue",)), 0.75
    )
    assert math.isclose(coverage(events, [(0.0, 2.0)]), 1.0)  # queue counts
    assert math.isclose(
        coverage(events, [(0.0, 1.0), (3.0, 4.0)], exclude_threads=("queue",)),
        0.5,
    )
    assert coverage(events, []) == 0.0


def test_top_spans_orders_by_duration():
    events = _synthetic_events()
    top = top_spans(events, k=2)
    assert [e.name for e in top] == ["serve.queue_wait", "serve.execute"]


# ------------------------------------------------- serving: neutrality + cost
def _demo_db():
    return {
        "t": make_relation(
            {
                "zip": np.array([1, 1, 2, 2, 3, 3]),
                "city": np.array([10, 11, 20, 21, 30, 30]),
            },
            overlay=["zip", "city"],
            k=4,
            rules=["zc"],
        )
    }


DEMO_RULES = {"t": [FD("zc", "zip", "city")]}
DEMO_QUERIES = [
    Query("t", preds=(Pred("zip", "==", 1),)),
    Query("t", preds=(Pred("zip", "==", 2),)),
    Query("t", groupby=GroupBySpec(keys=("city",), agg="count")),
]


def _serve_all(tracer):
    daisy = Daisy(
        _demo_db(), DEMO_RULES, DaisyConfig(use_cost_model=False),
        tracer=tracer,
    )
    server = QueryServer(daisy)
    session = server.open_session("u")
    tickets = [server.submit(session, q) for q in DEMO_QUERIES]
    server.drain()
    outs = []
    for t in tickets:
        res = t.result
        if res.groups is not None:
            outs.append({k: np.asarray(v).tolist() for k, v in res.groups.items()})
        else:
            outs.append(np.asarray(res.mask).tolist())
    return outs, daisy.clean_version, server


def test_traced_serving_bit_identical():
    """The bit-neutrality contract: tracing must never change answers or
    versions (DESIGN.md §13) — the traced run IS the untraced run plus
    span records."""
    traced_tracer = Tracer()
    plain, plain_version, _ = _serve_all(NULL_TRACER)
    traced, traced_version, server = _serve_all(traced_tracer)
    assert traced == plain
    assert traced_version == plain_version
    names = {e.name for e in traced_tracer.events()}
    # every serving layer showed up in the one shared trace
    assert {"serve.batch", "serve.cache_lookup", "serve.commit",
            "daisy.execute", "clean.detect", "clean.repair",
            "serve.queue_wait"} <= names
    # and the server surfaced per-class latency percentiles
    lat = server.snapshot()["latency"]
    assert "query" in lat and lat["query"]["count"] == len(DEMO_QUERIES)
    assert lat["query"]["p50_s"] > 0.0


def test_disabled_tracer_overhead_within_3_percent():
    """The untraced serving loop's tracing tax on the hot (cache-hit)
    path is two no-op span sites per ticket — serve.batch and
    serve.cache_lookup; queue-wait is truthiness-gated and commit only
    wraps executed results.  Gate their measured cost at <= 3% of the
    measured cache-hit serve itself (ISSUE 8 acceptance)."""
    daisy = Daisy(_demo_db(), DEMO_RULES, DaisyConfig(use_cost_model=False))
    server = QueryServer(daisy)
    session = server.open_session("u", max_inflight=64)
    q = DEMO_QUERIES[0]
    server.submit(session, q)
    server.drain()  # warm: every later submit is a cache hit

    def best_of(fn, reps=5):
        return min(fn() for _ in range(reps))

    def time_serves():
        n = 20
        t0 = time.perf_counter()
        for _ in range(n):
            server.submit(session, q)
            server.drain()
        return (time.perf_counter() - t0) / n

    def time_null_spans():
        n = 5000
        t0 = time.perf_counter()
        for i in range(n):
            with NULL_TRACER.span("serve.execute", seq=i, table="t") as sp:
                sp.set(hit=True)
        return (time.perf_counter() - t0) / n

    per_serve = best_of(time_serves)
    per_span = best_of(time_null_spans)
    assert per_span * 2 <= 0.03 * per_serve, (
        f"null-span cost {per_span*1e6:.2f}us x2 exceeds 3% of a "
        f"{per_serve*1e6:.0f}us cache-hit serve"
    )


# ------------------------------------------------------------- trace_summary
def test_trace_summary_cli(tmp_path):
    spec = importlib.util.spec_from_file_location(
        "trace_summary",
        os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "tools", "trace_summary.py",
        ),
    )
    trace_summary = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(trace_summary)
    path = str(tmp_path / "t.json")
    write_trace(path, _synthetic_events())
    out = trace_summary.summarize(path, top_k=2)
    assert "serve.execute" in out and "clean.detect" in out
    assert "top 2 slowest" in out
    assert trace_summary.main(["--trace", path, "--top", "1"]) == 0
    empty = str(tmp_path / "empty.json")
    write_trace(empty, [])
    assert trace_summary.summarize(empty).endswith("no spans")
