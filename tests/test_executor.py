"""End-to-end Daisy behaviour (§4-§6): SP queries, incremental cleaning,
offline equivalence, multi-rule merge, group-by, cost-model switch."""

import numpy as np

from repro.core.accuracy import repair_accuracy
from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.offline import OfflineCleaner
from repro.core.operators import GroupBySpec, Pred, Query
from repro.core.relation import make_relation
from tests.conftest import LA, NY, SF


def make_db(cities_rel):
    return {"cities": cities_rel}


def rules_fd():
    return {"cities": [FD("zip_city", "zip", "city")]}


class TestSPQueries:
    def test_rhs_filter_recovers_candidates(self, cities_rel):
        daisy = Daisy(make_db(cities_rel), rules_fd(), DaisyConfig(use_cost_model=False))
        res = daisy.execute(Query("cities", preds=(Pred("city", "==", LA),)))
        # rows 0..2 qualify in some world ({LA, SF} candidates); 10001 rows not
        np.testing.assert_array_equal(
            np.asarray(res.mask), [True, True, True, False, False]
        )
        step = res.report.steps[0]
        assert step.mode == "incremental"
        assert step.repaired > 0

    def test_lhs_filter_transitive(self, cities_rel):
        daisy = Daisy(make_db(cities_rel), rules_fd(), DaisyConfig(use_cost_model=False))
        res = daisy.execute(Query("cities", preds=(Pred("zip", "==", 9001),)))
        # row 1's zip candidates {9001, 10001} keep it qualifying; clean rows
        # 3/4 only qualify if their zip overlay includes 9001 (it does not)
        m = np.asarray(res.mask)
        assert m[:3].all()

    def test_second_query_skips_checked(self, cities_rel):
        daisy = Daisy(make_db(cities_rel), rules_fd(), DaisyConfig(use_cost_model=False))
        daisy.execute(Query("cities", preds=(Pred("zip", "==", 9001),)))
        res2 = daisy.execute(Query("cities", preds=(Pred("zip", "==", 9001),)))
        # every touched tuple was already checked -> no new repairs
        assert res2.report.steps[0].repaired == 0

    def test_dirty_group_skip(self):
        """Fig. 11 statistics: a query touching only clean groups skips
        relaxation/detection entirely."""
        rel = make_relation(
            {"zip": np.array([1, 1, 2, 2, 3]), "city": np.array([LA, SF, NY, NY, LA])},
            overlay=["zip", "city"],
            rules=["zip_city"],
        )
        daisy = Daisy({"cities": rel}, rules_fd(), DaisyConfig(use_cost_model=False))
        res = daisy.execute(Query("cities", preds=(Pred("zip", "==", 2),)))
        assert res.report.steps[0].mode == "skipped"
        res2 = daisy.execute(Query("cities", preds=(Pred("zip", "==", 1),)))
        assert res2.report.steps[0].mode == "incremental"

    def test_groupby_pushdown_full_clean(self, cities_rel):
        daisy = Daisy(make_db(cities_rel), rules_fd(), DaisyConfig(use_cost_model=False))
        res = daisy.execute(
            Query("cities", groupby=GroupBySpec(keys=("city",), agg="count"))
        )
        assert res.report.steps[0].mode == "full"
        keys = np.asarray(res.groups["key_city"])
        counts = np.asarray(res.groups["count"])
        got = {int(k): float(c) for k, c in zip(keys, counts) if c > 0}
        # expected-value semantics: 9001 group contributes {LA 2/3, SF 1/3}
        # per row (3 rows), 10001 group {SF .5, NY .5} per row (2 rows)
        np.testing.assert_allclose(got[LA], 3 * 2 / 3, atol=1e-5)
        np.testing.assert_allclose(got[SF], 3 * 1 / 3 + 2 * 0.5, atol=1e-5)
        np.testing.assert_allclose(got[NY], 2 * 0.5, atol=1e-5)
        # probability mass conserved
        np.testing.assert_allclose(sum(got.values()), 5.0, atol=1e-5)


class TestOfflineEquivalence:
    """Contribution 1: Daisy's answers == offline answers for FDs."""

    def test_fd_masks_match(self, cities_rel):
        queries = [
            Query("cities", preds=(Pred("city", "==", LA),)),
            Query("cities", preds=(Pred("zip", "==", 9001),)),
            Query("cities", preds=(Pred("zip", "==", 10001),)),
            Query("cities", preds=(Pred("city", "!=", NY),)),
        ]
        daisy = Daisy(make_db(cities_rel), rules_fd(), DaisyConfig(use_cost_model=False))
        off = OfflineCleaner(make_db(cities_rel), rules_fd())
        off.clean_all()
        for q in queries:
            m_d = np.asarray(daisy.execute(q).mask)
            m_o = np.asarray(off.execute(q).mask)
            np.testing.assert_array_equal(m_d, m_o, err_msg=str(q))

    def test_fd_candidate_probabilities_match(self, cities_rel):
        daisy = Daisy(make_db(cities_rel), rules_fd(), DaisyConfig(use_cost_model=False))
        off = OfflineCleaner(make_db(cities_rel), rules_fd())
        off.clean_all()
        # after a workload covering the dataset, overlays must agree
        daisy.execute(Query("cities", preds=(Pred("zip", "==", 9001),)))
        daisy.execute(Query("cities", preds=(Pred("zip", "==", 10001),)))
        for attr in ("city", "zip"):
            p_d = np.asarray(daisy.db["cities"].probs(attr))
            p_o = np.asarray(off.db["cities"].probs(attr))
            # compare per-row candidate distributions as value->prob maps
            v_d = np.asarray(daisy.db["cities"].cand[attr])
            v_o = np.asarray(off.db["cities"].cand[attr])
            for r in range(5):
                d = {int(v): round(float(p), 5) for v, p in zip(v_d[r], p_d[r]) if p > 0}
                o = {int(v): round(float(p), 5) for v, p in zip(v_o[r], p_o[r]) if p > 0}
                assert d == o, f"{attr} row {r}: {d} != {o}"


class TestMultiRule:
    def test_two_rules_both_applied(self):
        rel = make_relation(
            {
                "zip": np.array([1, 1, 2, 2]),
                "city": np.array([LA, SF, NY, NY]),
                "state": np.array([7, 7, 8, 9]),
            },
            overlay=["zip", "city", "state"],
            rules=["r1", "r2"],
        )
        rules = {"t": [FD("r1", "zip", "city"), FD("r2", "zip", "state")]}
        daisy = Daisy({"t": rel}, rules, DaisyConfig(use_cost_model=False))
        res = daisy.execute(Query("t", preds=(Pred("zip", "==", 1),)))
        assert len(res.report.steps) == 2
        # r1 repaired rows 0/1 (city conflict); r2 rows 2/3 untouched by zip=1
        rel2 = daisy.db["t"]
        assert np.asarray(rel2.is_uncertain("city"))[:2].all()

    def test_rule_order_commutes(self):
        """Lemma 4 at the system level: executing the rules in either order
        yields identical candidate distributions."""
        def build():
            return make_relation(
                {
                    "a": np.array([1, 1, 2, 2, 1]),
                    "b": np.array([5, 6, 7, 7, 5]),
                    "c": np.array([9, 9, 3, 4, 8]),
                },
                overlay=["a", "b", "c"],
                rules=["p", "q"],
            )

        p, q = FD("p", "a", "b"), FD("q", "b", "c")
        d1 = Daisy({"t": build()}, {"t": [p, q]}, DaisyConfig(use_cost_model=False))
        d2 = Daisy({"t": build()}, {"t": [q, p]}, DaisyConfig(use_cost_model=False))
        full = Query("t", preds=(Pred("a", ">=", 0),))
        d1.execute(full)
        d2.execute(full)
        for attr in ("a", "b", "c"):
            r1, r2 = d1.db["t"], d2.db["t"]
            for row in range(5):
                m1 = {
                    (int(v), round(float(pp), 5))
                    for v, pp in zip(
                        np.asarray(r1.cand[attr])[row], np.asarray(r1.probs(attr))[row]
                    )
                    if pp > 0
                }
                m2 = {
                    (int(v), round(float(pp), 5))
                    for v, pp in zip(
                        np.asarray(r2.cand[attr])[row], np.asarray(r2.probs(attr))[row]
                    )
                    if pp > 0
                }
                assert m1 == m2, f"{attr} row {row}"


class TestDCExecution:
    def test_dc_query_auto_mode(self, salary_rel, dc_sal_tax):
        daisy = Daisy(
            {"t": salary_rel},
            {"t": [dc_sal_tax]},
            DaisyConfig(use_cost_model=False, dc_partitions=4),
        )
        res = daisy.execute(Query("t", preds=(Pred("salary", ">=", 2000.0),)))
        step = res.report.steps[0]
        assert step.mode in ("incremental", "full")
        # the violating rows got their range candidates
        rel = daisy.db["t"]
        assert np.asarray(rel.is_uncertain("salary"))[1] or np.asarray(
            rel.is_uncertain("salary")
        )[2]


class TestAccuracy:
    def test_precision_recall(self, cities_rel):
        rules = rules_fd()
        daisy = Daisy(make_db(cities_rel), rules, DaisyConfig(use_cost_model=False))
        daisy.execute(Query("cities", preds=(Pred("zip", "==", 9001),)))
        daisy.execute(Query("cities", preds=(Pred("zip", "==", 10001),)))
        import jax.numpy as jnp

        truth = {"city": jnp.asarray(np.array([LA, LA, LA, SF, SF]))}
        acc = repair_accuracy(daisy.db["cities"], truth, ["city"])
        # row 1 repaired SF->LA (majority): correct. 10001 group is a 50/50
        # tie -> repaired_value keeps the heavier-or-first candidate.
        assert acc.errors == 2
        assert acc.correct >= 1
        assert 0 <= acc.precision <= 1 and 0 <= acc.recall <= 1
