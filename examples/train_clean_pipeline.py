"""End-to-end driver: train a small LM whose data pipeline cleans itself.

Every batch request is a metadata query ("docs in language L with quality
>= q"); Daisy's cleaning operators run inside the query plan, so label
errors (FD source -> language violations) are repaired on-demand while the
model trains — the paper's query-driven regime with the training loop as
the workload.

Run:  PYTHONPATH=src python examples/train_clean_pipeline.py  (~2 min CPU)
"""

import time

import jax

from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, default_pipeline
from repro.models.params import init_params
from repro.train.optim import OptConfig, init_opt_state
from repro.train.steps import make_train_step

STEPS = 60

cfg = get_config("qwen3-4b", reduced=True).canonicalize(tp=1)
pipe, workload = default_pipeline(
    n_docs=512,
    cfg=PipelineConfig(batch_docs=8, seq_len=64, vocab_size=512),
)

params = init_params(jax.random.key(0), cfg)
opt_cfg = OptConfig(lr=1e-3, total_steps=STEPS, warmup_steps=10)
opt = init_opt_state(params, opt_cfg)
step = jax.jit(make_train_step(cfg, opt_cfg, n_micro=1, mamba_chunk=32),
               donate_argnums=(0, 1))

t0 = time.time()
losses = []
for i, batch in enumerate(pipe.batches(workload, STEPS)):
    params, opt, metrics = step(params, opt, batch)
    losses.append(float(metrics["loss"]))
    if i % 10 == 0:
        print(f"step {i:3d}  loss {losses[-1]:.3f}  "
              f"cleaned {pipe.cleaning_progress()}")

print(f"\n{STEPS} steps in {time.time()-t0:.1f}s")
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} (should decrease)")
print(f"metadata cleaning progress: {pipe.cleaning_progress()}")
print(f"queries executed by the pipeline: {pipe.queries_run}")
assert losses[-1] < losses[0], "training failed to reduce loss"
