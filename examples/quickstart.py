"""Quickstart: clean a dirty relation through queries (the paper's core).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import Dictionary, make_relation

# --- Table 2a of the paper: the Cities dataset --------------------------
city = Dictionary(["Los Angeles", "San Francisco", "New York"])
rel = make_relation(
    {
        "zip": np.array([9001, 9001, 9001, 10001, 10001]),
        "city": city.encode_many(
            ["Los Angeles", "San Francisco", "Los Angeles",
             "San Francisco", "New York"]
        ),
    },
    overlay=["zip", "city"],
    k=4,
    rules=["zip_city"],
)

# --- a Daisy engine with the FD zip -> city ------------------------------
daisy = Daisy(
    {"cities": rel},
    {"cities": [FD("zip_city", "zip", "city")]},
    DaisyConfig(use_cost_model=False),
)

# --- Example 2's query: which zip is Los Angeles? ------------------------
res = daisy.execute(
    Query("cities", preds=(Pred("city", "==", city.encode("Los Angeles")),))
)
print("qualifying rows :", np.flatnonzero(np.asarray(res.mask)).tolist())
print("cleaning steps  :", [(s.rule, s.mode, s.repaired) for s in res.report.steps])

# --- the dataset is now (partially) probabilistic — Table 2b -------------
cleaned = daisy.db["cities"]
probs = np.asarray(cleaned.probs("city"))
vals = np.asarray(cleaned.cand["city"])
for row in range(5):
    cands = {
        city.decode(v): round(float(p), 2)
        for v, p in zip(vals[row], probs[row]) if p > 0
    }
    print(f"row {row}: city candidates {cands or '(clean)'}")
