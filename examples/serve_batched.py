"""Serve a small model with continuous batching (vLLM-style slots).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine

cfg = get_config("gemma3-12b", reduced=True).canonicalize(tp=1)
params = init_params(jax.random.key(1), cfg)
engine = ServeEngine(cfg, params, max_batch=4, max_seq=96)

rng = np.random.default_rng(7)
requests = []
for rid in range(8):  # 8 requests through 4 slots -> continuous batching
    prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(4, 10)))
    req = Request(rid=rid, prompt=prompt.astype(np.int32), max_new=12)
    requests.append(req)
    engine.submit(req)

t0 = time.time()
engine.run()
dt = time.time() - t0
done = sum(r.done for r in requests)
toks = sum(len(r.out) for r in requests)
print(f"completed {done}/8 requests, {toks} new tokens in {dt:.1f}s")
for r in requests[:4]:
    print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> {r.out}")
assert done == 8
