"""Quickstart: serve concurrent analytical queries over one gradually-
cleaned instance (the repro.service subsystem, DESIGN.md §9).

Three analysts share a dirty Cities table.  Their queries drive the
cleaning (the paper's on-demand model); the service batches overlapping
queries so one detect/repair pass pays for everyone, and the clean-state-
aware cache answers repeats without touching the executor.

Run:  PYTHONPATH=src python examples/serve_queries.py
"""

import numpy as np

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import Dictionary, make_relation
from repro.service import QueryServer

city = Dictionary(["Los Angeles", "San Francisco", "New York"])
rel = make_relation(
    {
        "zip": np.array([9001, 9001, 9001, 10001, 10001]),
        "city": city.encode_many(
            ["Los Angeles", "San Francisco", "Los Angeles",
             "San Francisco", "New York"]
        ),
    },
    overlay=["zip", "city"],
    k=4,
    rules=["zip_city"],
)
daisy = Daisy(
    {"cities": rel},
    {"cities": [FD("zip_city", "zip", "city")]},
    DaisyConfig(use_cost_model=False),
)

server = QueryServer(daisy)
analysts = [server.open_session(name) for name in ("ana", "ben", "cho")]

# everyone explores the same neighborhoods — overlapping σ, repeated queries
la = Query("cities", preds=(Pred("city", "==", city.encode("Los Angeles")),))
ny_zip = Query("cities", preds=(Pred("zip", "==", 10001),))
tickets = []
for analyst in analysts:
    tickets.append(server.submit(analyst, la))
    tickets.append(server.submit(analyst, ny_zip))
for analyst in analysts:
    tickets.append(server.submit(analyst, la))  # repeat -> cache

server.drain()

for t in tickets:
    rows = np.flatnonzero(np.asarray(t.result.mask)).tolist()
    print(f"{t.session.sid}: rows {rows} "
          f"({'cache' if t.cached else 'executed'} @v{t.clean_version})")

snap = server.snapshot()
print(f"queries={snap['queries']} executions={snap['executions']} "
      f"cache hits={snap['cache_hits']} detect calls={snap['detect_calls']} "
      f"(amortized {snap['detect_repair_per_query']}/query)")
print("per-session lineage:", [s["cached_answers"] for s in snap["sessions"]],
      "answers from cache")
