"""Quickstart: serve concurrent analytical queries over one gradually-
cleaned instance (the repro.service subsystem, DESIGN.md §9/§10).

Three analysts share a dirty Cities table.  Their queries drive the
cleaning (the paper's on-demand model); the service batches overlapping
queries so one detect/repair pass pays for everyone, and the clean-state-
aware cache answers repeats without touching the executor.  Between
bursts, the background cleaner warms whatever is still cold so the next
first-touch query pays no detect latency (cooperative form — see the
README "Operating the service" section for the threaded form).

Run:  PYTHONPATH=src python examples/serve_queries.py
"""

import numpy as np

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig
from repro.core.operators import Pred, Query
from repro.core.relation import Dictionary, make_relation
from repro.launch.serve import ServeOptions
from repro.service import BackgroundCleaner, QueryServer

# the serving knobs live in ONE bundle shared with the CLI driver
# (repro.launch.serve) and the serving benchmarks, so "increment_rows"
# here means exactly what --increment-rows means there
opts = ServeOptions(sessions=3, rows=5, max_batch=8,
                    increment_rows=8, increment_strips=1)

city = Dictionary(["Los Angeles", "San Francisco", "New York", "Boston"])
rel = make_relation(
    {
        "zip": np.array([9001, 9001, 9001, 10001, 10001]),
        "city": city.encode_many(
            ["Los Angeles", "San Francisco", "Los Angeles",
             "New York", "Boston"]
        ),
    },
    overlay=["zip", "city"],
    k=4,
    rules=["zip_city"],
)
daisy = Daisy(
    {"cities": rel},
    {"cities": [FD("zip_city", "zip", "city")]},
    DaisyConfig(use_cost_model=False),
)

server = QueryServer(daisy, max_batch=opts.max_batch)
analysts = [server.open_session(name)
            for name in ("ana", "ben", "cho")[: opts.sessions]]

# everyone explores the same neighborhood — overlapping σ, repeated queries
# (nobody touches the 10001 cluster yet: it stays cold)
la = Query("cities", preds=(Pred("city", "==", city.encode("Los Angeles")),))
ny_zip = Query("cities", preds=(Pred("zip", "==", 10001),))
tickets = []
for analyst in analysts:
    tickets.append(server.submit(analyst, la))
for analyst in analysts:
    tickets.append(server.submit(analyst, la))  # repeat -> cache

server.drain()

for t in tickets:
    rows = np.flatnonzero(np.asarray(t.result.mask)).tolist()
    print(f"{t.session.sid}: rows {rows} "
          f"({'cache' if t.cached else 'executed'} @v{t.clean_version})")

snap = server.snapshot()
print(f"queries={snap['queries']} executions={snap['executions']} "
      f"cache hits={snap['cache_hits']} detect calls={snap['detect_calls']} "
      f"(amortized {snap['detect_repair_per_query']}/query)")
print("per-session lineage:", [s["cached_answers"] for s in snap["sessions"]],
      "answers from cache")

# idle window: the background cleaner warms the zip=10001 cluster nobody
# queried, so its first-touch query skips the cleaning steps entirely.
# increment_rows bounds one FD increment (whole lhs groups);
# increment_strips is the DC analogue — work-ledger strips per increment
# (DESIGN.md §11) — unused by this FD-only table but the knob to reach
# for when a DC scope must background-clean with bounded pauses.
cleaner = BackgroundCleaner(daisy, server=server,
                            increment_rows=opts.increment_rows,
                            increment_strips=opts.increment_strips)
increments = cleaner.drain()
d0 = server.metrics.detect_calls
t = server.submit(analysts[0], ny_zip)
server.drain()
snap = server.snapshot()
bg = snap["background"]
print(f"background: {increments} increments ({bg['detect_calls']} detects), "
      f"then first-touch zip=10001 served with "
      f"{server.metrics.detect_calls - d0} foreground detects "
      f"(rows {np.flatnonzero(np.asarray(t.result.mask)).tolist()})")
print("warmup progress:",
      {scope: f"{p['strips_done']}/{p['strips_total']} strips"
       for scope, p in snap["ledger"].items()})

# streaming ingest (DESIGN.md §12): two new listings for the 10001 cluster
# arrive through the SAME ticket queue — the append is a batch barrier, so
# the re-issued ny_zip query after it sees the grown instance (the cache
# entry for ny_zip is invalidated by the table's __rows__ version bump,
# nothing else is)
ingest_t = server.ingest("cities", {
    "zip": np.array([10001, 10001]),
    "city": city.encode_many(["New York", "Boston"]),
})
t2 = server.submit(analysts[0], ny_zip)
server.drain()
rep = ingest_t.result
print(f"ingested {rep.rows} rows at position {rep.start} "
      f"(capacity {rep.capacity_before} -> {rep.capacity}), "
      f"ny_zip now rows {np.flatnonzero(np.asarray(t2.result.mask)).tolist()} "
      f"({'cache' if t2.cached else 'executed'})")
