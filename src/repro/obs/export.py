"""Trace export and per-phase cost rollups (DESIGN.md §13).

``chrome_trace`` renders a tracer's events as Chrome trace-event JSON
(the ``{"traceEvents": [...]}`` container), loadable directly in
Perfetto / ``chrome://tracing``: one complete-event (``"ph": "X"``) per
span with microsecond timestamps relative to the tracer's creation, one
track per recording thread (plus synthetic tracks like the server's
queue-wait), and the span attrs under ``args``.  ``events_from_chrome``
inverts it, so a dumped trace round-trips back into ``SpanEvent``s for
offline analysis (tools/trace_summary.py).

``rollup`` is the per-phase cost attribution: for every span name, the
inclusive total, the **exclusive self-time** (inclusive minus the time
spent in child spans — computed by stack subtraction per thread, valid
because spans on one thread are well-nested, see obs/trace.py), the
count, and the slowest instance.  Self-times of all phases sum to the
wall-clock the trace actually covers, which is what lets the serving
benchmarks gate "the rollup explains >= 90% of the serving loop"
(``coverage``).
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.trace import SpanEvent


def chrome_trace(events: Iterable[SpanEvent], origin: float = 0.0) -> Dict:
    """Chrome trace-event JSON object for a list of spans.

    ``origin`` (a ``perf_counter`` value, typically ``Tracer.created``)
    becomes timestamp zero.  Zero-duration events export as instants
    (``"ph": "i"``); thread tracks carry name metadata so Perfetto labels
    them."""
    tids: Dict[str, int] = {}
    out: List[Dict] = []
    for ev in events:
        tid = tids.setdefault(ev.thread, len(tids) + 1)
        rec = {
            "name": ev.name,
            "cat": ev.name.partition(".")[0],
            "ts": (ev.t0 - origin) * 1e6,
            "pid": 1,
            "tid": tid,
            "args": dict(ev.attrs),
        }
        if ev.dur > 0.0:
            rec["ph"] = "X"
            rec["dur"] = ev.dur * 1e6
        else:
            rec["ph"] = "i"
            rec["s"] = "t"
        out.append(rec)
    meta = [
        {
            "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
            "args": {"name": thread},
        }
        for thread, tid in tids.items()
    ]
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def events_from_chrome(trace: Dict) -> List[SpanEvent]:
    """Invert ``chrome_trace``: rebuild ``SpanEvent``s (seconds, origin-
    relative) from a trace-event JSON object."""
    names = {
        rec["tid"]: rec["args"]["name"]
        for rec in trace.get("traceEvents", ())
        if rec.get("ph") == "M" and rec.get("name") == "thread_name"
    }
    out = []
    for rec in trace.get("traceEvents", ()):
        if rec.get("ph") not in ("X", "i"):
            continue
        out.append(SpanEvent(
            name=rec["name"],
            t0=rec["ts"] / 1e6,
            dur=rec.get("dur", 0.0) / 1e6,
            thread=names.get(rec.get("tid"), str(rec.get("tid"))),
            attrs=dict(rec.get("args", {})),
        ))
    return out


def write_trace(path: str, events: Iterable[SpanEvent],
                origin: float = 0.0) -> str:
    """Dump a Perfetto-loadable trace JSON to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(chrome_trace(events, origin=origin), f)
    return path


def load_trace(path: str) -> List[SpanEvent]:
    """Load a trace written by ``write_trace`` back into events."""
    with open(path) as f:
        return events_from_chrome(json.load(f))


def rollup(events: Iterable[SpanEvent]) -> Dict[str, Dict[str, float]]:
    """Per-phase attribution: name -> {count, total_s, self_s, max_s}.

    ``total_s`` is inclusive; ``self_s`` subtracts each span's direct
    children (per-thread stack walk over t0-sorted spans), so self-times
    across phases partition the covered wall-clock without double
    counting nested phases (clean.detect inside serve.execute inside a
    step).  Phases whose spans carry tile attrs (the block-sparse DC
    scans, DESIGN.md §15) additionally aggregate ``tiles_launched`` /
    ``tiles_skipped`` sums, so the rollup attributes launch work, not
    just wall-clock."""
    by_thread: Dict[str, List[SpanEvent]] = {}
    for ev in events:
        by_thread.setdefault(ev.thread, []).append(ev)
    out: Dict[str, Dict[str, float]] = {}
    for spans in by_thread.values():
        spans.sort(key=lambda e: (e.t0, -e.dur))
        stack: List[Tuple[float, SpanEvent]] = []  # (end, span)
        selfs = {id(ev): ev.dur for ev in spans}
        for ev in spans:
            while stack and stack[-1][0] <= ev.t0 + 1e-12:
                stack.pop()
            if stack:
                selfs[id(stack[-1][1])] -= ev.dur
            stack.append((ev.t0 + ev.dur, ev))
        for ev in spans:
            agg = out.setdefault(
                ev.name, {"count": 0, "total_s": 0.0, "self_s": 0.0, "max_s": 0.0}
            )
            agg["count"] += 1
            agg["total_s"] += ev.dur
            agg["self_s"] += max(selfs[id(ev)], 0.0)
            agg["max_s"] = max(agg["max_s"], ev.dur)
            for key in ("tiles_launched", "tiles_skipped"):
                val = ev.attrs.get(key)
                if isinstance(val, (int, float)):
                    agg[key] = agg.get(key, 0) + int(val)
    return out


def _merge_intervals(iv: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    iv.sort()
    merged: List[Tuple[float, float]] = []
    for lo, hi in iv:
        if merged and lo <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], hi))
        else:
            merged.append((lo, hi))
    return merged


def coverage(
    events: Iterable[SpanEvent],
    windows: Sequence[Tuple[float, float]],
    exclude_threads: Optional[Sequence[str]] = None,
) -> float:
    """Fraction of the wall-clock ``windows`` (perf_counter intervals)
    covered by the union of span intervals — the "does the trace explain
    where the time went" gate.  ``exclude_threads`` drops synthetic
    tracks (queue-wait overlaps real serving spans by construction)."""
    excl = set(exclude_threads or ())
    spans = _merge_intervals(
        [(e.t0, e.t0 + e.dur) for e in events if e.dur > 0 and e.thread not in excl]
    )
    wins = _merge_intervals([(lo, hi) for lo, hi in windows if hi > lo])
    total = sum(hi - lo for lo, hi in wins)
    if total <= 0.0:
        return 0.0
    covered = 0.0
    i = 0
    for wlo, whi in wins:
        while i < len(spans) and spans[i][1] <= wlo:
            i += 1
        j = i
        while j < len(spans) and spans[j][0] < whi:
            covered += min(spans[j][1], whi) - max(spans[j][0], wlo)
            j += 1
    return covered / total


def top_spans(events: Iterable[SpanEvent], k: int = 10) -> List[SpanEvent]:
    """The ``k`` slowest individual spans, slowest first."""
    return sorted(events, key=lambda e: e.dur, reverse=True)[:k]


def format_rollup(roll: Dict[str, Dict[str, float]]) -> str:
    """Human-readable per-phase table, largest self-time first.  Phases
    that aggregated tile attrs get a trailing launched/skipped column."""
    tiles = any("tiles_launched" in agg for agg in roll.values())
    header = f"{'phase':<28} {'count':>7} {'total':>10} {'self':>10} {'max':>10}"
    if tiles:
        header += f" {'tiles l/s':>17}"
    lines = [header]
    for name, agg in sorted(roll.items(), key=lambda kv: -kv[1]["self_s"]):
        line = (
            f"{name:<28} {agg['count']:>7d} {agg['total_s']*1e3:>8.1f}ms "
            f"{agg['self_s']*1e3:>8.1f}ms {agg['max_s']*1e3:>8.1f}ms"
        )
        if tiles and "tiles_launched" in agg:
            line += (
                f" {int(agg['tiles_launched']):>8d}/"
                f"{int(agg.get('tiles_skipped', 0)):<8d}"
            )
        lines.append(line)
    return "\n".join(lines)
