"""Span tracing for the serving stack (DESIGN.md §13).

One ``Tracer`` is threaded through every layer that does attributable
work — the executor's clean phases, the server's per-ticket serving
stages, the background cleaner's increments, the sharded detection's
shuffle/scan — and collects ``SpanEvent`` records into a thread-safe
bounded ring buffer.  Everything here is host-side stdlib: recording a
span never touches jax, never syncs a device value, and never changes
what the instrumented code computes (the bit-neutrality contract,
asserted by tests/test_obs.py).

Clock and thread contract:

* timestamps are ``time.perf_counter()`` — one monotone clock shared by
  every thread, so spans from the serving thread, the background cleaner
  and the shuffle path order correctly against each other;
* a span belongs to the thread that closed it, and spans on one thread
  are well-nested (context managers) — which is what lets
  ``obs.export.rollup`` compute exclusive self-times by stack
  subtraction.  Events recorded with an explicit ``thread`` (the
  server's queue-wait spans, which overlap many serving spans) live on
  their own synthetic track precisely to keep the real threads' nesting
  intact.

Disabled mode is a strict no-op: ``NULL_TRACER.span(...)`` returns one
shared, immutable context manager and records nothing — no allocation
beyond the kwargs dict at the call site, no lock, no branch in
``__enter__``/``__exit__``.  Layers default their ``tracer`` seam to
``NULL_TRACER``, so an untraced serving loop pays only that call
overhead (gated at <= 3% of a cache-hit serve in tests/test_obs.py).
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, NamedTuple, Optional


class SpanEvent(NamedTuple):
    """One closed span: ``t0``/``dur`` on the monotone clock
    (``time.perf_counter``), ``thread`` the recording thread's name (or
    the explicit track for externally-timed events), ``attrs`` host-
    scalar annotations (mode, detect_pairs, strip ranges, ...)."""

    name: str
    t0: float
    dur: float
    thread: str
    attrs: Dict[str, object]


class _NullSpan:
    """The shared disabled-mode context manager: enter/exit do nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        """Ignore late attribute annotations (disabled mode)."""


_NULL_SPAN = _NullSpan()


class _Span:
    """Context manager for one live span; records into its tracer on exit
    (the span's thread is whichever thread exits it)."""

    __slots__ = ("_tracer", "name", "attrs", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, object]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> None:
        """Annotate the span after entry (e.g. a detect path only known
        once dispatch resolved)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer.record(
            self.name, self.t0, time.perf_counter() - self.t0, **self.attrs
        )
        return False


class Tracer:
    """Thread-safe bounded span recorder.

    ``capacity`` bounds the ring buffer: the newest ``capacity`` events
    are kept, older ones are dropped oldest-first (``dropped`` counts
    them), so a long-lived traced server has bounded memory.  All
    mutation happens under one lock; ``span``/``record``/``instant`` are
    safe from any thread.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        if capacity < 1:
            raise ValueError("tracer capacity must be >= 1")
        self.capacity = capacity
        self.enabled = enabled
        self.created = time.perf_counter()
        self.dropped = 0
        self._lock = threading.Lock()
        self._events: List[SpanEvent] = []
        self._head = 0  # ring start once the buffer saturates

    def __bool__(self) -> bool:
        """Truthiness == enabled, so hot paths can gate optional work
        (building an attrs dict) with ``if tracer:``."""
        return self.enabled

    def span(self, name: str, **attrs):
        """Open a span context manager; the event is recorded when the
        ``with`` block exits.  Returns the shared no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def record(self, name: str, t0: float, dur: float,
               thread: Optional[str] = None, **attrs) -> None:
        """Record one externally-timed span (``t0`` must come from
        ``time.perf_counter``).  ``thread`` overrides the track — pass a
        synthetic name for events that overlap a real thread's nesting
        (the server's queue-wait spans)."""
        if not self.enabled:
            return
        event = SpanEvent(
            name, t0, dur,
            thread if thread is not None else threading.current_thread().name,
            attrs,
        )
        with self._lock:
            if len(self._events) < self.capacity:
                self._events.append(event)
            else:
                self._events[self._head] = event
                self._head = (self._head + 1) % self.capacity
                self.dropped += 1

    def instant(self, name: str, **attrs) -> None:
        """Record a zero-duration marker (a yield, an overflow retry)."""
        self.record(name, time.perf_counter(), 0.0, **attrs)

    def events(self) -> List[SpanEvent]:
        """Snapshot of buffered events in recording order (thread-safe)."""
        with self._lock:
            return self._events[self._head:] + self._events[:self._head]

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def clear(self) -> None:
        """Drop all buffered events (the ``dropped`` counter survives as
        a lifetime total)."""
        with self._lock:
            self._events = []
            self._head = 0


class NullTracer(Tracer):
    """The always-disabled tracer every instrumentation seam defaults to.

    A real (if degenerate) ``Tracer``, so ``isinstance`` checks and the
    full API hold; ``span`` short-circuits to the shared no-op via the
    base class's ``enabled`` gate and ``record`` drops everything."""

    def __init__(self):
        super().__init__(capacity=1, enabled=False)


NULL_TRACER = NullTracer()
