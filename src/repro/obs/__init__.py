"""repro.obs — span tracing, latency histograms, and per-phase cost
attribution for the serving stack (DESIGN.md §13).

The paper's thesis is that cleaning cost is driven by — and should be
attributed to — the analysis workload; this package is the layer that
makes the attribution observable.  Three pieces, all host-side stdlib
(recording never touches jax and never changes answers or clean
versions — the bit-neutrality contract, gated in tests/test_obs.py):

* ``trace``   ``Tracer.span(name, **attrs)`` context managers writing
              ``(name, t0, dur, thread, attrs)`` events on the monotone
              clock into a thread-safe bounded ring buffer; disabled
              mode (``NULL_TRACER``) is a strict no-op;
* ``hist``    fixed-bucket log-scale ``LatencyHistogram`` giving
              p50/p95/p99 without retaining samples — what
              ``ServiceMetrics.snapshot()["latency"]`` reports per
              ticket class, the prerequisite for SLO classes;
* ``export``  Chrome trace-event (Perfetto-loadable) JSON export, the
              per-phase ``rollup`` with exclusive self-times, and the
              wall-clock ``coverage`` gate the serving benchmarks
              enforce.

Instrumented seams: ``Daisy(tracer=...)`` (clean-step phases: relax /
detect / repair / mark, ingest deltas), ``QueryServer(tracer=...)``
(queue-wait, batch formation, cache lookup, execute, commit, ingest
barriers), ``BackgroundCleaner(tracer=...)`` (increments, yields,
preemption waits), and the sharded detection path (shuffle, per-shard
scan, overflow retries).  ``repro.launch.serve --trace out.json`` wires
them all and dumps the trace; ``tools/trace_summary.py`` reads it back.
"""

from repro.obs.export import (
    chrome_trace,
    coverage,
    events_from_chrome,
    format_rollup,
    load_trace,
    rollup,
    top_spans,
    write_trace,
)
from repro.obs.hist import LatencyHistogram
from repro.obs.trace import NULL_TRACER, NullTracer, SpanEvent, Tracer

__all__ = [
    "LatencyHistogram",
    "NULL_TRACER",
    "NullTracer",
    "SpanEvent",
    "Tracer",
    "chrome_trace",
    "coverage",
    "events_from_chrome",
    "format_rollup",
    "load_trace",
    "rollup",
    "top_spans",
    "write_trace",
]
