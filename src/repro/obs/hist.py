"""Fixed-bucket log-scale latency histograms (DESIGN.md §13).

``LatencyHistogram`` yields p50/p95/p99 without retaining samples: counts
land in geometrically-spaced buckets, so memory is O(buckets) forever and
a reported percentile is correct to within one bucket's width (ratio
``2^(1/buckets_per_decade 3.32...)`` — ~15% relative at the default 16
per decade, which is plenty to tell a 2ms cache hit from a 200ms cold
execute).  ``tests/test_obs.py`` property-tests the bound against a
sorted-sample reference.

Everything is host-side stdlib and internally locked: the serving thread
observes query/ingest latencies while the cleaner thread observes
increment latencies, and ``ServiceMetrics.snapshot`` reads percentiles
concurrently (DESIGN.md §9's metrics contract).
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List


class LatencyHistogram:
    """Log-scale bucket histogram over seconds.

    Buckets span ``[lo, hi)`` with ``buckets_per_decade`` geometric
    buckets per power of ten, plus an underflow and an overflow bucket;
    ``count``/``total``/``max`` are tracked exactly, so means are not
    quantized — only percentiles are (to one bucket).
    """

    def __init__(self, lo: float = 1e-6, hi: float = 100.0,
                 buckets_per_decade: int = 16):
        if not (0.0 < lo < hi):
            raise ValueError("need 0 < lo < hi")
        if buckets_per_decade < 1:
            raise ValueError("buckets_per_decade must be >= 1")
        self.lo = lo
        self.hi = hi
        self._log_lo = math.log10(lo)
        self._scale = buckets_per_decade
        n = int(math.ceil((math.log10(hi) - self._log_lo) * buckets_per_decade))
        # counts[0] is underflow (< lo), counts[n + 1] overflow (>= hi)
        self._counts: List[int] = [0] * (n + 2)
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self._lock = threading.Lock()

    def _bucket(self, seconds: float) -> int:
        if seconds < self.lo:
            return 0
        if seconds >= self.hi:
            return len(self._counts) - 1
        return 1 + int((math.log10(seconds) - self._log_lo) * self._scale)

    def _edge(self, bucket: int) -> float:
        """Upper edge of a bucket — what percentiles report, so the
        estimate never understates the true order statistic."""
        if bucket <= 0:
            return self.lo
        if bucket >= len(self._counts) - 1:
            return self.max if self.max > 0 else self.hi
        return 10.0 ** (self._log_lo + bucket / self._scale)

    def observe(self, seconds: float) -> None:
        """Record one latency sample (thread-safe)."""
        with self._lock:
            self._counts[self._bucket(seconds)] += 1
            self.count += 1
            self.total += seconds
            if seconds > self.max:
                self.max = seconds

    def percentile(self, q: float) -> float:
        """The smallest bucket upper edge covering the ``q``-th percentile
        (q in [0, 100]); 0.0 before any sample."""
        if not 0.0 <= q <= 100.0:
            raise ValueError("percentile must be in [0, 100]")
        with self._lock:
            if self.count == 0:
                return 0.0
            # the rank of the order statistic numpy's 'lower' method picks
            rank = int(q / 100.0 * (self.count - 1)) + 1
            seen = 0
            for bucket, c in enumerate(self._counts):
                seen += c
                if seen >= rank:
                    return self._edge(bucket)
            return self._edge(len(self._counts) - 1)  # pragma: no cover

    @property
    def mean(self) -> float:
        """Exact sample mean (not bucket-quantized)."""
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "LatencyHistogram") -> None:
        """Fold another histogram's counts in (bucket layouts must match —
        the per-host aggregation path for a sharded service)."""
        if (other.lo, other.hi, other._scale) != (self.lo, self.hi, self._scale):
            raise ValueError("cannot merge histograms with different buckets")
        with other._lock:
            counts = list(other._counts)
            count, total, mx = other.count, other.total, other.max
        with self._lock:
            for i, c in enumerate(counts):
                self._counts[i] += c
            self.count += count
            self.total += total
            self.max = max(self.max, mx)

    def snapshot(self) -> Dict[str, float]:
        """JSON-serializable summary: count, mean, p50/p95/p99, max."""
        return {
            "count": self.count,
            "mean_s": self.mean,
            "p50_s": self.percentile(50),
            "p95_s": self.percentile(95),
            "p99_s": self.percentile(99),
            "max_s": self.max,
        }
