"""Batched serving engine: continuous batching over fixed decode slots.

The engine owns a slot-table of ``max_batch`` concurrent sequences sharing
one KV cache tree (slot = batch index).  Requests join free slots; every
engine step runs ONE fused decode for all active slots; finished sequences
(EOS or max_len) free their slot.  This is vLLM-style continuous batching
restricted to static shapes: the cache is a preallocated (slots, S_max)
region — TPU-friendly, no paging indirection (DESIGN.md §5 notes the paged
variant as future kernel work).

Per-slot state is host-side bookkeeping; device state is the cache pytree.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (p,) int32
    max_new: int = 32
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 8,
        max_seq: int = 512,
        cache_dtype=jnp.float32,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache = init_cache(cfg, max_batch, max_seq, cache_dtype)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pending: List[Request] = []
        self._tokens = np.zeros((max_batch, 1), np.int32)
        self._pos = np.zeros(max_batch, np.int32)  # per-slot sequence length
        self._step = jax.jit(
            lambda p, c, t: decode_step(p, cfg, c, t)
        )

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slots[i] = req
                # prompt enters token-by-token (prefill-by-decode: simple,
                # exact; a batched prefill path exists in models/transformer)
                self._tokens[i, 0] = req.prompt[0]
                self._pos[i] = 0
                req._consumed = 1
                req._prompt_len = len(req.prompt)

    # ------------------------------------------------------------------ step
    def step(self) -> int:
        """One fused decode across all slots; returns #active slots."""
        self._admit()
        active = [i for i in range(self.max_batch) if self.slots[i] is not None]
        if not active:
            return 0
        # NOTE: slots share one global cache['t']; per-slot positions are
        # tracked host-side and the shared t advances uniformly.  Sequences
        # therefore align their cache writes; empty slots decode garbage
        # that is never read.  (Per-slot t is the paged-cache follow-up.)
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(self._tokens)
        )
        logits = np.asarray(logits)
        for i in active:
            req = self.slots[i]
            nxt_pos = int(self._pos[i]) + 1
            if req._consumed < req._prompt_len:
                # still feeding the prompt
                self._tokens[i, 0] = req.prompt[req._consumed]
                req._consumed += 1
            else:
                tok = int(np.argmax(logits[i]))
                req.out.append(tok)
                self._tokens[i, 0] = tok
                if (req.eos is not None and tok == req.eos) or len(
                    req.out
                ) >= req.max_new:
                    req.done = True
                    self.slots[i] = None
            self._pos[i] = nxt_pos
            if nxt_pos >= self.max_seq - 1 and self.slots[i] is not None:
                self.slots[i].done = True
                self.slots[i] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.pending or any(s is not None for s in self.slots)) and (
            steps < max_steps
        ):
            self.step()
            steps += 1
