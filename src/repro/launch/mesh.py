"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).

    single-pod:  (data=16, model=16)            = 256 chips (one v5e pod)
    multi-pod:   (pod=2, data=16, model=16)     = 512 chips

``pod`` composes with ``data`` as the outer data-parallel axis; TP groups
(``model``) stay inside an ICI torus.
"""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)"
        )
    from jax.sharding import Mesh

    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires >= data*model forced devices)."""
    import jax
    from jax.sharding import Mesh

    n = data * model
    dev = np.asarray(jax.devices()[:n]).reshape(data, model)
    return Mesh(dev, ("data", "model"))
