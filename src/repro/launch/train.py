"""Training driver: cleaning-woven data pipeline -> sharded train loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 50 --batch-docs 8 --seq 128

On this CPU container the reduced configs run end-to-end (the quickstart
example trains a ~few-M-param model for a few hundred steps); on a pod the
full config + production mesh apply unchanged.

Fault tolerance: every --ckpt-every steps a sharded checkpoint lands under
--ckpt-dir (atomic); on start the latest checkpoint restores (elastic:
restore re-shards onto whatever mesh this run built).  Step times feed the
straggler monitor.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, default_pipeline
from repro.models.params import init_params
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.fault_tolerance import StragglerMonitor
from repro.train.optim import OptConfig, init_opt_state
from repro.train.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-docs", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--n-docs", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    cfg = cfg.canonicalize(tp=1)
    pipe_cfg = PipelineConfig(
        batch_docs=args.batch_docs, seq_len=args.seq,
        vocab_size=min(cfg.vocab_size, 1024), seed=args.seed,
    )
    pipe, workload = default_pipeline(args.n_docs, pipe_cfg)

    params = init_params(jax.random.key(args.seed), cfg)
    opt_cfg = OptConfig(name=cfg.optimizer if cfg.optimizer != "adamw_bf16" else "adamw_bf16",
                        lr=args.lr, total_steps=args.steps)
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, n_micro=1, mamba_chunk=32),
        donate_argnums=(0, 1),
    )

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt": opt_state}
        )
        params, opt_state = state["params"], state["opt"]
        print(f"restored checkpoint at step {start}")

    monitor = StragglerMonitor()
    t_start = time.time()
    for step, batch in enumerate(pipe.batches(workload, args.steps - start),
                                 start=start):
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.time() - t0
        if monitor.record(step, dt):
            print(f"[straggler] step {step} took {dt:.2f}s "
                  f"(mean {monitor.mean:.2f}s)")
        if step % 10 == 0:
            loss = float(metrics["loss"])
            print(f"step {step:4d} loss {loss:.4f} "
                  f"({dt:.2f}s/step, clean={pipe.cleaning_progress()})")
        if args.ckpt_dir and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            path = save_checkpoint(
                args.ckpt_dir, step + 1,
                {"params": params, "opt": opt_state,
                 "extra": {"arch": cfg.name}},
            )
            print(f"checkpointed -> {path}")
    print(f"done: {args.steps - start} steps in {time.time()-t_start:.1f}s; "
          f"cleaning progress {pipe.cleaning_progress()}")


if __name__ == "__main__":
    main()
