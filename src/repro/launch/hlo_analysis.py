"""Roofline-term extraction from compiled dry-run artifacts.

``collective_bytes`` is not in ``cost_analysis()``: we parse the optimized
HLO text and sum the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.  Shapes in HLO are
per-SHARD (post-SPMD), so the sums are per-device wire bytes — exactly the
numerator of the collective roofline term.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (3 links usable per chip on a 2-D torus; we charge the single-link
worst case, as the system prompt specifies).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'f32[16,128]{1,0}' or a tuple
    '(f32[2,4], s32[8])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in the module.

    HLO line form:  <shape> <op-name> = <opcode>(...operands...)
    e.g.  %ag = bf16[4,1024,512] all-gather(bf16[4,1024,32] %p), ...
    We charge the RESULT shape (bytes that cross the wire into each device;
    for all-reduce result==operand, for all-gather it is the gathered size —
    the standard per-device wire accounting under ring algorithms).
    """
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    nbytes: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match  "... = <shape> <collective>(" — opcode right before '('
        m = re.search(r"=\s+((?:\([^)]*\)|\S+))\s+([\w-]+)(?:-start|-done)?\(", s)
        if not m:
            continue
        shape_str, opcode = m.group(1), m.group(2)
        base = opcode.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES:
            if opcode.endswith("-done"):
                continue  # counted at -start
            counts[base] += 1
            nbytes[base] += _shape_bytes(shape_str)
    return CollectiveStats(counts, nbytes)


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    chips: int = 1,
) -> Dict[str, float]:
    """The three §Roofline terms in seconds.  flops/bytes are PER-DEVICE
    (post-SPMD shapes), so chips=1 unless aggregating global numbers."""
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_BW)
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": total,
        "roofline_fraction": compute_s / total if total > 0 else 0.0,
    }


def model_flops(cfg, shape_kind: str, seq: int, batch: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (decode/prefill fwd-only),
    N = active params, D = tokens processed."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        tokens = seq * batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq * batch
        return 2.0 * n_active * tokens
    tokens = 1 * batch  # decode: one token per sequence
    return 2.0 * n_active * tokens
