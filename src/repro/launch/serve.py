"""Serving drivers.

Two workloads share this entry point:

* ``--workload decode``  (default) the batched continuous-batching LLM
  decode engine over a reduced model:

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 6

* ``--workload queries``  a synthetic multi-user analytical workload over
  the query service (repro.service, DESIGN.md §9): many sessions issue
  repeated exploratory queries against one shared, gradually-cleaned
  Daisy instance; the driver prints throughput, cache effectiveness, and
  the detect/repair work amortized per query.  ``--background`` runs the
  cost-model-driven background cleaner (DESIGN.md §10) behind the serving
  thread so first-touch queries stop paying detect latency.  The cleaner
  granularity knobs (DESIGN.md §11): ``--increment-rows`` bounds one FD
  increment (whole lhs groups up to that many rows) and
  ``--increment-strips`` bounds one DC increment (that many work-ledger
  strips per lock hold — the workload carries a beds/quality DC so the
  knob is exercised):

      PYTHONPATH=src python -m repro.launch.serve --workload queries \\
          --sessions 8 --requests 40 --rows 2048 --background \\
          --increment-rows 256 --increment-strips 2

  ``--ingest-chunks``/``--ingest-rows`` turn it into ingest-while-serving
  (DESIGN.md §12): that many rows are held back from the seed instance and
  streamed through ``QueryServer.ingest`` between query bursts:

      PYTHONPATH=src python -m repro.launch.serve --workload queries \\
          --rows 2048 --ingest-chunks 4 --ingest-rows 128

All query-workload knobs live in ONE ``ServeOptions`` bundle shared with
examples/serve_queries.py and the serving benchmarks.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class ServeOptions:
    """The query-serving workload knobs, consolidated: one bundle shared by
    the CLI driver (``--workload queries``), the quickstart example
    (examples/serve_queries.py), and the serving benchmarks
    (benchmarks/serve_bg_warmup.py, benchmarks/serve_ingest.py), so each
    knob means the same thing everywhere it appears.

    ``ingest_chunks`` x ``ingest_rows`` rows are held back from the seed
    instance and streamed through ``QueryServer.ingest`` between query
    bursts — the ingest-while-serving workload (DESIGN.md §12).  Zero
    (the default) serves a fixed instance.

    ``trace`` names a Chrome trace-event JSON to dump the run's spans to
    (DESIGN.md §13): the whole stack — executor, server, background
    cleaner — records into one tracer, the file loads in Perfetto, and
    the driver prints the per-phase rollup.  None (the default) disables
    tracing entirely (the strict no-op tracer).

    ``qos`` turns on traffic shaping (DESIGN.md §14): the submit queue
    becomes weighted-fair over sessions, requests carry SLO classes (the
    driver mixes ``interactive`` and ``batch``), and per-class latency
    percentiles are reported.  ``overload_depth`` > 0 additionally arms
    admission control: once the queue is deeper than that, sheddable
    (interactive) requests are answered from the version-vector cache
    with an explicit staleness tag instead of queueing."""

    sessions: int = 4
    requests: int = 40
    rows: int = 1024
    max_batch: int = 8
    background: bool = False
    increment_rows: int = 0  # 0 -> rows // 8 (min 64); whole FD lhs groups
    increment_strips: int = 1  # work-ledger strips per DC increment (§11)
    ingest_chunks: int = 0
    ingest_rows: int = 0
    seed: int = 0
    trace: str | None = None  # Chrome trace JSON output path (§13)
    qos: bool = False  # weighted-fair queue + SLO classes (§14)
    overload_depth: int = 0  # 0 = never shed; >0 arms stale-serve shedding

    @property
    def fd_increment_rows(self) -> int:
        """Rows per background FD increment; the 0 default scales with the
        instance size."""
        return self.increment_rows or max(self.rows // 8, 64)

    @property
    def held_back_rows(self) -> int:
        """Rows kept out of the seed instance for streaming ingest."""
        return self.ingest_chunks * self.ingest_rows

    @classmethod
    def from_args(cls, args) -> "ServeOptions":
        """Build from ``main``'s argparse namespace."""
        return cls(
            sessions=args.sessions, requests=args.requests, rows=args.rows,
            max_batch=args.max_batch, background=args.background,
            increment_rows=args.increment_rows,
            increment_strips=args.increment_strips,
            ingest_chunks=args.ingest_chunks, ingest_rows=args.ingest_rows,
            seed=args.seed, trace=args.trace,
            qos=args.qos, overload_depth=args.overload,
        )


def run_decode(args) -> None:
    import jax

    from repro.configs import get_config
    from repro.models.params import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch, reduced=True).canonicalize(tp=1)
    params = init_params(jax.random.key(args.seed), cfg)
    engine = ServeEngine(cfg, params, max_batch=args.max_batch, max_seq=128)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 12))
        req = Request(rid=rid, prompt=prompt.astype(np.int32), max_new=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s fused batch)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {len(r.prompt)} toks -> {r.out[:8]}...")


def run_queries(opts: ServeOptions) -> None:
    import threading

    from repro.core.constraints import Atom, DC, FD
    from repro.core.executor import Daisy, DaisyConfig
    from repro.core.operators import GroupBySpec, Pred, Query
    from repro.core.relation import make_relation
    from repro.data.generators import hospital_like
    from repro.obs import Tracer, format_rollup, rollup, write_trace
    from repro.obs.trace import NULL_TRACER
    from repro.service import BackgroundCleaner, QoSPolicy, QueryServer

    # generate the FULL dataset (seed + held-back stream) in one draw, so the
    # same --seed with/without ingest sees the same rows — only delivery
    # differs: the last held_back_rows arrive through QueryServer.ingest
    total = opts.rows + opts.held_back_rows
    ds = hospital_like(total, error_frac=0.1, seed=opts.seed)
    data = dict(ds.data)
    # a noisy quality score, mostly monotone in beds: the DC below says a
    # smaller hospital must not outrank a larger one — the inversions the
    # noise plants are its violations, giving the strip-grained background
    # DC cleaning (DESIGN.md §11) real work to bound
    rng_q = np.random.default_rng(opts.seed + 1)
    data["quality"] = (
        data["beds"].astype(np.float32)
        + rng_q.integers(-60, 60, total).astype(np.float32)
    )
    seed_data = {k: v[: opts.rows] for k, v in data.items()}
    chunks = [
        {
            k: v[opts.rows + c * opts.ingest_rows:
                 opts.rows + (c + 1) * opts.ingest_rows]
            for k, v in data.items()
        }
        for c in range(opts.ingest_chunks)
    ]
    rel = make_relation(
        seed_data, overlay=["zip", "city", "beds", "quality"], k=8,
        rules=["zc", "bq"],
    )
    rules = [
        FD("zc", "zip", "city"),
        DC("bq", [Atom("beds", "<", "beds"), Atom("quality", ">", "quality")]),
    ]
    # one tracer for the whole stack (DESIGN.md §13): the server and the
    # background cleaner default their seams to the executor's tracer
    tracer = Tracer() if opts.trace else NULL_TRACER
    daisy = Daisy(
        {"h": rel}, {"h": rules},
        DaisyConfig(use_cost_model=False, expected_queries=opts.requests),
        tracer=tracer,
    )
    # traffic shaping (DESIGN.md §14): weighted-fair queue + SLO classes;
    # overload_depth > 0 arms the stale-serve shed path
    policy = (
        QoSPolicy(overload_depth=opts.overload_depth) if opts.qos else None
    )
    server = QueryServer(daisy, max_batch=opts.max_batch, qos=policy)
    cleaner = None
    if opts.background:
        # serving thread + cleaner thread: the cleaner warms cold scopes
        # whenever the submission queue is empty and yields on arrivals
        serving = threading.Thread(target=server.run, name="serving", daemon=True)
        serving.start()
        cleaner = BackgroundCleaner(
            daisy, server=server,
            increment_rows=opts.fd_increment_rows,
            increment_strips=opts.increment_strips,
        ).start()

    # exploratory pool: per-neighborhood selections + one overview group-by
    # + a couple of DC-overlapping ranking views; users revisit the same
    # views over and over (Table 8's access pattern)
    n_zip = max(opts.rows // 20, 4)
    pool = [Query("h", preds=(Pred("zip", "==", g),)) for g in range(n_zip)]
    pool.append(Query("h", groupby=GroupBySpec(keys=("city",), agg="count")))
    pool.append(Query("h", preds=(Pred("beds", ">=", 400),)))

    rng = np.random.default_rng(opts.seed)
    # the whole workload is submitted before drain(), so size the per-user
    # inflight bound to the share each session will queue
    inflight = max(opts.requests // opts.sessions + 1, 1)
    sessions = [
        server.open_session(f"user{i}", max_inflight=inflight)
        for i in range(opts.sessions)
    ]
    # ingest-while-serving: slice the request stream into chunk+1 bursts and
    # queue one append between bursts — the ingest ticket is a batch barrier
    # (DESIGN.md §12), so queries before it answer over the old rows and
    # queries after it see the appended instance
    burst = max(opts.requests // (opts.ingest_chunks + 1), 1)
    t0 = time.perf_counter()
    tickets = []
    next_chunk = 0
    for i in range(opts.requests):
        if i and i % burst == 0 and next_chunk < len(chunks):
            tickets.append(server.ingest("h", chunks[next_chunk]))
            next_chunk += 1
        session = sessions[i % opts.sessions]
        # zipf-ish revisit pattern: hot views dominate
        idx = min(int(rng.zipf(1.7)) - 1, len(pool) - 1)
        # under --qos, mix classes: every 4th request is a batch report,
        # the rest are interactive lookups (the WFQ keeps both flowing)
        slo = ("batch" if opts.qos and i % 4 == 3 else "interactive")
        tickets.append(server.submit(session, pool[idx], slo=slo))
    # any chunks the burst schedule didn't reach still stream in at the tail
    while next_chunk < len(chunks):
        tickets.append(server.ingest("h", chunks[next_chunk]))
        next_chunk += 1
    if cleaner is not None:
        for t in tickets:
            t.wait(timeout=600)
        server.stop()
        cleaner.stop()
    else:
        server.drain()
    dt = time.perf_counter() - t0

    snap = server.snapshot()
    print(
        f"served {snap['queries']} queries from {opts.sessions} sessions in "
        f"{dt:.2f}s ({snap['queries']/dt:.1f} q/s)"
    )
    print(
        f"  executions {snap['executions']}  cache hits {snap['cache_hits']} "
        f"(hit rate {snap['hit_rate']:.0%})  clean_version {snap['clean_version']}"
    )
    print(
        f"  detect {snap['detect_calls']} / repair {snap['repair_calls']} "
        f"-> {snap['detect_repair_per_query']} invocations amortized per query"
    )
    if snap["ingests"]:
        print(
            f"  ingest: {snap['ingests']} appends, {snap['ingested_rows']} rows "
            f"streamed in, {snap['ingest_pending_deltas']} pending deltas queued "
            f"(final instance {int(daisy.db['h'].num_rows())} rows)"
        )
    if cleaner is not None:
        bg = snap["background"]
        print(
            f"  background: {bg['increments']} increments "
            f"({bg['detect_calls']} detect / {bg['repair_calls']} repair, "
            f"{bg['scopes_completed']} scopes warmed, {bg['yields']} yields) "
            f"serving idle fraction {snap['idle_fraction']:.0%}"
        )
        for scope, prog in snap["ledger"].items():
            print(
                f"  ledger {scope}: {prog['strips_done']}/{prog['strips_total']}"
                f" strips warm, {prog['cold_rows']} cold rows"
            )
    if opts.qos:
        qos = snap["qos"]
        print(
            f"  qos: shed {qos['shed']} ({qos['shed_stale']} stale-tagged, "
            f"total staleness {qos['shed_staleness_total']}), "
            f"cancelled {qos['cancelled']}, "
            f"deadline misses {qos['deadline_misses']}"
        )
        for cls, counts in sorted(qos["by_class"].items()):
            parts = ", ".join(f"{k} {v}" for k, v in sorted(counts.items()))
            print(f"    class {cls}: {parts}")
    for s in snap["sessions"][:4]:
        print(f"  {s['sid']}: answered {s['answered']} "
              f"({s['cached_answers']} from cache)")
    for kind, lat in snap.get("latency", {}).items():
        print(
            f"  latency[{kind}]: p50 {lat['p50_s']*1e3:.2f}ms "
            f"p95 {lat['p95_s']*1e3:.2f}ms p99 {lat['p99_s']*1e3:.2f}ms "
            f"({lat['count']} samples)"
        )
    if opts.trace:
        events = tracer.events()
        write_trace(opts.trace, events, origin=tracer.created)
        print(f"  trace: {len(events)} spans -> {opts.trace} "
              f"(Perfetto-loadable; {tracer.dropped} dropped)")
        print(format_rollup(rollup(events)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("decode", "queries"), default="decode")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--rows", type=int, default=1024)
    ap.add_argument(
        "--background", action="store_true",
        help="run the DESIGN.md §10 background cleaner behind the serving loop",
    )
    ap.add_argument(
        "--increment-rows", type=int, default=0,
        help="rows per background FD increment (0 = rows/8; whole lhs groups)",
    )
    ap.add_argument(
        "--increment-strips", type=int, default=1,
        help="work-ledger strips per background DC increment (DESIGN.md §11)",
    )
    ap.add_argument(
        "--ingest-chunks", type=int, default=0,
        help="appends to stream through QueryServer.ingest mid-workload "
             "(DESIGN.md §12; 0 = fixed instance)",
    )
    ap.add_argument(
        "--ingest-rows", type=int, default=0,
        help="rows per streamed append (held back from the seed instance)",
    )
    ap.add_argument(
        "--qos", action="store_true",
        help="weighted-fair queueing + SLO classes on the submit queue "
             "(DESIGN.md §14); the driver mixes interactive and batch "
             "requests and reports per-class latency",
    )
    ap.add_argument(
        "--overload", type=int, default=0, metavar="DEPTH",
        help="queue depth past which sheddable requests are answered from "
             "the cache with a staleness tag instead of queueing "
             "(DESIGN.md §14; 0 = never shed; implies --qos semantics "
             "only when --qos is set)",
    )
    ap.add_argument(
        "--trace", default=None, metavar="OUT.json",
        help="dump a Chrome trace-event JSON of the serving run "
             "(DESIGN.md §13; load it in Perfetto, or summarize with "
             "tools/trace_summary.py)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.workload == "queries":
        run_queries(ServeOptions.from_args(args))
    else:
        run_decode(args)


if __name__ == "__main__":
    main()
