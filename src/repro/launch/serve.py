"""Serving driver: batched continuous-batching engine over a reduced model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 6
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True).canonicalize(tp=1)
    params = init_params(jax.random.key(args.seed), cfg)
    engine = ServeEngine(cfg, params, max_batch=args.max_batch, max_seq=128)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 12))
        req = Request(rid=rid, prompt=prompt.astype(np.int32), max_new=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s fused batch)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {len(r.prompt)} toks -> {r.out[:8]}...")


if __name__ == "__main__":
    main()
