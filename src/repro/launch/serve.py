"""Serving drivers.

Two workloads share this entry point:

* ``--workload decode``  (default) the batched continuous-batching LLM
  decode engine over a reduced model:

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 6

* ``--workload queries``  a synthetic multi-user analytical workload over
  the query service (repro.service, DESIGN.md §9): many sessions issue
  repeated exploratory queries against one shared, gradually-cleaned
  Daisy instance; the driver prints throughput, cache effectiveness, and
  the detect/repair work amortized per query.  ``--background`` runs the
  cost-model-driven background cleaner (DESIGN.md §10) behind the serving
  thread so first-touch queries stop paying detect latency.  The cleaner
  granularity knobs (DESIGN.md §11): ``--increment-rows`` bounds one FD
  increment (whole lhs groups up to that many rows) and
  ``--increment-strips`` bounds one DC increment (that many work-ledger
  strips per lock hold — the workload carries a beds/quality DC so the
  knob is exercised):

      PYTHONPATH=src python -m repro.launch.serve --workload queries \\
          --sessions 8 --requests 40 --rows 2048 --background \\
          --increment-rows 256 --increment-strips 2
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def run_decode(args) -> None:
    import jax

    from repro.configs import get_config
    from repro.models.params import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch, reduced=True).canonicalize(tp=1)
    params = init_params(jax.random.key(args.seed), cfg)
    engine = ServeEngine(cfg, params, max_batch=args.max_batch, max_seq=128)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 12))
        req = Request(rid=rid, prompt=prompt.astype(np.int32), max_new=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s fused batch)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {len(r.prompt)} toks -> {r.out[:8]}...")


def run_queries(args) -> None:
    import threading

    from repro.core.constraints import Atom, DC, FD
    from repro.core.executor import Daisy, DaisyConfig
    from repro.core.operators import GroupBySpec, Pred, Query
    from repro.core.relation import make_relation
    from repro.data.generators import hospital_like
    from repro.service import BackgroundCleaner, QueryServer

    ds = hospital_like(args.rows, error_frac=0.1, seed=args.seed)
    data = dict(ds.data)
    # a noisy quality score, mostly monotone in beds: the DC below says a
    # smaller hospital must not outrank a larger one — the inversions the
    # noise plants are its violations, giving the strip-grained background
    # DC cleaning (DESIGN.md §11) real work to bound
    rng_q = np.random.default_rng(args.seed + 1)
    data["quality"] = (
        data["beds"].astype(np.float32)
        + rng_q.integers(-60, 60, args.rows).astype(np.float32)
    )
    rel = make_relation(
        data, overlay=["zip", "city", "beds", "quality"], k=8,
        rules=["zc", "bq"],
    )
    rules = [
        FD("zc", "zip", "city"),
        DC("bq", [Atom("beds", "<", "beds"), Atom("quality", ">", "quality")]),
    ]
    daisy = Daisy(
        {"h": rel}, {"h": rules},
        DaisyConfig(use_cost_model=False, expected_queries=args.requests),
    )
    server = QueryServer(daisy, max_batch=args.max_batch)
    cleaner = None
    if args.background:
        # serving thread + cleaner thread: the cleaner warms cold scopes
        # whenever the submission queue is empty and yields on arrivals
        serving = threading.Thread(target=server.run, name="serving", daemon=True)
        serving.start()
        cleaner = BackgroundCleaner(
            daisy, server=server,
            increment_rows=args.increment_rows or max(args.rows // 8, 64),
            increment_strips=args.increment_strips,
        ).start()

    # exploratory pool: per-neighborhood selections + one overview group-by
    # + a couple of DC-overlapping ranking views; users revisit the same
    # views over and over (Table 8's access pattern)
    n_zip = max(args.rows // 20, 4)
    pool = [Query("h", preds=(Pred("zip", "==", g),)) for g in range(n_zip)]
    pool.append(Query("h", groupby=GroupBySpec(keys=("city",), agg="count")))
    pool.append(Query("h", preds=(Pred("beds", ">=", 400),)))

    rng = np.random.default_rng(args.seed)
    # the whole workload is submitted before drain(), so size the per-user
    # inflight bound to the share each session will queue
    inflight = max(args.requests // args.sessions + 1, 1)
    sessions = [
        server.open_session(f"user{i}", max_inflight=inflight)
        for i in range(args.sessions)
    ]
    t0 = time.perf_counter()
    tickets = []
    for i in range(args.requests):
        session = sessions[i % args.sessions]
        # zipf-ish revisit pattern: hot views dominate
        idx = min(int(rng.zipf(1.7)) - 1, len(pool) - 1)
        tickets.append(server.submit(session, pool[idx]))
    if cleaner is not None:
        for t in tickets:
            t.wait(timeout=600)
        server.stop()
        cleaner.stop()
    else:
        server.drain()
    dt = time.perf_counter() - t0

    snap = server.snapshot()
    print(
        f"served {snap['queries']} queries from {args.sessions} sessions in "
        f"{dt:.2f}s ({snap['queries']/dt:.1f} q/s)"
    )
    print(
        f"  executions {snap['executions']}  cache hits {snap['cache_hits']} "
        f"(hit rate {snap['hit_rate']:.0%})  clean_version {snap['clean_version']}"
    )
    print(
        f"  detect {snap['detect_calls']} / repair {snap['repair_calls']} "
        f"-> {snap['detect_repair_per_query']} invocations amortized per query"
    )
    if cleaner is not None:
        bg = snap["background"]
        print(
            f"  background: {bg['increments']} increments "
            f"({bg['detect_calls']} detect / {bg['repair_calls']} repair, "
            f"{bg['scopes_completed']} scopes warmed, {bg['yields']} yields) "
            f"serving idle fraction {snap['idle_fraction']:.0%}"
        )
        for scope, prog in snap["ledger"].items():
            print(
                f"  ledger {scope}: {prog['strips_done']}/{prog['strips_total']}"
                f" strips warm, {prog['cold_rows']} cold rows"
            )
    for s in snap["sessions"][:4]:
        print(f"  {s['sid']}: answered {s['answered']} "
              f"({s['cached_answers']} from cache)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("decode", "queries"), default="decode")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--rows", type=int, default=1024)
    ap.add_argument(
        "--background", action="store_true",
        help="run the DESIGN.md §10 background cleaner behind the serving loop",
    )
    ap.add_argument(
        "--increment-rows", type=int, default=0,
        help="rows per background FD increment (0 = rows/8; whole lhs groups)",
    )
    ap.add_argument(
        "--increment-strips", type=int, default=1,
        help="work-ledger strips per background DC increment (DESIGN.md §11)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.workload == "queries":
        run_queries(args)
    else:
        run_decode(args)


if __name__ == "__main__":
    main()
