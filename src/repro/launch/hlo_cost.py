"""Trip-count-aware HLO cost roll-up.

``compiled.cost_analysis()`` counts every while-loop body ONCE — under our
scanned unit stacks and microbatch accumulation that undercounts FLOPs by
the product of all enclosing trip counts (verified empirically: reported
FLOPs scale as 1/n_micro).  This module parses ``compiled.as_text()`` and
rolls costs up through the call graph:

  * **flops**: 2*M*N*K for every ``dot`` (batch/contracting dims parsed),
    including dots inside fusions;
  * **hbm bytes**: operand + result bytes of every top-level instruction
    (fusion internals are free, matching XLA's fusion-aware accounting;
    bookkeeping ops — tuple/gte/parameter/constant/bitcast — are free);
  * **collective bytes**: result-shape bytes per all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute;
  * **while**: body+cond costs multiply by the trip count recovered from
    the loop condition's ``compare(iter, constant)``;
  * **fusion/call/conditional**: fusion adds called-dot flops, call adds
    everything, conditional takes the max branch.

Shapes in the SPMD-partitioned module are per-device, so all results are
per-chip roofline numerators.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# ops a TPU compile fuses into producers/consumers; XLA:CPU leaves many at
# top level, which inflates a naive bytes-accessed sum.  ``bytes_fused``
# skips these (the TPU-realistic memory term); ``bytes`` counts everything
# (the conservative bound).  Both are reported.
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "select", "convert",
    "broadcast", "compare", "exponential", "exponential-minus-one", "log",
    "log-plus-one", "tanh", "rsqrt", "sqrt", "negate", "maximum", "minimum",
    "abs", "and", "or", "xor", "not", "clamp", "floor", "ceil", "power",
    "sign", "cosine", "sine", "is-finite", "remainder", "atan2",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "round-nearest-afz", "round-nearest-even", "reduce-precision",
}

_SHAPE_RE = re.compile(r"(\w[\w\d]*)\[([\d,]*)\]")
# tuple shapes may contain /*index=N*/ comments — match to the balanced
# close-paren (tuple shapes never nest parens)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+?)\s+([\w\-]+)\((.*)$"
)
# header params may contain nested parens (tuple types): match the prefix only
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str  # operand list + attributes (raw tail of the line)

    def operand_names(self) -> List[str]:
        # ``rest`` starts right AFTER the opcode's opening paren (consumed by
        # the instruction regex), so we begin at depth 1 and stop at the
        # matching close.
        depth = 1
        end = len(self.rest)
        for i, c in enumerate(self.rest):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        names = re.findall(r"%([\w\.\-]+)", self.rest[:end])
        return names

    def attr(self, key: str) -> Optional[str]:
        m = re.search(rf"{key}=%?([\w\.\-]+)", self.rest)
        return m.group(1) if m else None

    def dims_attr(self, key: str) -> List[int]:
        m = re.search(rf"{key}={{([\d,]*)}}", self.rest)
        if not m or not m.group(1):
            return []
        return [int(x) for x in m.group(1).split(",")]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]  # result name -> shape string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_fused: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_fused += other.bytes_fused * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        for k, v in other.coll_bytes_by_kind.items():
            self.coll_bytes_by_kind[k] = (
                self.coll_bytes_by_kind.get(k, 0) + v * mult
            )
        self.unknown_trip_loops += other.unknown_trip_loops


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        instr = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
        cur.instrs.append(instr)
        cur.shapes[instr.name] = instr.shape
    return comps, entry


def _dot_flops(instr: Instr, comp: Computation) -> float:
    ops = instr.operand_names()
    if len(ops) < 2:
        return 0.0
    lhs_shape = _shape_dims(comp.shapes.get(ops[0], ""))
    if not lhs_shape:
        return 0.0
    lhs_batch = instr.dims_attr("lhs_batch_dims")
    lhs_contract = instr.dims_attr("lhs_contracting_dims")
    out_dims = _shape_dims(instr.shape)
    batch = 1
    for d in lhs_batch:
        batch *= lhs_shape[d]
    contract = 1
    for d in lhs_contract:
        contract *= lhs_shape[d]
    out = 1
    for d in out_dims:
        out *= d
    # out already includes batch dims; flops = 2 * out * contract
    return 2.0 * out * contract


def _trip_count(
    cond: Computation, comps: Optional[Dict[str, Computation]] = None
) -> Optional[int]:
    """Recover the loop bound from compare(iter, constant) in the cond.

    The compare is often fused (``fusion(..., calls=%wrapped_compare``), so
    when no top-level compare resolves, fall back to the positive s32 scalar
    constants visible in the cond (for scan loops the bound is the only
    one), assuming the canonical ``i < N`` form."""
    consts: Dict[str, int] = {}
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            if m and ins.shape.strip().startswith("s32[]"):
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.opcode != "compare":
            continue
        direction = (re.search(r"direction=(\w+)", ins.rest) or [None, ""])[1]
        for o in ins.operand_names():
            if o in consts:
                n = consts[o]
                if direction == "LE":
                    return max(n + 1, 0)
                return max(n, 0)
    positive = [v for v in consts.values() if v > 0]
    if positive:
        return max(positive)
    return None


def _comp_cost(
    name: str,
    comps: Dict[str, Computation],
    cache: Dict[str, Cost],
    fused_comps: set,
    inside_fusion: bool,
) -> Cost:
    key = name + ("#f" if inside_fusion else "")
    if key in cache:
        return cache[key]
    comp = comps[name]
    cost = Cost()
    for ins in comp.instrs:
        if ins.opcode == "dot":
            cost.flops += _dot_flops(ins, comp)
        if not inside_fusion and ins.opcode not in _FREE_OPS:
            b = shape_bytes(ins.shape)
            for o in ins.operand_names():
                if o in comp.shapes:
                    b += shape_bytes(comp.shapes[o])
            cost.bytes += b
            if ins.opcode not in _ELEMENTWISE:
                cost.bytes_fused += b
        base = ins.opcode.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
            nb = shape_bytes(ins.shape)
            # XLA:CPU promotes bf16 all-reduces to f32 ("*_promoted"
            # reducers); TPU reduces in bf16 — charge the unpromoted width.
            reducer = ins.attr("to_apply") or ""
            if "promoted" in reducer:
                nb = nb // 2
            cost.coll_bytes += nb
            cost.coll_counts[base] = cost.coll_counts.get(base, 0) + 1
            cost.coll_bytes_by_kind[base] = (
                cost.coll_bytes_by_kind.get(base, 0) + nb
            )
        # ---- called computations
        if ins.opcode == "fusion":
            called = ins.attr("calls")
            if called and called in comps:
                sub = _comp_cost(called, comps, cache, fused_comps, True)
                # fusion internals: flops count, bytes/collectives don't
                cost.flops += sub.flops
        elif ins.opcode == "while":
            body = ins.attr("body")
            cond = ins.attr("condition")
            trip = _trip_count(comps[cond], comps) if cond and cond in comps else None
            if trip is None:
                trip = 1
                cost.unknown_trip_loops += 1
            if body and body in comps:
                cost.add(
                    _comp_cost(body, comps, cache, fused_comps, inside_fusion),
                    trip,
                )
            if cond and cond in comps:
                cost.add(
                    _comp_cost(cond, comps, cache, fused_comps, inside_fusion),
                    trip,
                )
        elif ins.opcode == "conditional":
            branches = re.search(r"branch_computations={([^}]*)}", ins.rest)
            if branches:
                names = re.findall(r"%([\w\.\-]+)", branches.group(1))
                subs = [
                    _comp_cost(n, comps, cache, fused_comps, inside_fusion)
                    for n in names
                    if n in comps
                ]
                if subs:
                    best = max(subs, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
        elif ins.opcode in ("call", "async-start"):
            called = ins.attr("to_apply") or ins.attr("calls")
            if called and called in comps:
                cost.add(
                    _comp_cost(called, comps, cache, fused_comps, inside_fusion)
                )
    cache[key] = cost
    return cost


def analyze_hlo(text: str) -> Cost:
    comps, entry = parse_module(text)
    if entry is None:
        return Cost()
    fused = set()
    cache: Dict[str, Cost] = {}
    return _comp_cost(entry, comps, cache, fused, False)
