import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against ShapeDtypeStruct stand-ins, and extract the roofline
terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out d/]

The FIRST TWO LINES above force 512 host platform devices BEFORE any jax
import — jax locks the device count at first initialization.
"""

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import (
    MAMBA_CHUNK,
    SHAPES,
    TRAIN_MICROBATCHES,
    ShapeSpec,
    cell_applicable,
    input_specs,
)
from repro.dist.sharding import (
    batch_specs,
    cache_specs,
    param_specs,
    shardings,
)
from repro.launch.hlo_analysis import (
    model_flops,
    roofline_terms,
)
from repro.launch.mesh import make_production_mesh
from repro.models.params import abstract_params
from repro.models.transformer import decode_step, prefill
from repro.train.optim import OptConfig, init_opt_state
from repro.train.steps import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def _rep(mesh):
    return NamedSharding(mesh, P())


def build_cell(arch: str, shape_name: str, mesh, opt_override: Optional[str] = None,
               fsdp: bool = True, microbatch_override: Optional[int] = None,
               kv_quant: bool = False, dp_only: bool = False,
               grad_compress: bool = False):
    """Returns (lowered, meta) for one cell."""
    tp = mesh.shape["model"]
    cfg = get_config(arch).canonicalize(tp=1 if dp_only else tp)
    if opt_override:
        cfg = dataclasses.replace(cfg, optimizer=opt_override)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if cfg.moe is not None:
        dp = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"]))
        cfg = dataclasses.replace(cfg, moe_groups=dp)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": why}

    aparams = abstract_params(cfg)
    pspecs = param_specs(aparams, mesh, fsdp=fsdp)
    if dp_only:
        # TP right-sizing experiment: weights fully sharded over BOTH axes
        # as pure FSDP (no tensor-parallel dim); batch over both axes too.
        from repro.dist.sharding import param_specs_dp_only

        pspecs = param_specs_dp_only(aparams, mesh)
    pshard = shardings(pspecs, mesh)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = OptConfig(name=cfg.optimizer)
        aopt = jax.eval_shape(
            partial(init_opt_state, cfg=opt_cfg, grad_compress=grad_compress),
            aparams,
        )
        # moments mirror the param specs (adafactor's factored stats drop
        # the reduced dims from the spec); step is replicated
        ospecs = {}
        for k in aopt.keys():
            if k == "step":
                ospecs[k] = P()
            elif k == "vr":  # p.shape[:-1]
                ospecs[k] = jax.tree.map(
                    lambda sp: P(*sp[:-1]) if len(sp) else P(), pspecs,
                    is_leaf=lambda x: isinstance(x, P),
                )
            elif k == "vc":  # p.shape[:-2] + p.shape[-1:]
                ospecs[k] = jax.tree.map(
                    lambda sp: P(*(tuple(sp[:-2]) + (sp[-1],))) if len(sp) >= 2 else P(),
                    pspecs, is_leaf=lambda x: isinstance(x, P),
                )
            else:
                ospecs[k] = pspecs
        oshard = shardings(ospecs, mesh)
        bspecs = batch_specs(specs, mesh, all_axes=dp_only)
        bshard = shardings(bspecs, mesh)
        n_micro = microbatch_override or TRAIN_MICROBATCHES.get(cfg.name, 1)
        # microbatches must stay shardable over the full DP extent: on the
        # multi-pod mesh dp=32, so mb_global = batch/n_micro >= dp
        dp_size = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                               if a != "model"]))
        n_micro = max(min(n_micro, shape.global_batch // dp_size), 1)
        step = make_train_step(cfg, opt_cfg, n_micro=n_micro, mamba_chunk=MAMBA_CHUNK,
                               grad_compress=grad_compress,
                               mesh=mesh if grad_compress else None)
        fn = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, _rep(mesh)),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = fn.lower(aparams, aopt, specs)
        meta = {"kind": "train", "n_micro": n_micro}
    elif shape.kind == "prefill":
        bspecs = batch_specs(specs, mesh)
        bshard = shardings(bspecs, mesh)
        from repro.models.transformer import init_cache

        acache = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16)
        )
        cspecs = cache_specs(acache, mesh)
        cshard = shardings(cspecs, mesh)

        def prefill_step(params, batch):
            return prefill(params, cfg, batch, s_max=shape.seq_len,
                           mamba_chunk=MAMBA_CHUNK)

        fn = jax.jit(
            prefill_step,
            in_shardings=(pshard, bshard),
            out_shardings=(_rep(mesh), cshard),
        )
        with mesh:
            lowered = fn.lower(aparams, specs)
        meta = {"kind": "prefill"}
    else:  # decode
        acache = specs["cache"]
        cspecs = cache_specs(acache, mesh)
        cshard = shardings(cspecs, mesh)
        tshard = shardings(batch_specs({"token": specs["token"]}, mesh), mesh)["token"]

        def serve_step(params, cache, token):
            return decode_step(params, cfg, cache, token)

        fn = jax.jit(
            serve_step,
            in_shardings=(pshard, cshard, tshard),
            out_shardings=(_rep(mesh), cshard),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = fn.lower(aparams, acache, specs["token"])
        meta = {"kind": "decode"}
    meta["arch"] = cfg.name
    meta["shape"] = shape_name
    return lowered, meta


def analyse(lowered, meta, mesh, shape: ShapeSpec, cfg) -> Dict:
    from repro.launch.hlo_cost import analyze_hlo

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # trip-count-aware roll-up: cost_analysis() counts while bodies ONCE,
    # which undercounts the scanned unit stack / microbatch loop (see
    # launch/hlo_cost.py).  The roll-up is the headline; raw values kept.
    roll = analyze_hlo(hlo)
    terms = roofline_terms(roll.flops, roll.bytes, roll.coll_bytes)
    mf = model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
    chips = int(np.prod(list(mesh.shape.values())))
    mf_per_chip = mf / chips
    out = {
        **meta,
        "mesh": dict(mesh.shape),
        "chips": chips,
        "compile_s": round(compile_s, 1),
        "per_device": {
            "flops": roll.flops,
            "hbm_bytes": roll.bytes,
            "hbm_bytes_fused_estimate": roll.bytes_fused,
            "collective_bytes": roll.coll_bytes,
            "collective_counts": {
                k: round(v, 1) for k, v in roll.coll_counts.items()
            },
            "collective_bytes_by_kind": {
                k: v for k, v in roll.coll_bytes_by_kind.items()
            },
            "unknown_trip_loops": roll.unknown_trip_loops,
        },
        "xla_cost_analysis_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
        },
        "roofline": terms,
        "model_flops_per_chip": mf_per_chip,
        "useful_flops_ratio": (mf_per_chip / roll.flops) if roll.flops else 0.0,
    }
    # sharded-detection capacity planning (DESIGN.md §8): what routing this
    # cell's token stream as detection rows over the mesh's DP extent saves
    # on the O(n^2) pair scan — reported next to the collective stats above.
    from repro.dist.detect import default_n_shards, pair_count_report

    out["dc_detect_sharding"] = pair_count_report(
        shape.global_batch * shape.seq_len, max(default_n_shards(mesh), 1)
    )
    return out


def run_cell(arch, shape_name, multi_pod, out_dir=None, **kw) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    cfg = get_config(arch).canonicalize(tp=tp)
    shape = SHAPES[shape_name]
    lowered, meta = build_cell(arch, shape_name, mesh, **kw)
    if lowered is None:
        rec = {"arch": cfg.name, "shape": shape_name,
               "mesh": dict(mesh.shape), **meta}
    else:
        rec = analyse(lowered, meta, mesh, shape, cfg)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = "multipod" if multi_pod else "singlepod"
        path = os.path.join(out_dir, f"{cfg.name}__{shape_name}__{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--dp-only", action="store_true")
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 error-feedback gradient all-reduce (train cells)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                cells.append((arch, shape_name))
    else:
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape_name in cells:
        try:
            rec = run_cell(arch, shape_name, args.multi_pod, args.out,
                           fsdp=bool(args.fsdp),
                           microbatch_override=args.microbatches,
                           kv_quant=args.kv_quant, dp_only=args.dp_only,
                           grad_compress=args.grad_compress)
            if "skipped" in rec:
                print(f"[skip] {arch} x {shape_name}: {rec['skipped']}")
                continue
            r = rec["roofline"]
            print(
                f"[ok] {rec['arch']} x {shape_name} "
                f"({'multi' if args.multi_pod else 'single'}-pod): "
                f"compute {r['compute_s']:.4f}s | memory {r['memory_s']:.4f}s | "
                f"collective {r['collective_s']:.4f}s | dominant {r['dominant']} "
                f"| peak {rec['memory']['peak_bytes']/2**30:.2f} GiB/dev "
                f"| compile {rec['compile_s']}s "
                f"| dc-pairs {rec['dc_detect_sharding']['pair_savings_x']:.0f}x"
                f"/{rec['dc_detect_sharding']['n_shards']}sh"
            )
        except Exception as e:
            failures += 1
            print(f"[FAIL] {arch} x {shape_name}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
