"""Pallas TPU kernel: blocked semijoin membership.

The relaxation fixpoint (Algorithm 1) calls ``contains`` twice per iteration;
fusing the membership OR-reduce into VMEM tiles avoids materializing the
(n x m) boolean matrix in HBM.  Single key column (dictionary codes); the
multi-column case goes through the exact sort-merge path in core/setops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(bm, bn, q_ref, k_ref, km_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...]
    k = k_ref[...]
    km = km_ref[...]
    hit = jnp.any((q[:, None] == k[None, :]) & (km > 0)[None, :], axis=1)
    out_ref[...] = out_ref[...] | hit.astype(jnp.int32)


def semijoin_pallas(
    query: jnp.ndarray,
    query_mask: jnp.ndarray,
    keys: jnp.ndarray,
    keys_mask: jnp.ndarray,
    block: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    n = query.shape[0]
    m = keys.shape[0]
    nb_q = -(-n // block)
    nb_k = -(-m // block)

    qp = jnp.pad(query, (0, nb_q * block - n))
    kp = jnp.pad(keys, (0, nb_k * block - m))
    kmp = jnp.pad(keys_mask, (0, nb_k * block - m)).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, block, block),
        grid=(nb_q, nb_k),
        in_specs=[
            pl.BlockSpec((block,), lambda i, j: (i,)),
            pl.BlockSpec((block,), lambda i, j: (j,)),
            pl.BlockSpec((block,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb_q * block,), jnp.int32),
        interpret=interpret,
    )(qp, kp, kmp)
    return (out[:n] > 0) & query_mask
