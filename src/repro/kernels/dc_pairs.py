"""Pallas TPU kernel: blocked theta-join scan for DC violation detection.

The paper's DC error detection partitions the cartesian-product matrix into
``p`` partitions and prunes partitions whose boundary ranges cannot produce a
violation (§4.2, Fig. 3/4).  On TPU this becomes a 2-D grid of (BM, BN) VMEM
tiles over the comparison matrix:

* per-tile **bound pruning**: per-block min/max of each atom column are
  precomputed (scope-masked) and prefetched; a tile whose bounds make some
  atom unsatisfiable everywhere is skipped with ``@pl.when`` — the paper's
  partition pruning, at tile granularity;
* the 8x128-lane VPU evaluates the atom predicates for all BM*BN pairs of the
  tile at once (the Spark version loops over JVM tuples);
* outputs are row-indexed (violation count + per-atom extremal partner value,
  which is the bound of the candidate *range* fix, Example 4) and accumulate
  across the column grid dimension — the column dim is innermost so each
  output block is revisited consecutively, as the TPU grid requires.

Both tuple roles (t1, t2) use this same kernel: the t2 role flips the atoms
(see core/detect.py), keeping every output row-indexed.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_INT_MIN = np.int32(np.iinfo(np.int32).min)
_INT_MAX = np.int32(np.iinfo(np.int32).max)


def _ident(dtype, reduce):
    if jnp.issubdtype(dtype, jnp.integer):
        return _INT_MAX if reduce == "min" else _INT_MIN
    return jnp.array(np.inf if reduce == "min" else -np.inf, dtype)


def _tile_possible(op, lmin, lmax, rmin, rmax):
    """Can ``l op r`` hold for ANY (l, r) with l in [lmin,lmax], r in [rmin,rmax]?"""
    if op == "<":
        return lmin < rmax
    if op == "<=":
        return lmin <= rmax
    if op == ">":
        return lmax > rmin
    if op == ">=":
        return lmax >= rmin
    if op == "==":
        return (lmin <= rmax) & (rmin <= lmax)
    if op == "!=":  # only impossible when both ranges are the same singleton
        return ~((lmin == lmax) & (rmin == rmax) & (lmin == rmin))
    raise ValueError(op)


def _cmp(op, a, b):
    return {
        "==": lambda: a == b,
        "!=": lambda: a != b,
        "<": lambda: a < b,
        "<=": lambda: a <= b,
        ">": lambda: a > b,
        ">=": lambda: a >= b,
    }[op]()


def _kernel(
    ops: Tuple[str, ...],
    reduces: Tuple[str, ...],
    bm: int,
    bn: int,
    row_lo: int,
    col_lo: int,
    *refs,
):
    n_atoms = len(ops)
    # ref layout: l[a] (bm,), r[a] (bn,), rs (bm,), cs (bn,),
    #             lmin[a] (1,), lmax[a] (1,), rmin[a] (1,), rmax[a] (1,),
    #             out: count (bm,), stat[a] (bm,)
    it = iter(refs)

    def take(count):
        return tuple(next(it) for _ in range(count))

    lv = take(n_atoms)
    r = take(n_atoms)
    (rs,) = take(1)
    (cs,) = take(1)
    lmin = take(n_atoms)
    lmax = take(n_atoms)
    rmin = take(n_atoms)
    rmax = take(n_atoms)
    (count_ref,) = take(1)
    stat_refs = take(n_atoms)

    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)
        for a in range(n_atoms):
            stat_refs[a][...] = jnp.full_like(
                stat_refs[a], _ident(stat_refs[a].dtype, reduces[a])
            )

    # ---- tile pruning from prefetched block bounds (paper's partition
    # pruning): every atom must be satisfiable somewhere in the tile.
    possible = jnp.bool_(True)
    for a, op in enumerate(ops):
        possible = possible & _tile_possible(
            op, lmin[a][0], lmax[a][0], rmin[a][0], rmax[a][0]
        )

    @pl.when(possible)
    def _compute():
        # row/col ids are GLOBAL indices: a strip-scoped launch (row_lo or
        # col_lo > 0) shifts the grid but the diagonal exclusion still
        # compares untranslated positions.
        row_ids = (row_lo + i) * bm + jax.lax.broadcasted_iota(
            jnp.int32, (bm, bn), 0
        )
        col_ids = (col_lo + j) * bn + jax.lax.broadcasted_iota(
            jnp.int32, (bm, bn), 1
        )
        viol = (
            (rs[...] > 0)[:, None]
            & (cs[...] > 0)[None, :]
            & (row_ids != col_ids)
        )
        for a, op in enumerate(ops):
            viol = viol & _cmp(op, lv[a][...][:, None], r[a][...][None, :])
        count_ref[...] += jnp.sum(viol.astype(jnp.int32), axis=1)
        for a, red in enumerate(reduces):
            ident = _ident(stat_refs[a].dtype, red)
            vals = jnp.where(viol, r[a][...][None, :], ident)
            tile = jnp.min(vals, axis=1) if red == "min" else jnp.max(vals, axis=1)
            stat_refs[a][...] = (
                jnp.minimum(stat_refs[a][...], tile)
                if red == "min"
                else jnp.maximum(stat_refs[a][...], tile)
            )


def dc_role_scan_pallas(
    l_cols: Sequence[jnp.ndarray],
    r_cols: Sequence[jnp.ndarray],
    ops: Sequence[str],
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    reduces: Sequence[str],
    block: int = 256,
    interpret: bool = False,
    row_blocks: Optional[Tuple[int, int]] = None,
    col_blocks: Optional[Tuple[int, int]] = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray]]:
    """Blocked theta-join violation scan (see module docstring).

    Shapes are padded to a multiple of ``block``; padded rows are scoped out.

    ``row_blocks=(lo, hi)`` is the strip-scoped entry (DESIGN.md §11): the
    grid only launches row blocks in ``[lo, hi)`` — a partition-strip of the
    comparison matrix — so a strip scan costs ``(hi - lo) * nb`` tiles
    instead of the ``nb * nb`` full grid.  Rows outside the launched range
    get count 0 and the reduce identity, exactly as if they were scoped out.

    ``col_blocks=(lo, hi)`` symmetrically restricts the PARTNER grid
    dimension — the ingest-delta entry (DESIGN.md §12): checked rows scan
    only the fresh column strip, ``nrb * (hi - lo)`` tiles.  Partners
    outside the range simply never contribute, as if scoped out.
    """
    n_atoms = len(ops)
    n = l_cols[0].shape[0]
    bm = bn = block
    nb = -(-n // block)
    npad = nb * block
    row_lo, row_hi = (0, nb) if row_blocks is None else row_blocks
    if not (0 <= row_lo < row_hi <= nb):
        raise ValueError(f"row_blocks {row_blocks!r} outside grid [0, {nb})")
    nrb = row_hi - row_lo
    col_lo, col_hi = (0, nb) if col_blocks is None else col_blocks
    if not (0 <= col_lo < col_hi <= nb):
        raise ValueError(f"col_blocks {col_blocks!r} outside grid [0, {nb})")
    ncb = col_hi - col_lo

    def pad1(x, fill=0):
        return jnp.pad(x, (0, npad - n), constant_values=fill)

    rs = pad1(row_scope).astype(jnp.int32)
    cs = pad1(col_scope).astype(jnp.int32)
    lp = [pad1(c) for c in l_cols]
    rp = [pad1(c) for c in r_cols]

    # scope-masked per-block bounds (identity outside scope keeps pruning sound)
    def block_bounds(vals, scope, reduce):
        ident = _ident(vals.dtype, reduce)
        masked = jnp.where(scope > 0, vals, ident)
        resh = masked.reshape(nb, block)
        return jnp.min(resh, axis=1) if reduce == "min" else jnp.max(resh, axis=1)

    lmin = [block_bounds(c, rs, "min") for c in lp]
    lmax = [block_bounds(c, rs, "max") for c in lp]
    rmin = [block_bounds(c, cs, "min") for c in rp]
    rmax = [block_bounds(c, cs, "max") for c in rp]

    # row-side inputs index from the strip offset; outputs are compact over
    # the launched range (Pallas leaves unvisited output blocks undefined,
    # so the full-width result is stitched back on the host side below).
    row_spec = pl.BlockSpec((bm,), lambda i, j: (row_lo + i,))
    col_spec = pl.BlockSpec((bn,), lambda i, j: (col_lo + j,))
    bound_i = pl.BlockSpec((1,), lambda i, j: (row_lo + i,))
    bound_j = pl.BlockSpec((1,), lambda i, j: (col_lo + j,))
    out_spec = pl.BlockSpec((bm,), lambda i, j: (i,))

    in_specs = (
        [row_spec] * n_atoms  # l
        + [col_spec] * n_atoms  # r
        + [row_spec, col_spec]  # rs, cs
        + [bound_i] * n_atoms  # lmin
        + [bound_i] * n_atoms  # lmax
        + [bound_j] * n_atoms  # rmin
        + [bound_j] * n_atoms  # rmax
    )
    out_specs = [out_spec] + [out_spec] * n_atoms
    out_shape = [jax.ShapeDtypeStruct((nrb * block,), jnp.int32)] + [
        jax.ShapeDtypeStruct((nrb * block,), c.dtype) for c in r_cols
    ]

    kernel = functools.partial(
        _kernel, tuple(ops), tuple(reduces), bm, bn, row_lo, col_lo
    )
    outs = pl.pallas_call(
        kernel,
        grid=(nrb, ncb),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*lp, *rp, rs, cs, *lmin, *lmax, *rmin, *rmax)
    if row_blocks is None:
        count = outs[0][:n]
        stats = [s[:n] for s in outs[1:]]
        return count, stats
    # stitch the strip back into full-width outputs: unlaunched rows take
    # count 0 / the reduce identity (what the full grid gives scoped-out rows)
    lo_row = row_lo * block
    count = (
        jnp.zeros((npad,), jnp.int32)
        .at[lo_row : lo_row + nrb * block]
        .set(outs[0])[:n]
    )
    stats = [
        jnp.full((npad,), _ident(c.dtype, red), c.dtype)
        .at[lo_row : lo_row + nrb * block]
        .set(s)[:n]
        for s, c, red in zip(outs[1:], r_cols, reduces)
    ]
    return count, stats
