"""Pallas TPU kernels: blocked theta-join scans for DC violation detection.

The paper's DC error detection partitions the cartesian-product matrix into
``p`` partitions and prunes partitions whose boundary ranges cannot produce a
violation (§4.2, Fig. 3/4).  On TPU this becomes a grid of (BM, BN) VMEM
tiles over the comparison matrix:

* **block-sparse worklist grid** (DESIGN.md §15): the launch is a 1-D grid
  over a host-built worklist of *active* tile pairs — the cross product of
  the active row-block ids and active col-block ids.  The two id arrays are
  scalar-prefetched (``pltpu.PrefetchScalarGridSpec``) so the BlockSpec
  index maps read ``rid[g // ncols]`` / ``cid[g % ncols]`` before the tile's
  DMAs are issued; checked x checked tile pairs are never launched and never
  move bytes.  A contiguous ``(lo, hi)`` range and the dense grid are just
  worklists that happen to be ``arange``s — one code path for all of them;
* per-tile **bound pruning**: per-block min/max of each atom column are
  precomputed (scope-masked) and indexed by the same prefetched ids; a tile
  whose bounds make some atom unsatisfiable everywhere skips its body with
  ``@pl.when`` — the paper's partition pruning, at tile granularity, on top
  of the worklist sparsity;
* the 8x128-lane VPU evaluates the atom predicates for all BM*BN pairs of
  the tile at once (the Spark version loops over JVM tuples);
* outputs are row-indexed (violation count + per-atom extremal partner
  value, which is the bound of the candidate *range* fix, Example 4) and
  accumulate across the worklist's column-innermost order — each output
  block is revisited consecutively, as the TPU grid requires.

Two entry points share the machinery: ``dc_role_scan_pallas`` is the
single-role scan, and ``dc_pair_scan_pallas`` fuses BOTH tuple roles (t1
with the atoms as written, t2 with them flipped — see core/detect.py) into
one launch over one worklist, loading each distinct atom column once per
tile instead of twice (DESIGN.md §15's fusion contract).
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ident(dtype, reduce):
    """Reduce identity in the array's OWN dtype (int8 atoms carry int8
    identities — the host-side stat decode maps them back, DESIGN.md §15)."""
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return jnp.array(info.max if reduce == "min" else info.min, dtype)
    return jnp.array(np.inf if reduce == "min" else -np.inf, dtype)


def _tile_possible(op, lmin, lmax, rmin, rmax):
    """Can ``l op r`` hold for ANY (l, r) with l in [lmin,lmax], r in [rmin,rmax]?"""
    if op == "<":
        return lmin < rmax
    if op == "<=":
        return lmin <= rmax
    if op == ">":
        return lmax > rmin
    if op == ">=":
        return lmax >= rmin
    if op == "==":
        return (lmin <= rmax) & (rmin <= lmax)
    if op == "!=":  # only impossible when both ranges are the same singleton
        return ~((lmin == lmax) & (rmin == rmax) & (lmin == rmin))
    raise ValueError(op)


def _cmp(op, a, b):
    return {
        "==": lambda: a == b,
        "!=": lambda: a != b,
        "<": lambda: a < b,
        "<=": lambda: a <= b,
        ">": lambda: a > b,
        ">=": lambda: a >= b,
    }[op]()


def resolve_block_ids(
    nb: int,
    blocks: Optional[Tuple[int, int]] = None,
    block_ids=None,
) -> np.ndarray:
    """Normalize a grid restriction into the sorted, deduped worklist-side
    id array: explicit ``block_ids`` win, else the contiguous ``(lo, hi)``
    range, else the full grid.  Every launch path funnels through this, so
    dense and contiguous-strip scans are just worklists that happen to be
    ``arange``s."""
    if block_ids is not None:
        ids = np.unique(np.asarray(block_ids, dtype=np.int32).ravel())
        if ids.size and (ids[0] < 0 or ids[-1] >= nb):
            raise ValueError(f"block ids {ids!r} outside grid [0, {nb})")
        return ids
    if blocks is None:
        return np.arange(nb, dtype=np.int32)
    lo, hi = blocks
    if not (0 <= lo < hi <= nb):
        raise ValueError(f"blocks {blocks!r} outside grid [0, {nb})")
    return np.arange(lo, hi, dtype=np.int32)


def _empty_role_outputs(n, r_cols, reduces):
    """What a scan with an empty worklist returns: count 0 and the reduce
    identity everywhere — exactly the full grid's value for scoped-out rows."""
    count = jnp.zeros((n,), jnp.int32)
    stats = [
        jnp.full((n,), _ident(c.dtype, red), c.dtype)
        for c, red in zip(r_cols, reduces)
    ]
    return count, stats


def _stitch(outs, row_ids, block, npad, n, r_cols, reduces):
    """Scatter worklist-compact outputs back to full row width: rows in
    unlaunched blocks take count 0 / the reduce identity (what the dense
    grid gives scoped-out rows)."""
    nb = npad // block
    if row_ids.size == nb:  # dense row coverage: outputs are already in order
        return outs[0][:n], [s[:n] for s in outs[1:]]
    ridx = jnp.asarray(
        (row_ids[:, None] * block + np.arange(block)[None, :]).reshape(-1)
    )
    count = jnp.zeros((npad,), jnp.int32).at[ridx].set(outs[0])[:n]
    stats = [
        jnp.full((npad,), _ident(c.dtype, red), c.dtype).at[ridx].set(s)[:n]
        for s, c, red in zip(outs[1:], r_cols, reduces)
    ]
    return count, stats


def _block_bounds(vals, scope, reduce, nb, block):
    """Scope-masked per-block bounds (identity outside scope keeps the
    ``@pl.when`` pruning sound)."""
    ident = _ident(vals.dtype, reduce)
    masked = jnp.where(scope > 0, vals, ident)
    resh = masked.reshape(nb, block)
    return jnp.min(resh, axis=1) if reduce == "min" else jnp.max(resh, axis=1)


# --------------------------------------------------------- single-role kernel
def _role_kernel(
    ops: Tuple[str, ...],
    reduces: Tuple[str, ...],
    bm: int,
    bn: int,
    ncols: int,
    *refs,
):
    n_atoms = len(ops)
    # scalar-prefetch refs first (the worklist id arrays), then
    # l[a] (bm,), r[a] (bn,), rs (bm,), cs (bn,),
    # lmin[a] lmax[a] rmin[a] rmax[a] (1,) each, out: count (bm,), stat[a] (bm,)
    it = iter(refs)

    def take(count):
        return tuple(next(it) for _ in range(count))

    (rid_ref,) = take(1)
    (cid_ref,) = take(1)
    lv = take(n_atoms)
    r = take(n_atoms)
    (rs,) = take(1)
    (cs,) = take(1)
    lmin = take(n_atoms)
    lmax = take(n_atoms)
    rmin = take(n_atoms)
    rmax = take(n_atoms)
    (count_ref,) = take(1)
    stat_refs = take(n_atoms)

    g = pl.program_id(0)

    @pl.when(g % ncols == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)
        for a in range(n_atoms):
            stat_refs[a][...] = jnp.full_like(
                stat_refs[a], _ident(stat_refs[a].dtype, reduces[a])
            )

    # ---- tile pruning from prefetched block bounds (paper's partition
    # pruning): every atom must be satisfiable somewhere in the tile.
    possible = jnp.bool_(True)
    for a, op in enumerate(ops):
        possible = possible & _tile_possible(
            op, lmin[a][0], lmax[a][0], rmin[a][0], rmax[a][0]
        )

    @pl.when(possible)
    def _compute():
        # row/col ids are GLOBAL indices read from the prefetched worklist:
        # the diagonal exclusion compares untranslated positions no matter
        # which tile pairs actually launch.
        row_ids = rid_ref[g // ncols] * bm + jax.lax.broadcasted_iota(
            jnp.int32, (bm, bn), 0
        )
        col_ids = cid_ref[g % ncols] * bn + jax.lax.broadcasted_iota(
            jnp.int32, (bm, bn), 1
        )
        viol = (
            (rs[...] > 0)[:, None]
            & (cs[...] > 0)[None, :]
            & (row_ids != col_ids)
        )
        for a, op in enumerate(ops):
            viol = viol & _cmp(op, lv[a][...][:, None], r[a][...][None, :])
        count_ref[...] += jnp.sum(viol.astype(jnp.int32), axis=1)
        for a, red in enumerate(reduces):
            ident = _ident(stat_refs[a].dtype, red)
            vals = jnp.where(viol, r[a][...][None, :], ident)
            tile = jnp.min(vals, axis=1) if red == "min" else jnp.max(vals, axis=1)
            stat_refs[a][...] = (
                jnp.minimum(stat_refs[a][...], tile)
                if red == "min"
                else jnp.maximum(stat_refs[a][...], tile)
            )


def _worklist_specs(bm, bn, ncols):
    """BlockSpecs for a 1-D worklist launch: row-side inputs index through
    the prefetched ``rid`` array, col-side through ``cid``; outputs are
    compact over the worklist's row order (stitched back host-side).
    Returns ``(row, col, bound_row, bound_col, out)`` specs for callers to
    compose in their own operand order."""
    row_spec = pl.BlockSpec((bm,), lambda g, rid, cid: (rid[g // ncols],))
    col_spec = pl.BlockSpec((bn,), lambda g, rid, cid: (cid[g % ncols],))
    bound_i = pl.BlockSpec((1,), lambda g, rid, cid: (rid[g // ncols],))
    bound_j = pl.BlockSpec((1,), lambda g, rid, cid: (cid[g % ncols],))
    out_spec = pl.BlockSpec((bm,), lambda g, rid, cid: (g // ncols,))
    return row_spec, col_spec, bound_i, bound_j, out_spec


def dc_role_scan_pallas(
    l_cols: Sequence[jnp.ndarray],
    r_cols: Sequence[jnp.ndarray],
    ops: Sequence[str],
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    reduces: Sequence[str],
    block: int = 256,
    interpret: bool = False,
    row_blocks: Optional[Tuple[int, int]] = None,
    col_blocks: Optional[Tuple[int, int]] = None,
    row_block_ids=None,
    col_block_ids=None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray]]:
    """Blocked theta-join violation scan, one role (see module docstring).

    Shapes are padded to a multiple of ``block``; padded rows are scoped out.

    ``row_block_ids`` / ``col_block_ids`` are the block-sparse worklist
    entry (DESIGN.md §15): only the cross product of the given row and col
    block ids is launched — the executor passes the ledger's cold block
    geometry so checked x checked tile pairs never launch.  ``row_blocks``
    / ``col_blocks`` are the contiguous ``(lo, hi)`` sugar (the §11 strip
    entry and §12 ingest-delta entry); they resolve to ``arange``
    worklists.  Rows outside the launched blocks get count 0 and the
    reduce identity, exactly as if they were scoped out."""
    n_atoms = len(ops)
    n = l_cols[0].shape[0]
    bm = bn = block
    nb = -(-n // block)
    npad = nb * block
    rid = resolve_block_ids(nb, row_blocks, row_block_ids)
    cid = resolve_block_ids(nb, col_blocks, col_block_ids)
    if rid.size == 0 or cid.size == 0:
        return _empty_role_outputs(n, r_cols, reduces)
    nrows, ncols = rid.size, cid.size

    def pad1(x, fill=0):
        return jnp.pad(x, (0, npad - n), constant_values=fill)

    rs = pad1(row_scope).astype(jnp.int32)
    cs = pad1(col_scope).astype(jnp.int32)
    lp = [pad1(c) for c in l_cols]
    rp = [pad1(c) for c in r_cols]

    lmin = [_block_bounds(c, rs, "min", nb, block) for c in lp]
    lmax = [_block_bounds(c, rs, "max", nb, block) for c in lp]
    rmin = [_block_bounds(c, cs, "min", nb, block) for c in rp]
    rmax = [_block_bounds(c, cs, "max", nb, block) for c in rp]

    row_s, col_s, b_i, b_j, out_s = _worklist_specs(bm, bn, ncols)
    in_specs = (
        [row_s] * n_atoms + [col_s] * n_atoms + [row_s, col_s]
        + [b_i] * 2 * n_atoms + [b_j] * 2 * n_atoms
    )
    out_shape = [jax.ShapeDtypeStruct((nrows * block,), jnp.int32)] + [
        jax.ShapeDtypeStruct((nrows * block,), c.dtype) for c in r_cols
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nrows * ncols,),
        in_specs=in_specs,
        out_specs=[out_s] * (1 + n_atoms),
    )
    kernel = functools.partial(
        _role_kernel, tuple(ops), tuple(reduces), bm, bn, ncols
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(
        jnp.asarray(rid), jnp.asarray(cid),
        *lp, *rp, rs, cs, *lmin, *lmax, *rmin, *rmax,
    )
    return _stitch(outs, rid, block, npad, n, r_cols, reduces)


# ---------------------------------------------------------- fused-role kernel
def _pair_kernel(
    ops: Tuple[str, ...],
    flipped: Tuple[str, ...],
    t1_reduces: Tuple[str, ...],
    t2_reduces: Tuple[str, ...],
    l_idx: Tuple[int, ...],
    r_idx: Tuple[int, ...],
    n_distinct: int,
    bm: int,
    bn: int,
    ncols: int,
    *refs,
):
    """Both tuple roles in one tile visit (DESIGN.md §15 fusion contract):
    role t1 evaluates the atoms as written over (row, col), role t2 the
    flipped atoms — each distinct atom column's row and col tiles are
    loaded ONCE and serve both roles."""
    n_atoms = len(ops)
    it = iter(refs)

    def take(count):
        return tuple(next(it) for _ in range(count))

    (rid_ref,) = take(1)
    (cid_ref,) = take(1)
    rowv = take(n_distinct)  # distinct columns, row-side tiles
    (rs,) = take(1)
    colv = take(n_distinct)  # distinct columns, col-side tiles
    (cs,) = take(1)
    row_min = take(n_distinct)  # per-block bounds under the ROW scope
    row_max = take(n_distinct)
    col_min = take(n_distinct)  # per-block bounds under the COL scope
    col_max = take(n_distinct)
    (t1_count_ref,) = take(1)
    (t2_count_ref,) = take(1)
    t1_stat_refs = take(n_atoms)
    t2_stat_refs = take(n_atoms)

    g = pl.program_id(0)

    @pl.when(g % ncols == 0)
    def _init():
        t1_count_ref[...] = jnp.zeros_like(t1_count_ref)
        t2_count_ref[...] = jnp.zeros_like(t2_count_ref)
        for a in range(n_atoms):
            t1_stat_refs[a][...] = jnp.full_like(
                t1_stat_refs[a], _ident(t1_stat_refs[a].dtype, t1_reduces[a])
            )
            t2_stat_refs[a][...] = jnp.full_like(
                t2_stat_refs[a], _ident(t2_stat_refs[a].dtype, t2_reduces[a])
            )

    possible1 = jnp.bool_(True)
    possible2 = jnp.bool_(True)
    for a, (op, fop) in enumerate(zip(ops, flipped)):
        li, ri = l_idx[a], r_idx[a]
        possible1 = possible1 & _tile_possible(
            op, row_min[li][0], row_max[li][0], col_min[ri][0], col_max[ri][0]
        )
        possible2 = possible2 & _tile_possible(
            fop, row_min[ri][0], row_max[ri][0], col_min[li][0], col_max[li][0]
        )

    row_ids = rid_ref[g // ncols] * bm + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bn), 0
    )
    col_ids = cid_ref[g % ncols] * bn + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bn), 1
    )
    base = (
        (rs[...] > 0)[:, None]
        & (cs[...] > 0)[None, :]
        & (row_ids != col_ids)
    )

    def accumulate(viol, count_ref, stat_refs, stat_src, reduces):
        count_ref[...] += jnp.sum(viol.astype(jnp.int32), axis=1)
        for a, red in enumerate(reduces):
            ident = _ident(stat_refs[a].dtype, red)
            vals = jnp.where(viol, colv[stat_src[a]][...][None, :], ident)
            tile = jnp.min(vals, axis=1) if red == "min" else jnp.max(vals, axis=1)
            stat_refs[a][...] = (
                jnp.minimum(stat_refs[a][...], tile)
                if red == "min"
                else jnp.maximum(stat_refs[a][...], tile)
            )

    @pl.when(possible1)
    def _role_t1():
        viol = base
        for a, op in enumerate(ops):
            viol = viol & _cmp(
                op, rowv[l_idx[a]][...][:, None], colv[r_idx[a]][...][None, :]
            )
        accumulate(viol, t1_count_ref, t1_stat_refs, r_idx, t1_reduces)

    @pl.when(possible2)
    def _role_t2():
        viol = base
        for a, fop in enumerate(flipped):
            viol = viol & _cmp(
                fop, rowv[r_idx[a]][...][:, None], colv[l_idx[a]][...][None, :]
            )
        accumulate(viol, t2_count_ref, t2_stat_refs, l_idx, t2_reduces)


def distinct_columns(
    l_cols: Sequence[jnp.ndarray], r_cols: Sequence[jnp.ndarray]
) -> Tuple[List[jnp.ndarray], Tuple[int, ...], Tuple[int, ...]]:
    """Dedup the atom columns by array identity: same-attribute atoms (the
    common DC shape) load one tile per side for both roles.  Returns the
    distinct column list plus per-atom indices into it."""
    distinct: List[jnp.ndarray] = []
    index: dict = {}

    def at(col):
        key = id(col)
        if key not in index:
            index[key] = len(distinct)
            distinct.append(col)
        return index[key]

    l_idx = tuple(at(c) for c in l_cols)
    r_idx = tuple(at(c) for c in r_cols)
    return distinct, l_idx, r_idx


def dc_pair_scan_pallas(
    l_cols: Sequence[jnp.ndarray],
    r_cols: Sequence[jnp.ndarray],
    ops: Sequence[str],
    flipped: Sequence[str],
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    t1_reduces: Sequence[str],
    t2_reduces: Sequence[str],
    block: int = 256,
    interpret: bool = False,
    row_blocks: Optional[Tuple[int, int]] = None,
    col_blocks: Optional[Tuple[int, int]] = None,
    row_block_ids=None,
    col_block_ids=None,
):
    """Fused BOTH-role scan: one worklist launch computes the t1 detection
    (atoms as written) and the t2 detection (``flipped`` atoms) over the
    same tiles — the relax→detect role scans that used to be two separate
    launches over identical tile pairs (DESIGN.md §15).

    Returns ``(t1_count, t1_stats, t2_count, t2_stats)``, each full row
    width, bit-identical to two ``dc_role_scan`` launches."""
    n_atoms = len(ops)
    n = l_cols[0].shape[0]
    bm = bn = block
    nb = -(-n // block)
    npad = nb * block
    rid = resolve_block_ids(nb, row_blocks, row_block_ids)
    cid = resolve_block_ids(nb, col_blocks, col_block_ids)
    if rid.size == 0 or cid.size == 0:
        t1c, t1s = _empty_role_outputs(n, r_cols, t1_reduces)
        t2c, t2s = _empty_role_outputs(n, l_cols, t2_reduces)
        return t1c, t1s, t2c, t2s
    nrows, ncols = rid.size, cid.size

    distinct, l_idx, r_idx = distinct_columns(l_cols, r_cols)
    n_distinct = len(distinct)

    def pad1(x, fill=0):
        return jnp.pad(x, (0, npad - n), constant_values=fill)

    rs = pad1(row_scope).astype(jnp.int32)
    cs = pad1(col_scope).astype(jnp.int32)
    dp = [pad1(c) for c in distinct]
    row_min = [_block_bounds(c, rs, "min", nb, block) for c in dp]
    row_max = [_block_bounds(c, rs, "max", nb, block) for c in dp]
    col_min = [_block_bounds(c, cs, "min", nb, block) for c in dp]
    col_max = [_block_bounds(c, cs, "max", nb, block) for c in dp]

    row_s, col_s, b_i, b_j, out_s = _worklist_specs(bm, bn, ncols)
    in_specs = (
        [row_s] * n_distinct + [row_s] + [col_s] * n_distinct + [col_s]
        + [b_i] * 2 * n_distinct + [b_j] * 2 * n_distinct
    )
    out_shape = (
        [jax.ShapeDtypeStruct((nrows * block,), jnp.int32)] * 2
        + [jax.ShapeDtypeStruct((nrows * block,), c.dtype) for c in r_cols]
        + [jax.ShapeDtypeStruct((nrows * block,), c.dtype) for c in l_cols]
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nrows * ncols,),
        in_specs=in_specs,
        out_specs=[out_s] * (2 + 2 * n_atoms),
    )
    kernel = functools.partial(
        _pair_kernel, tuple(ops), tuple(flipped), tuple(t1_reduces),
        tuple(t2_reduces), l_idx, r_idx, n_distinct, bm, bn, ncols,
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(
        jnp.asarray(rid), jnp.asarray(cid),
        *dp, rs, *dp, cs, *row_min, *row_max, *col_min, *col_max,
    )
    # outs order mirrors out_shape: t1_count, t2_count, t1_stats, t2_stats
    t1c, t1s = _stitch(
        (outs[0],) + tuple(outs[2:2 + n_atoms]), rid, block, npad, n,
        r_cols, t1_reduces,
    )
    t2c, t2s = _stitch(
        (outs[1],) + tuple(outs[2 + n_atoms:]), rid, block, npad, n,
        l_cols, t2_reduces,
    )
    return t1c, t1s, t2c, t2s
