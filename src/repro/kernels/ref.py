"""Pure-jnp oracles for every Pallas kernel.

These are the semantics-defining implementations: each Pallas kernel is
validated against the function here (interpret mode on CPU, shape/dtype
sweeps in tests/test_kernels.py).  They are also the execution path picked by
``ops.py`` when not running on TPU, so the whole system works on CPU.

The pairwise scans are blocked with ``lax.fori_loop`` over column tiles so
the oracle itself never materializes the O(n^2) matrix.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dc_pairs import resolve_block_ids


def _apply_op(a: jnp.ndarray, op: str, b: jnp.ndarray) -> jnp.ndarray:
    if op == "==":
        return a == b
    if op == "!=":
        return a != b
    if op == "<":
        return a < b
    if op == "<=":
        return a <= b
    if op == ">":
        return a > b
    if op == ">=":
        return a >= b
    raise ValueError(op)


def _identity(dtype, reduce: str):
    """Reduce identity in the array's OWN dtype — int8-encoded atoms must
    carry int8 identities or the sentinel overflows (DESIGN.md §15)."""
    if reduce not in ("min", "max"):
        raise ValueError(reduce)
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return jnp.array(info.max if reduce == "min" else info.min, dtype)
    return jnp.array(np.inf if reduce == "min" else -np.inf, dtype)


def dc_role_scan(
    l_cols: Sequence[jnp.ndarray],
    r_cols: Sequence[jnp.ndarray],
    ops: Sequence[str],
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    reduces: Sequence[str],
    block: int = 256,
    row_blocks: Tuple[int, int] | None = None,
    col_blocks: Tuple[int, int] | None = None,
    row_block_ids=None,
    col_block_ids=None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray]]:
    """Oracle for the ``dc_pairs`` theta-join kernel (one role).

    For every row i in ``row_scope``, scan partners j in ``col_scope``
    (i != j).  A pair violates iff ALL atoms hold: ``l_cols[a][i] op_a
    r_cols[a][j]``.  Returns:

    * ``count``: (n,) int32 — number of violating partners of i,
    * ``stats[a]``: (n,) — min or max (per ``reduces[a]``) of ``r_cols[a][j]``
      over i's violating partners; identity value when count == 0.

    ``row_blocks=(lo, hi)`` restricts the scan to the row-block strip
    ``[lo * block, hi * block)`` (DESIGN.md §11): only that row slice is
    scanned against every column tile; rows outside take count 0 and the
    reduce identity, exactly as the full scan gives scoped-out rows.

    ``col_blocks=(lo, hi)`` symmetrically restricts the PARTNER side to
    that block range — the ingest-delta entry (DESIGN.md §12): scanning
    checked rows against only the freshly-appended column strip makes the
    delta cost O(checked x fresh) instead of O(checked x n).

    ``row_block_ids`` / ``col_block_ids`` generalize both to an arbitrary
    set of block ids — the ledger's block-sparse worklist (DESIGN.md §15):
    only the cross product of the given row and col blocks is scanned.
    All four restrictions resolve through ``resolve_block_ids``, so these
    ARE the mask semantics the Pallas worklist kernel is validated against.
    """
    n = l_cols[0].shape[0]
    nb = -(-n // block)
    rid = resolve_block_ids(nb, row_blocks, row_block_ids)
    cid = resolve_block_ids(nb, col_blocks, col_block_ids)
    idents = [_identity(r.dtype, red) for r, red in zip(r_cols, reduces)]
    if rid.size == 0 or cid.size == 0:
        return (
            jnp.zeros((n,), jnp.int32),
            [jnp.full((n,), idents[a], r_cols[a].dtype) for a in range(len(ops))],
        )
    npad = nb * block
    pad = npad - n
    cs = jnp.pad(col_scope, (0, pad))
    r_pad = [jnp.pad(r, (0, pad)) for r in r_cols]
    # gather the worklist's row blocks into a compact strip; GLOBAL row ids
    # ride along so the diagonal exclusion compares untranslated positions
    ridx = (rid[:, None] * block + np.arange(block)[None, :]).reshape(-1)
    jridx = jnp.asarray(ridx)
    rs = jnp.pad(row_scope, (0, pad))[jridx]
    l_g = [jnp.pad(c, (0, pad))[jridx] for c in l_cols]
    row_ids = jridx.astype(jnp.int32)
    m = ridx.size
    cid_arr = jnp.asarray(cid)

    def body(t, state):
        count, stats = state
        sl = cid_arr[t] * block
        cs_t = jax.lax.dynamic_slice_in_dim(cs, sl, block)
        col_ids = sl + jnp.arange(block, dtype=jnp.int32)
        viol = rs[:, None] & cs_t[None, :] & (row_ids[:, None] != col_ids[None, :])
        for a, (lcol, op) in enumerate(zip(l_g, ops)):
            r_t = jax.lax.dynamic_slice_in_dim(r_pad[a], sl, block)
            viol = viol & _apply_op(lcol[:, None], op, r_t[None, :])
        count = count + jnp.sum(viol.astype(jnp.int32), axis=1)
        new_stats = []
        for a, red in enumerate(reduces):
            r_t = jax.lax.dynamic_slice_in_dim(r_pad[a], sl, block)
            vals = jnp.where(viol, r_t[None, :], idents[a])
            tile_stat = jnp.min(vals, axis=1) if red == "min" else jnp.max(vals, axis=1)
            combined = (
                jnp.minimum(stats[a], tile_stat)
                if red == "min"
                else jnp.maximum(stats[a], tile_stat)
            )
            new_stats.append(combined)
        return count, tuple(new_stats)

    init = (
        jnp.zeros((m,), jnp.int32),
        tuple(jnp.full((m,), idents[a], r_cols[a].dtype) for a in range(len(ops))),
    )
    count, stats = jax.lax.fori_loop(0, int(cid.size), body, init)
    if rid.size == nb:  # dense row coverage: compact outputs are in order
        return count[:n], [s[:n] for s in stats]
    # stitch the worklist strip back into full-width outputs (unscanned rows
    # get the same values the full scan gives scoped-out rows)
    count = jnp.zeros((npad,), jnp.int32).at[jridx].set(count)[:n]
    stats = [
        jnp.full((npad,), idents[a], r_cols[a].dtype).at[jridx].set(s)[:n]
        for a, s in enumerate(stats)
    ]
    return count, stats


def dc_pair_scan(
    l_cols: Sequence[jnp.ndarray],
    r_cols: Sequence[jnp.ndarray],
    ops: Sequence[str],
    flipped: Sequence[str],
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    t1_reduces: Sequence[str],
    t2_reduces: Sequence[str],
    block: int = 256,
    row_blocks: Tuple[int, int] | None = None,
    col_blocks: Tuple[int, int] | None = None,
    row_block_ids=None,
    col_block_ids=None,
):
    """Oracle for the fused both-role scan: role t1 evaluates the atoms as
    written, role t2 the ``flipped`` atoms with the column sides swapped
    (core/detect.py's second launch).  Fusion is an execution detail of the
    Pallas kernel — the oracle simply runs the two role scans."""
    restr = dict(
        block=block, row_blocks=row_blocks, col_blocks=col_blocks,
        row_block_ids=row_block_ids, col_block_ids=col_block_ids,
    )
    t1_count, t1_stats = dc_role_scan(
        l_cols, r_cols, ops, row_scope, col_scope, t1_reduces, **restr
    )
    t2_count, t2_stats = dc_role_scan(
        r_cols, l_cols, flipped, row_scope, col_scope, t2_reduces, **restr
    )
    return t1_count, t1_stats, t2_count, t2_stats


def semijoin(
    query: jnp.ndarray,
    query_mask: jnp.ndarray,
    keys: jnp.ndarray,
    keys_mask: jnp.ndarray,
    block: int = 512,
) -> jnp.ndarray:
    """Oracle for the ``semijoin`` membership kernel (single key column).

    ``(n,) bool``: query[i] appears among keys[j] with keys_mask[j].
    """
    m = keys.shape[0]
    nb = -(-m // block)
    pad = nb * block - m
    kp = jnp.pad(keys, (0, pad))
    km = jnp.pad(keys_mask, (0, pad))

    def body(jb, found):
        sl = jb * block
        k_t = jax.lax.dynamic_slice_in_dim(kp, sl, block)
        m_t = jax.lax.dynamic_slice_in_dim(km, sl, block)
        hit = jnp.any((query[:, None] == k_t[None, :]) & m_t[None, :], axis=1)
        return found | hit

    found = jax.lax.fori_loop(0, nb, body, jnp.zeros(query.shape, bool))
    return found & query_mask


def attention_blocked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_kv: int = 1024,
) -> jnp.ndarray:
    """Memory-bounded attention: the same online-softmax tiling as the
    Pallas kernel, expressed as nested ``lax.scan``s in pure jnp.  Live
    temporaries are (b, h, block_q, block_kv) — this is the execution path
    for long sequences off-TPU (the naive oracle materializes O(s^2)).

    The kv scan body is rematerialized so the backward pass replays tiles
    instead of stashing every (bq, bkv) probability block.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    assert sq % block_q == 0 and sk % block_kv == 0
    nq, nk = sq // block_q, sk // block_kv
    kr = k if group == 1 else jnp.repeat(k, group, axis=1)
    vr = v if group == 1 else jnp.repeat(v, group, axis=1)
    # layout: (nq, b, hq, block_q, d)
    qb = q.reshape(b, hq, nq, block_q, d).transpose(2, 0, 1, 3, 4)
    kb = kr.reshape(b, hq, nk, block_kv, d).transpose(2, 0, 1, 3, 4)
    vb = vr.reshape(b, hq, nk, block_kv, d).transpose(2, 0, 1, 3, 4)

    def q_block(qi, qt):
        # qi is a CARRIED counter (not scan xs): were the block index an xs
        # array, XLA hoists the position masks out of the loop and
        # materializes all of them stacked in HBM.
        def kv_block(state, kv):
            kt, vt = kv
            m_prev, l_prev, acc, kj = state
            # bf16 operands, f32 accumulation — the MXU contract
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", qt, kt,
                preferred_element_type=jnp.float32,
            ) * scale
            q_pos = qi * block_q + jnp.arange(block_q)
            k_pos = kj * block_kv + jnp.arange(block_kv)
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window is not None:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v.dtype), vt,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc, kj + 1), None

        init = (
            jnp.full((b, hq, block_q), -1e30, jnp.float32),
            jnp.zeros((b, hq, block_q), jnp.float32),
            jnp.zeros((b, hq, block_q, d), jnp.float32),
            jnp.int32(0),
        )
        (m, lsum, acc, _), _ = jax.lax.scan(
            jax.checkpoint(kv_block), init, (kb, vb)
        )
        out = acc / jnp.maximum(lsum, 1e-30)[..., None]
        return qi + 1, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, jnp.int32(0), qb)
    # outs: (nq, b, hq, block_q, d) -> (b, hq, sq, d)
    return outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, d)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    q_offset: int | jnp.ndarray = 0,
    kv_len: jnp.ndarray | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Oracle for the flash-attention kernel.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
    ``q_offset`` is the absolute position of q[0] (decode: Sq=1, offset=t).
    ``window``: sliding-window width (gemma-style local attention).
    ``kv_len``: valid KV prefix length (decode with a preallocated cache).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    sk = k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kr) * scale
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    if kv_len is not None:
        mask = mask & (k_pos[None, :] < kv_len)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    # rows with no visible key (can happen with padded caches) -> zeros, and
    # keep the softmax NaN-free by subtracting a finite max for such rows.
    row_visible = jnp.any(mask, axis=-1)  # (sq, sk) -> (sq,)
    safe_logits = jnp.where(row_visible[None, None, :, None], logits, 0.0)
    probs = jax.nn.softmax(safe_logits, axis=-1)
    probs = jnp.where(row_visible[None, None, :, None], probs, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vr).astype(q.dtype)
