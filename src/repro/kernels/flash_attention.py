"""Pallas TPU kernel: flash attention (online softmax, GQA, causal/sliding).

The LM substrate's compute hot-spot.  Standard two-level tiling:

* grid = (batch * q_heads, Sq/block_q, Sk/block_kv), kv innermost so the
  output block (indexed by (bh, qi)) is revisited consecutively;
* online softmax state (running max ``m``, normalizer ``l``, accumulator
  ``acc``) lives in VMEM scratch, f32;
* causal pruning: kv blocks strictly after the q block are skipped via the
  grid predicate (``@pl.when``), the diagonal block gets the triangular mask;
* sliding-window (gemma-style local attention) additionally skips kv blocks
  strictly before the window and masks inside the boundary block;
* GQA: the kv head index map is ``h // (Hq // Hkv)`` — no repeat in HBM.

Block sizes default to (128, 128); head_dim is zero-padded to a multiple of
128 lanes by the wrapper in ops.py when needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(
    block_q: int,
    block_kv: int,
    scale: float,
    causal: bool,
    window: int | None,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_kv

    # block-level pruning: causal skip (kv entirely after q) and window skip
    # (kv entirely before q's window).
    run = jnp.bool_(True)
    if causal:
        run = run & (k_start <= q_start + block_q - 1)
    if window is not None:
        run = run & (k_start + block_kv - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_kv)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        lsum = l_scr[...]
        safe_l = jnp.where(lsum > 0, lsum, 1.0)
        o_ref[0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D).  Returns (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    assert sq % block_q == 0 and sk % block_kv == 0, "pad seq to block multiple"

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, sk, d)
    vr = v.reshape(b * hkv, sk, d)

    def kv_map(bh, qi, kj):
        return (bh // group, kj, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, block_q, block_kv, scale, causal, window),
        grid=(b * hq, sq // block_q, sk // block_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, d), kv_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # normalizer l
            pltpu.VMEM((block_q, d), jnp.float32),  # accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
