"""Jit'd dispatch wrappers for the Pallas kernels.

Selection policy (``force`` overrides):

* on TPU -> compiled Pallas kernels;
* elsewhere -> the pure-jnp oracles from ``ref.py`` (vectorized, fast on CPU).
  Interpret-mode Pallas execution is reserved for the kernel-correctness
  tests (``force="interpret"``) because it runs the kernel body per grid step
  in Python — correct but orders of magnitude slower than the oracle.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dc_pairs import dc_role_scan_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.semijoin import semijoin_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force: str | None) -> str:
    if force is not None:
        return force
    return "pallas" if on_tpu() else "ref"


def dc_role_scan(
    l_cols: Sequence[jnp.ndarray],
    r_cols: Sequence[jnp.ndarray],
    ops: Sequence[str],
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    reduces: Sequence[str],
    block: int = 256,
    force: str | None = None,
    row_blocks: Tuple[int, int] | None = None,
    col_blocks: Tuple[int, int] | None = None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray]]:
    """``row_blocks=(lo, hi)`` launches only that strip of row blocks — the
    partition-strip entry the work ledger schedules (DESIGN.md §11).
    ``col_blocks`` is the symmetric partner-side restriction: the
    ingest-delta entry scanning against only fresh rows (DESIGN.md §12)."""
    mode = _mode(force)
    if mode == "ref":
        return ref.dc_role_scan(
            l_cols, r_cols, ops, row_scope, col_scope, reduces, block=block,
            row_blocks=row_blocks, col_blocks=col_blocks,
        )
    return dc_role_scan_pallas(
        l_cols,
        r_cols,
        ops,
        row_scope,
        col_scope,
        reduces,
        block=block,
        interpret=(mode == "interpret"),
        row_blocks=row_blocks,
        col_blocks=col_blocks,
    )


def semijoin(
    query: jnp.ndarray,
    query_mask: jnp.ndarray,
    keys: jnp.ndarray,
    keys_mask: jnp.ndarray,
    block: int = 512,
    force: str | None = None,
) -> jnp.ndarray:
    mode = _mode(force)
    if mode == "ref":
        return ref.semijoin(query, query_mask, keys, keys_mask, block=block)
    return semijoin_pallas(
        query, query_mask, keys, keys_mask, block=block, interpret=(mode == "interpret")
    )


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    force: str | None = None,
) -> jnp.ndarray:
    mode = _mode(force)
    if mode == "ref":
        # long sequences: the blocked online-softmax path (O(s) live memory,
        # same tiling as the Pallas kernel); short ones: the exact oracle.
        sq, sk = q.shape[2], k.shape[2]
        if sq >= 1024 and sq % 512 == 0 and sk % 1024 == 0:
            return ref.attention_blocked(
                q, k, v, causal=causal, window=window, scale=scale
            )
        return ref.attention(q, k, v, causal=causal, window=window, scale=scale)
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        interpret=(mode == "interpret"),
    )
