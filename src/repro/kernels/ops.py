"""Jit'd dispatch wrappers for the Pallas kernels.

Selection policy (``force`` overrides):

* on TPU -> compiled Pallas kernels;
* elsewhere -> the pure-jnp oracles from ``ref.py`` (vectorized, fast on CPU).
  Interpret-mode Pallas execution is reserved for the kernel-correctness
  tests (``force="interpret"``) because it runs the kernel body per grid step
  in Python — correct but orders of magnitude slower than the oracle.
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.dc_pairs import (
    dc_pair_scan_pallas,
    dc_role_scan_pallas,
    distinct_columns,
    resolve_block_ids,
)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.semijoin import semijoin_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force: str | None) -> str:
    if force is not None:
        return force
    return "pallas" if on_tpu() else "ref"


def dc_role_scan(
    l_cols: Sequence[jnp.ndarray],
    r_cols: Sequence[jnp.ndarray],
    ops: Sequence[str],
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    reduces: Sequence[str],
    block: int = 256,
    force: str | None = None,
    row_blocks: Tuple[int, int] | None = None,
    col_blocks: Tuple[int, int] | None = None,
    row_block_ids=None,
    col_block_ids=None,
) -> Tuple[jnp.ndarray, List[jnp.ndarray]]:
    """``row_blocks=(lo, hi)`` launches only that strip of row blocks — the
    partition-strip entry the work ledger schedules (DESIGN.md §11).
    ``col_blocks`` is the symmetric partner-side restriction: the
    ingest-delta entry scanning against only fresh rows (DESIGN.md §12).
    ``row_block_ids`` / ``col_block_ids`` generalize both to an arbitrary
    block-id worklist (DESIGN.md §15): only the cross product of the given
    row and col blocks is launched."""
    mode = _mode(force)
    restr = dict(
        row_blocks=row_blocks, col_blocks=col_blocks,
        row_block_ids=row_block_ids, col_block_ids=col_block_ids,
    )
    if mode == "ref":
        return ref.dc_role_scan(
            l_cols, r_cols, ops, row_scope, col_scope, reduces, block=block,
            **restr,
        )
    return dc_role_scan_pallas(
        l_cols,
        r_cols,
        ops,
        row_scope,
        col_scope,
        reduces,
        block=block,
        interpret=(mode == "interpret"),
        **restr,
    )


class TileStats(NamedTuple):
    """Launch geometry + modeled HBM traffic of one DC scan (DESIGN.md §15).

    ``bytes_moved`` is computed from the launch geometry and the ACTUAL
    operand dtypes (so compressed encodings show up as fewer bytes) — a
    deterministic model of tile DMA traffic, not a hardware counter, which
    keeps the CI gates reproducible on any backend.
    """

    launched: int  # tile pairs actually launched (the worklist size)
    total: int  # tile pairs a dense scan would launch (nb x nb)
    bytes_moved: int  # modeled bytes DMA'd by the launched tiles


def _tile_bytes(
    distinct: Sequence[jnp.ndarray],
    l_cols: Sequence[jnp.ndarray],
    r_cols: Sequence[jnp.ndarray],
    block: int,
) -> int:
    """Modeled per-tile DMA traffic of the fused scan: each DISTINCT atom
    column loads one row tile + one col tile (the fusion contract — shared
    columns are not re-fetched per role), scopes load both sides, per-block
    bounds are scalars, and each tile visit writes both roles' outputs."""
    col_bytes = sum(block * c.dtype.itemsize for c in distinct)
    scope_bytes = 2 * block * 4
    bound_bytes = 4 * sum(c.dtype.itemsize for c in distinct)
    out_bytes = (
        2 * block * 4
        + sum(block * c.dtype.itemsize for c in r_cols)
        + sum(block * c.dtype.itemsize for c in l_cols)
    )
    return 2 * col_bytes + scope_bytes + bound_bytes + out_bytes


class DCPairScanResult(NamedTuple):
    t1_count: jnp.ndarray
    t1_stat: Tuple[jnp.ndarray, ...]
    t2_count: jnp.ndarray
    t2_stat: Tuple[jnp.ndarray, ...]
    tiles: TileStats


def dc_pair_scan(
    l_cols: Sequence[jnp.ndarray],
    r_cols: Sequence[jnp.ndarray],
    ops: Sequence[str],
    flipped: Sequence[str],
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    t1_reduces: Sequence[str],
    t2_reduces: Sequence[str],
    block: int = 256,
    force: str | None = None,
    row_blocks: Tuple[int, int] | None = None,
    col_blocks: Tuple[int, int] | None = None,
    row_block_ids=None,
    col_block_ids=None,
) -> DCPairScanResult:
    """Fused BOTH-role DC scan over one block worklist (DESIGN.md §15).

    One call computes role t1 (atoms as written) and role t2 (``flipped``
    atoms, column sides swapped) — on the Pallas path a single launch that
    loads each distinct atom column once per tile.  The returned
    ``TileStats`` carry the worklist geometry and modeled bytes for
    telemetry; an empty worklist returns identities with zero launches and
    no kernel call at all."""
    n = l_cols[0].shape[0]
    nb = -(-n // block)
    rid = resolve_block_ids(nb, row_blocks, row_block_ids)
    cid = resolve_block_ids(nb, col_blocks, col_block_ids)
    launched = int(rid.size) * int(cid.size)
    distinct, _, _ = distinct_columns(l_cols, r_cols)
    tiles = TileStats(
        launched=launched,
        total=nb * nb,
        bytes_moved=launched * _tile_bytes(distinct, l_cols, r_cols, block),
    )
    mode = _mode(force)
    restr = dict(
        block=block, row_block_ids=rid, col_block_ids=cid,
    )
    if mode == "ref":
        t1c, t1s, t2c, t2s = ref.dc_pair_scan(
            l_cols, r_cols, ops, flipped, row_scope, col_scope,
            t1_reduces, t2_reduces, **restr,
        )
    else:
        t1c, t1s, t2c, t2s = dc_pair_scan_pallas(
            l_cols, r_cols, ops, flipped, row_scope, col_scope,
            t1_reduces, t2_reduces, interpret=(mode == "interpret"), **restr,
        )
    return DCPairScanResult(t1c, tuple(t1s), t2c, tuple(t2s), tiles)


# ------------------------------------------------------- compressed encodings
# Exactness-proved atom compression (DESIGN.md §15): a column may be scanned
# in a narrower dtype only when the predicate outcomes are PROVABLY identical
# to the f32/int32 originals.  Three encodings, strongest first:
#
# * ``code``  — order-preserving dense ranks (exact hashing of the value set)
#               for attributes whose every touching atom is a same-attribute
#               ==/!= atom: codes are equal iff values are equal;
# * ``int8``  — identity cast for integer-valued columns within int8 range:
#               every comparison op is preserved by the identity map;
# * ``bf16``  — for float columns that round-trip f32 -> bf16 -> f32 exactly
#               (NaN never round-trips, so NaN columns fall out naturally);
# * ``orig``  — the always-sound fallback.
#
# Both sides of every atom must land on the SAME encoding kind (comparing an
# int8 tile against an f32 tile proves nothing), so the planner runs a
# fixpoint demotion until every atom is consistent.


class ColumnEncoding(NamedTuple):
    kind: str  # "orig" | "int8" | "bf16" | "code"
    table: Optional[np.ndarray]  # code: sorted distinct values (decode table)
    code_dtype: object = None  # code: np.int8/np.int16/np.int32


_ENC_RANK = {"orig": 0, "bf16": 1, "int8": 2, "code": 3}


def _eligible_kinds(arr: np.ndarray) -> set:
    """Encoding kinds this column alone can prove exact (code eligibility is
    atom-context dependent and handled by the planner)."""
    kinds = {"orig"}
    if arr.size == 0:
        return kinds
    if np.issubdtype(arr.dtype, np.integer):
        if arr.min() >= -128 and arr.max() <= 127:
            kinds.add("int8")
        return kinds
    if np.isnan(arr).any():
        return kinds
    if np.all(arr == np.floor(arr)) and arr.min() >= -128 and arr.max() <= 127:
        kinds.add("int8")
    rt = np.asarray(jnp.asarray(arr).astype(jnp.bfloat16).astype(arr.dtype))
    if np.array_equal(rt, arr):
        kinds.add("bf16")
    return kinds


def plan_dc_encodings(
    cols: Dict[str, jnp.ndarray],
    atoms: Sequence[Tuple[str, str, str]],
) -> Optional[Dict[str, ColumnEncoding]]:
    """Choose one exact encoding per attribute for a DC's atom columns.

    ``atoms`` is ``[(left_attr, right_attr, op), ...]``.  Returns ``None``
    when nothing compresses (all ``orig``) so callers can skip the encode
    pass entirely.  Planning is host-side numpy over the base columns —
    O(n) per attribute, noise next to the O(n^2/block) scan it feeds."""
    host = {a: np.asarray(c) for a, c in cols.items()}
    eligible = {a: _eligible_kinds(arr) for a, arr in host.items()}
    # code: every atom touching the attr is a same-attribute equality atom
    # (and the column is NaN-free — code(NaN) == code(NaN) would flip !=)
    touching: Dict[str, List[Tuple[str, str, str]]] = {a: [] for a in host}
    for lname, rname, op in atoms:
        touching[lname].append((lname, rname, op))
        if rname != lname:
            touching[rname].append((lname, rname, op))
    for a, arr in host.items():
        if not touching[a]:
            continue
        same_eq = all(
            ln == rn == a and op in ("==", "!=") for ln, rn, op in touching[a]
        )
        no_nan = not (
            np.issubdtype(arr.dtype, np.floating) and np.isnan(arr).any()
        )
        if same_eq and no_nan and arr.size:
            eligible[a].add("code")
    enc = {
        a: max(kinds, key=_ENC_RANK.__getitem__) for a, kinds in eligible.items()
    }
    # fixpoint: both sides of every atom must share a kind both can prove
    changed = True
    while changed:
        changed = False
        for lname, rname, _ in atoms:
            if enc[lname] == enc[rname]:
                continue
            common = eligible[lname] & eligible[rname]
            cap = min(_ENC_RANK[enc[lname]], _ENC_RANK[enc[rname]])
            k = max(
                (c for c in common if _ENC_RANK[c] <= cap),
                key=_ENC_RANK.__getitem__,
            )
            enc[lname] = enc[rname] = k
            changed = True
    if all(k == "orig" for k in enc.values()):
        return None
    out = {}
    for a, kind in enc.items():
        if kind == "code":
            table = np.unique(host[a])
            cdt = (
                np.int8 if table.size <= 127
                else np.int16 if table.size <= 32767
                else np.int32
            )
            out[a] = ColumnEncoding("code", table, cdt)
        else:
            out[a] = ColumnEncoding(kind, None)
    return out


def encode_column(col: jnp.ndarray, enc: ColumnEncoding) -> jnp.ndarray:
    if enc.kind == "orig":
        return col
    if enc.kind == "int8":
        return col.astype(jnp.int8)
    if enc.kind == "bf16":
        return col.astype(jnp.bfloat16)
    if enc.kind == "code":
        codes = np.searchsorted(enc.table, np.asarray(col))
        return jnp.asarray(codes.astype(enc.code_dtype))
    raise ValueError(enc.kind)


def decode_stat(
    stat: jnp.ndarray,
    count: jnp.ndarray,
    enc: ColumnEncoding,
    orig_dtype,
    reduce: str,
) -> jnp.ndarray:
    """Map an encoded extremal-partner stat back to the original value
    space.  Rows with ``count == 0`` hold the ENCODED identity sentinel
    (e.g. int8 127), which has no preimage — they are rewritten to the
    original dtype's identity, exactly what an unencoded scan yields."""
    ident = ref._identity(orig_dtype, reduce)
    if enc.kind == "orig":
        return stat
    if enc.kind == "code":
        idx = jnp.clip(stat.astype(jnp.int32), 0, len(enc.table) - 1)
        dec = jnp.asarray(enc.table)[idx]
    else:
        dec = stat.astype(orig_dtype)
    return jnp.where(count > 0, dec, ident)


def semijoin(
    query: jnp.ndarray,
    query_mask: jnp.ndarray,
    keys: jnp.ndarray,
    keys_mask: jnp.ndarray,
    block: int = 512,
    force: str | None = None,
) -> jnp.ndarray:
    mode = _mode(force)
    if mode == "ref":
        return ref.semijoin(query, query_mask, keys, keys_mask, block=block)
    return semijoin_pallas(
        query, query_mask, keys, keys_mask, block=block, interpret=(mode == "interpret")
    )


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    force: str | None = None,
) -> jnp.ndarray:
    mode = _mode(force)
    if mode == "ref":
        # long sequences: the blocked online-softmax path (O(s) live memory,
        # same tiling as the Pallas kernel); short ones: the exact oracle.
        sq, sk = q.shape[2], k.shape[2]
        if sq >= 1024 and sq % 512 == 0 and sk % 1024 == 0:
            return ref.attention_blocked(
                q, k, v, causal=causal, window=window, scale=scale
            )
        return ref.attention(q, k, v, causal=causal, window=window, scale=scale)
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        interpret=(mode == "interpret"),
    )
