"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536  [arXiv:2403.19887; hf]

Pattern unit (8 blocks = 1 attention + 7 mamba, Jamba's 1:7 ratio); MoE
replaces the MLP every other block (Jamba: e=2).  Optimizer state runs in
bf16 (DESIGN.md §5: fp32 AdamW for 398B does not fit a single 256-chip pod).
"""

from repro.models.config import BlockSpec, ModelConfig, MoEConfig, SSMConfig

_PATTERN = tuple(
    BlockSpec(mixer=("attn" if i == 4 else "mamba"), moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    mlp="swiglu",
    rope="nope",  # Jamba uses no positional encoding (Mamba carries order)
    pattern=_PATTERN,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=True,
    # 398B on one 256-chip pod: bf16 master + Adafactor (DESIGN.md §5)
    param_dtype="bfloat16",
    optimizer="adafactor",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-reduced",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        mlp="swiglu",
        rope="nope",
        pattern=_PATTERN,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, every=2),
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
        remat=False,
    )
