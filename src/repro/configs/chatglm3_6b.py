"""chatglm3-6b [dense] — 2d (partial) RoPE, GQA kv=2.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024  [arXiv:2406.12793]

ChatGLM rotates half of each head (2d RoPE) — rope='partial', ratio 0.5.
kv=2 pads to the TP degree (16) for weight sharding; the replication is
recorded against useful FLOPs.
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    mlp="swiglu",
    rope="partial",
    partial_rotary=0.5,
    pattern=(BlockSpec(),),
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-reduced",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=224,
        vocab_size=512,
        mlp="swiglu",
        rope="partial",
        partial_rotary=0.5,
        pattern=(BlockSpec(),),
        tie_embeddings=False,
        remat=False,
    )
