"""whisper-large-v3 [audio] — encoder-decoder; conv frontend is a STUB.

32L (decoder) d_model=1280 20H (kv=20) d_ff=5120 vocab=51866, encoder 32L
over 1500 frames.  [arXiv:2212.04356]

The audio frontend (mel + conv) is stubbed: ``input_specs`` provides
precomputed (b, 1500, d) frame embeddings.  Learned absolute positions
(rope='none'); 20 heads pad to 32 for the 16-way TP mesh (zero-row wo).
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp="gelu",
    norm="layernorm",
    rope="none",
    max_seq=32_768,  # assignment shapes exercise the backbone at 32k
    pattern=(BlockSpec(),),
    enc_dec=True,
    enc_layers=32,
    enc_seq=1500,
    frontend="audio",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        mlp="gelu",
        norm="layernorm",
        rope="none",
        max_seq=128,
        pattern=(BlockSpec(),),
        enc_dec=True,
        enc_layers=2,
        enc_seq=32,
        frontend="audio",
        remat=False,
    )
