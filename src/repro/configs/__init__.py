"""Architecture registry: ``get_config(arch_id, reduced=False)``.

One module per assigned architecture; each exports ``CONFIG`` (the exact
published configuration) and ``reduced()`` (a same-family small variant for
CPU smoke tests).
"""

from __future__ import annotations

import importlib
from typing import List

ARCH_IDS: List[str] = [
    "jamba_1_5_large_398b",
    "falcon_mamba_7b",
    "nemotron_4_340b",
    "gemma3_12b",
    "chatglm3_6b",
    "qwen3_4b",
    "whisper_large_v3",
    "internvl2_26b",
    "olmoe_1b_7b",
    "qwen2_moe_a2_7b",
]

# assignment-sheet ids -> module names
ALIASES = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "nemotron-4-340b": "nemotron_4_340b",
    "gemma3-12b": "gemma3_12b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen3-4b": "qwen3_4b",
    "whisper-large-v3": "whisper_large_v3",
    "internvl2-26b": "internvl2_26b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
}


def get_config(arch: str, reduced: bool = False):
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced() if reduced else mod.CONFIG
