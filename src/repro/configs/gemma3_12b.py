"""gemma3-12b [dense] — 5:1 local:global attention interleave, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144, head_dim=256,
sliding window 1024 on local layers.  [hf:google/gemma-3-12b-pt]

long_500k eligible: 40/48 layers are sliding-window (O(s*w)); the 8 global
layers are KV-linear at decode (one token against the cache).
"""

from repro.models.config import BlockSpec, ModelConfig

_PATTERN = tuple(
    BlockSpec(attn_type=("global" if i == 5 else "local")) for i in range(6)
)

CONFIG = ModelConfig(
    name="gemma3-12b",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    mlp="swiglu",
    rope="standard",
    rope_theta=1_000_000.0,
    window=1024,
    pattern=_PATTERN,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-reduced",
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=16,
        mlp="swiglu",
        rope="standard",
        window=32,
        pattern=_PATTERN,
        remat=False,
    )
