"""olmoe-1b-7b [moe] — 64 experts, top-8, qk-norm.

16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304, MoE 64e top-8
[arXiv:2409.02060]
"""

from repro.models.config import BlockSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    mlp="swiglu",
    rope="standard",
    qk_norm=True,
    pattern=(BlockSpec(moe=True),),
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe-reduced",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab_size=512,
        mlp="swiglu",
        rope="standard",
        qk_norm=True,
        pattern=(BlockSpec(moe=True),),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
        tie_embeddings=False,
        remat=False,
    )
