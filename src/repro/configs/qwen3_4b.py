"""qwen3-4b [dense] — qk-norm, GQA.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936, head_dim=128
[hf:Qwen/Qwen3-4B]
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    mlp="swiglu",
    rope="standard",
    rope_theta=1_000_000.0,
    qk_norm=True,
    pattern=(BlockSpec(),),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-reduced",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        head_dim=16,
        mlp="swiglu",
        rope="standard",
        qk_norm=True,
        pattern=(BlockSpec(),),
        remat=False,
    )
