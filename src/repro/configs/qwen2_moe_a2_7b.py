"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts.

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]

60 experts pad to 64 for the 16-way EP mesh (router masks the padding).
"""

from repro.models.config import BlockSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    mlp="swiglu",
    rope="standard",
    pattern=(BlockSpec(moe=True),),
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408, n_shared=4),
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-reduced",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab_size=512,
        mlp="swiglu",
        rope="standard",
        pattern=(BlockSpec(moe=True),),
        moe=MoEConfig(n_experts=6, top_k=2, d_ff_expert=64, n_shared=2),
        tie_embeddings=False,
        remat=False,
    )
