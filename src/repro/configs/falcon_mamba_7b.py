"""falcon-mamba-7b [ssm] — attention-free Mamba-1 stack.

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16
[arXiv:2410.05355]

Pure SSM: every block is a Mamba mixer; d_ff=0 means no separate MLP —
the Mamba block (expand=2 in/out projections + gating) is the whole layer.
We model that by pattern=[mamba] with a pass-through MLP of width 0 being
invalid, so the block omits the MLP entirely (mlp='none').
"""

from repro.models.config import BlockSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    mlp="none",
    rope="nope",
    pattern=(BlockSpec(mixer="mamba"),),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-reduced",
        n_layers=4,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=256,
        mlp="none",
        rope="nope",
        pattern=(BlockSpec(mixer="mamba"),),
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
        remat=False,
    )
