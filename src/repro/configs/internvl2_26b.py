"""internvl2-26b [vlm] — InternViT frontend (STUB) + InternLM2-20B backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553  [arXiv:2404.16821]

The vision tower is stubbed: ``input_specs`` provides precomputed
(b, vis_tokens, d) patch embeddings, prepended to the text embeddings.
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    mlp="swiglu",
    rope="standard",
    pattern=(BlockSpec(),),
    frontend="vision",
    vis_tokens=256,  # one 448x448 tile -> 256 visual tokens (InternVL2)
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-reduced",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        mlp="swiglu",
        rope="standard",
        pattern=(BlockSpec(),),
        frontend="vision",
        vis_tokens=8,
        tie_embeddings=False,
        remat=False,
    )
