"""Assigned input shapes and per-(arch x shape) cell definitions.

Four shapes per LM architecture (assignment sheet):

    train_4k     seq=4,096   global_batch=256   lowers train_step
    prefill_32k  seq=32,768  global_batch=32    lowers prefill
    decode_32k   seq=32,768  global_batch=128   lowers serve_step (1 token,
                                                 KV cache of seq_len)
    long_500k    seq=524,288 global_batch=1     serve_step; SUB-QUADRATIC
                                                 archs only (ssm / hybrid /
                                                 mostly-local) — skips are
                                                 recorded in DESIGN.md §5

``input_specs`` returns ShapeDtypeStructs only — nothing is allocated; the
dry-run lowers against them (the shannon/kernels pattern).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.config import ModelConfig
from repro.models.transformer import init_cache


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# per-arch training memory knobs: microbatches for train_4k (grad-accum
# steps inside the train step) and the mamba scan chunk length.
TRAIN_MICROBATCHES: Dict[str, int] = {
    "jamba-1.5-large-398b": 16,
    "falcon-mamba-7b": 16,
    "nemotron-4-340b": 16,
    "gemma3-12b": 8,
    "chatglm3-6b": 8,
    "qwen3-4b": 8,
    "whisper-large-v3": 4,
    "internvl2-26b": 16,
    "olmoe-1b-7b": 8,
    "qwen2-moe-a2.7b": 8,
}

MAMBA_CHUNK = 256


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether (arch, shape) is a valid assignment cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (O(s^2))"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = cell_applicable(cfg, shape)
            if ok:
                cells.append((cfg.name, shape.name))
    return cells


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind in ("train", "prefill"):
        s_text = s - cfg.vis_tokens if cfg.frontend == "vision" else s
        batch = {"tokens": _sds((b, s_text), jnp.int32)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = _sds((b, cfg.vis_tokens, d), jnp.bfloat16)
        if cfg.frontend == "audio":
            batch["enc_frames"] = _sds((b, cfg.enc_seq, d), jnp.bfloat16)
        if shape.kind == "train":
            batch["labels"] = _sds((b, s_text), jnp.int32)
        return batch
    # decode: one new token + cache of seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s, jnp.bfloat16))
    return {"token": _sds((b, 1), jnp.int32), "cache": cache}
