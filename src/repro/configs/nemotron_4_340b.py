"""nemotron-4-340b [dense] — GQA + squared-ReLU MLP.

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000  [arXiv:2402.16819]

Optimizer state in bf16: fp32 AdamW for 340B params cannot fit a single
256-chip v5e pod (340e9 x 12 B / 256 = 16 GB/chip before activations);
bf16 m/v + fp32 master = 10.6 GB/chip (see DESIGN.md §5 hardware notes).
"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp="sq_relu",
    norm="layernorm",
    rope="standard",
    pattern=(BlockSpec(),),
    tie_embeddings=False,
    # 340B on one 256-chip pod: bf16 master + Adafactor (DESIGN.md §5)
    param_dtype="bfloat16",
    optimizer="adafactor",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-reduced",
        n_layers=4,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=384,
        vocab_size=512,
        mlp="sq_relu",
        norm="layernorm",
        rope="standard",
        pattern=(BlockSpec(),),
        tie_embeddings=False,
        remat=False,
    )
