"""Serving metrics (DESIGN.md §9/§10): throughput, cache effectiveness, and
the cleaning work one shared probabilistic instance amortizes across
sessions — now attributed between the foreground serving path and the
background cleaner.

Thread-safety contract: the foreground observers (``observe_hit``,
``observe_execution``, ``observe_work``) and the step/idle counters are
mutated by the single serving thread only; the background observers
(``observe_background``, ``observe_bg_yield``, ``observe_ledger``) are
mutated by the cleaner thread under ``_bg_lock``, and ``snapshot()``
acquires that same lock to read the ``bg_*`` group and the ledger
progress — the background section of a snapshot is therefore an exact
point-in-time read, never a torn one (an increment's detect/repair/busy
deltas land atomically).  The traffic-shaping observers
(``observe_admitted``, ``observe_shed``, ``observe_cancelled``,
``observe_deadline_miss``, DESIGN.md §14) may be called from MANY client
threads — shed and cancel decisions happen on the submitting side — so
the whole ``qos`` group shares ``_bg_lock`` too: it is the metrics
object's multi-writer lock, not a cleaner-only one.  Foreground counters
are single-writer monotone host ints/floats read without a lock, so
across the groups a snapshot is a consistent approximation under
concurrency and exact once all threads quiesce.  (``queries`` counts
tickets the SERVING thread answered; shed tickets are answered at submit
and counted in ``qos.shed`` — ``snapshot()["answered"]`` is the sum.)  It returns only JSON-serializable scalars plus the last
few serialized ``StepReport`` dicts (``StepReport.asdict``) for
drill-down, and — when latencies were observed — per-ticket-class
p50/p95/p99 under ``"latency"`` (DESIGN.md §13).

The two derived numbers the layer exists for:

* ``detect_repair_per_query`` — *foreground* detect/repair invocations per
  answered query, the paper's incremental-cleaning cost amortized by the
  clean-state-aware cache AND by background warmup
  (benchmarks/serve_bg_warmup.py gates that background cleaning strictly
  lowers it against the same workload without it);
* ``idle_fraction`` — share of serving wall-clock the step loop spent
  waiting for work: the budget the background cleaner runs in.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List

from repro.obs.hist import LatencyHistogram


@dataclasses.dataclass
class ServiceMetrics:
    """Counters for one server (+ optional background cleaner) lifetime.

    Foreground fields are serving-thread-only; fields prefixed ``bg_`` are
    cleaner-thread-only (guarded by ``_bg_lock``); see the module
    docstring for the full contract.  ``detect_calls``/``repair_calls``
    count FOREGROUND work — the executor's own counters hold the total,
    so background work is the difference and is tracked explicitly in the
    ``bg_*`` fields.
    """

    queries: int = 0  # tickets answered (hit or executed)
    steps: int = 0  # step-loop iterations that served >= 1 ticket
    executions: int = 0  # Daisy.execute calls (cache misses)
    cache_hits: int = 0
    batched: int = 0  # hits on a fingerprint executed earlier in the same step
    detect_calls: int = 0  # executor detect invocations while serving (fg)
    repair_calls: int = 0
    # block-sparse launch geometry (DESIGN.md §15): tile pairs the fg DC
    # scans launched vs the checked×checked pairs the ledger worklist let
    # them skip — the kernel-level counterpart of detect_calls
    tiles_launched: int = 0
    tiles_skipped: int = 0
    clean_steps: int = 0  # non-skipped cleaning steps across executions
    skipped_steps: int = 0
    rejected: int = 0  # session-limit denials
    errors: int = 0
    # streaming ingest (DESIGN.md §12): appends served through the ticket
    # queue and the rows they added (serving thread only)
    ingests: int = 0
    ingested_rows: int = 0
    ingest_pending_deltas: int = 0  # rule scopes that queued an ingest-delta
    serving_idle_s: float = 0.0  # step-loop time spent waiting for work
    # traffic shaping (DESIGN.md §14) — multi-writer, guarded by _bg_lock:
    # admission/shed/cancel happen on client threads, deadline accounting
    # on the serving thread
    shed: int = 0  # tickets answered stale-from-cache at submit
    shed_stale: int = 0  # shed answers whose staleness tag was > 0
    shed_staleness_total: int = 0  # sum of staleness tags (avg = /shed)
    cancelled: int = 0  # tickets abandoned before serving started
    deadline_misses: int = 0  # served tickets that blew their deadline
    # per-SLO-class counters: {class: {"admitted"/"shed"/"cancelled"/
    # "deadline_misses": n}}
    by_class: Dict[str, Dict[str, int]] = dataclasses.field(default_factory=dict)
    # background cleaner attribution (DESIGN.md §10)
    bg_increments: int = 0  # clean_scope_increment calls that did work
    bg_detect_calls: int = 0
    bg_repair_calls: int = 0
    bg_scopes_completed: int = 0  # increments that left their scope warm
    bg_yields: int = 0  # times the cleaner deferred to pending tickets
    bg_busy_s: float = 0.0  # wall-clock spent inside increments
    # latest work-ledger progress snapshot (DESIGN.md §11): per-scope
    # strips done / total + cold rows, updated by whichever side observed
    # it last (cleaner after each increment, server on snapshot)
    ledger_progress: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict
    )
    max_reports: int = 32
    recent_reports: List[Dict[str, object]] = dataclasses.field(default_factory=list)
    started: float = dataclasses.field(default_factory=time.perf_counter)
    # end-to-end latency histograms per ticket class ("query" / "ingest" /
    # "bg-increment"), DESIGN.md §13: log-scale buckets, so percentiles
    # come without retained samples.  Each histogram locks internally;
    # the dict itself is only grown under ``_bg_lock``.
    latency: Dict[str, LatencyHistogram] = dataclasses.field(default_factory=dict)
    _bg_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    # ------------------------------------------------------------ observers
    def observe_hit(self, same_step: bool) -> None:
        """Record one cache hit (serving thread)."""
        self.queries += 1
        self.cache_hits += 1
        if same_step:
            self.batched += 1

    def observe_execution(self, report) -> None:
        """Record one cache-miss execution from its ``ExecReport``
        (serving thread)."""
        self.queries += 1
        self.executions += 1
        for step in report.steps:
            if step.mode == "skipped":
                self.skipped_steps += 1
            else:
                self.clean_steps += 1
        self.recent_reports.append(report.asdict())
        del self.recent_reports[: -self.max_reports]

    def observe_work(
        self, detect_delta: int, repair_delta: int,
        tiles_launched_delta: int = 0, tiles_skipped_delta: int = 0,
    ) -> None:
        """Attribute executor detect/repair deltas (and the DC scans' tile
        launch/skip deltas, DESIGN.md §15) to the foreground serving path
        (serving thread)."""
        self.detect_calls += detect_delta
        self.repair_calls += repair_delta
        self.tiles_launched += tiles_launched_delta
        self.tiles_skipped += tiles_skipped_delta

    def observe_idle(self, seconds: float) -> None:
        """Accumulate step-loop wait time (serving thread)."""
        self.serving_idle_s += seconds

    def observe_ingest(self, report) -> None:
        """Record one served append from its ``IngestReport``
        (serving thread)."""
        self.ingests += 1
        self.ingested_rows += report.rows
        self.ingest_pending_deltas += len(report.pending_rules)

    def observe_background(
        self, detect_delta: int, repair_delta: int, busy_s: float,
        scope_completed: bool,
    ) -> None:
        """Attribute one background increment's work (cleaner thread)."""
        with self._bg_lock:
            self.bg_increments += 1
            self.bg_detect_calls += detect_delta
            self.bg_repair_calls += repair_delta
            self.bg_busy_s += busy_s
            if scope_completed:
                self.bg_scopes_completed += 1

    def _class_counter(self, slo: str, key: str, delta: int = 1) -> None:
        """Bump one per-class counter (callers hold ``_bg_lock``)."""
        cls = self.by_class.setdefault(slo, {})
        cls[key] = cls.get(key, 0) + delta

    def observe_admitted(self, slo: str) -> None:
        """Record one ticket entering the queue for an SLO class (client
        threads; thread-safe)."""
        with self._bg_lock:
            self._class_counter(slo, "admitted")

    def observe_shed(self, slo: str, staleness: int) -> None:
        """Record one overload shed: the ticket was answered at submit
        from the version-vector cache with this explicit staleness tag
        (client threads; thread-safe)."""
        with self._bg_lock:
            self.shed += 1
            self.shed_staleness_total += staleness
            if staleness > 0:
                self.shed_stale += 1
            self._class_counter(slo, "shed")

    def observe_cancelled(self, slo: str) -> None:
        """Record one abandoned ticket discarded before any cleaning work
        (serving thread at pick/serve time; thread-safe anyway)."""
        with self._bg_lock:
            self.cancelled += 1
            self._class_counter(slo, "cancelled")

    def observe_deadline_miss(self, slo: str) -> None:
        """Record one served ticket that finished past its deadline
        (serving thread; thread-safe)."""
        with self._bg_lock:
            self.deadline_misses += 1
            self._class_counter(slo, "deadline_misses")

    def observe_bg_yield(self) -> None:
        """Record the cleaner deferring to foreground work (cleaner thread)."""
        with self._bg_lock:
            self.bg_yields += 1

    def observe_latency(self, kind: str, seconds: float) -> None:
        """Record one end-to-end latency sample for a ticket class
        (``"query"`` / ``"ingest"`` from the serving thread,
        ``"bg-increment"`` from the cleaner thread).  Thread-safe."""
        hist = self.latency.get(kind)
        if hist is None:
            with self._bg_lock:
                hist = self.latency.setdefault(kind, LatencyHistogram())
        hist.observe(seconds)

    def observe_ledger(self, progress: Dict[str, Dict[str, int]]) -> None:
        """Store the latest per-scope ledger progress (strips done / total,
        cold rows — ``WorkLedger.progress()``, DESIGN.md §11).  Called by
        the cleaner after each increment and by the server at snapshot
        time; last writer wins, which is fine for a monotone gauge."""
        with self._bg_lock:
            self.ledger_progress = dict(progress)

    # -------------------------------------------------------------- derived
    @property
    def elapsed(self) -> float:
        """Wall-clock seconds since construction (monotone clock)."""
        return max(time.perf_counter() - self.started, 1e-9)

    @property
    def queries_per_sec(self) -> float:
        """Answered tickets per wall-clock second."""
        return self.queries / self.elapsed

    @property
    def hit_rate(self) -> float:
        """Fraction of answered tickets served from the cache."""
        return self.cache_hits / max(self.queries, 1)

    @property
    def detect_repair_per_query(self) -> float:
        """Foreground cleaning work amortized per answered query."""
        return (self.detect_calls + self.repair_calls) / max(self.queries, 1)

    @property
    def idle_fraction(self) -> float:
        """Share of elapsed wall-clock the step loop spent idle — the
        background cleaner's available budget."""
        return min(self.serving_idle_s / self.elapsed, 1.0)

    def snapshot(self) -> Dict[str, object]:
        """JSON-serializable counter snapshot with foreground/background
        attribution nested under ``foreground``/``background`` and
        per-ticket-class latency percentiles under ``latency``.

        The background section (``bg_*`` counters, ledger progress) is
        read under ``_bg_lock`` — the same lock every cleaner-thread
        observer writes under — so it is an exact point-in-time view, not
        a torn read racing a concurrent increment."""
        with self._bg_lock:
            background = {
                "increments": self.bg_increments,
                "detect_calls": self.bg_detect_calls,
                "repair_calls": self.bg_repair_calls,
                "scopes_completed": self.bg_scopes_completed,
                "yields": self.bg_yields,
                "busy_s": round(self.bg_busy_s, 6),
            }
            qos = {
                "shed": self.shed,
                "shed_stale": self.shed_stale,
                "shed_staleness_total": self.shed_staleness_total,
                "cancelled": self.cancelled,
                "deadline_misses": self.deadline_misses,
                "by_class": {k: dict(v) for k, v in self.by_class.items()},
            }
            shed = self.shed
            ledger = {k: dict(v) for k, v in self.ledger_progress.items()}
            latency = dict(self.latency)
        return {
            "queries": self.queries,
            # every admitted-or-shed ticket that got an answer: the serving
            # thread's count plus the submit-time sheds (DESIGN.md §14)
            "answered": self.queries + shed,
            # traffic shaping: sheds, cancels, deadline misses, per-class
            # counts (DESIGN.md §14)
            "qos": qos,
            "steps": self.steps,
            "executions": self.executions,
            "cache_hits": self.cache_hits,
            "batched": self.batched,
            "detect_calls": self.detect_calls,
            "repair_calls": self.repair_calls,
            "tiles_launched": self.tiles_launched,
            "tiles_skipped": self.tiles_skipped,
            "clean_steps": self.clean_steps,
            "skipped_steps": self.skipped_steps,
            "rejected": self.rejected,
            "errors": self.errors,
            "ingests": self.ingests,
            "ingested_rows": self.ingested_rows,
            "ingest_pending_deltas": self.ingest_pending_deltas,
            "elapsed_s": round(self.elapsed, 6),
            "queries_per_sec": round(self.queries_per_sec, 3),
            "hit_rate": round(self.hit_rate, 4),
            "detect_repair_per_query": round(self.detect_repair_per_query, 4),
            "idle_fraction": round(self.idle_fraction, 4),
            "foreground": {
                "detect_calls": self.detect_calls,
                "repair_calls": self.repair_calls,
            },
            "background": background,
            # per-scope warmup progress (strips done / total), so operators
            # and benchmarks report HOW warm each rule is, not only detect
            # counts (DESIGN.md §11)
            "ledger": ledger,
            # p50/p95/p99 per ticket class (query / ingest / bg-increment),
            # DESIGN.md §13
            "latency": {k: h.snapshot() for k, h in latency.items()},
            "recent_reports": list(self.recent_reports),
        }
