"""Serving metrics (DESIGN.md §9): throughput, cache effectiveness, and the
cleaning work one shared probabilistic instance amortizes across sessions.

All counters are plain host ints mutated by the single serving thread (the
step loop), so ``snapshot()`` is always self-consistent; it returns only
JSON-serializable scalars plus the last few serialized ``StepReport`` dicts
(``StepReport.asdict``) for drill-down.  The interesting derived number is
``detect_repair_per_query``: detect/repair invocations divided by queries
answered — the paper's incremental-cleaning cost, amortized further by the
clean-state-aware cache (benchmarks/serve_throughput.py plots it against
the cacheless and offline baselines).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List


@dataclasses.dataclass
class ServiceMetrics:
    queries: int = 0  # tickets answered (hit or executed)
    steps: int = 0  # step-loop iterations that served >= 1 ticket
    executions: int = 0  # Daisy.execute calls (cache misses)
    cache_hits: int = 0
    batched: int = 0  # hits on a fingerprint executed earlier in the same step
    detect_calls: int = 0  # executor detect invocations while serving
    repair_calls: int = 0
    clean_steps: int = 0  # non-skipped cleaning steps across executions
    skipped_steps: int = 0
    rejected: int = 0  # session-limit denials
    errors: int = 0
    max_reports: int = 32
    recent_reports: List[Dict[str, object]] = dataclasses.field(default_factory=list)
    started: float = dataclasses.field(default_factory=time.perf_counter)

    # ------------------------------------------------------------ observers
    def observe_hit(self, same_step: bool) -> None:
        self.queries += 1
        self.cache_hits += 1
        if same_step:
            self.batched += 1

    def observe_execution(self, report) -> None:
        """Record one cache-miss execution from its ``ExecReport``."""
        self.queries += 1
        self.executions += 1
        for step in report.steps:
            if step.mode == "skipped":
                self.skipped_steps += 1
            else:
                self.clean_steps += 1
        self.recent_reports.append(report.asdict())
        del self.recent_reports[: -self.max_reports]

    def observe_work(self, detect_delta: int, repair_delta: int) -> None:
        self.detect_calls += detect_delta
        self.repair_calls += repair_delta

    # -------------------------------------------------------------- derived
    @property
    def elapsed(self) -> float:
        return max(time.perf_counter() - self.started, 1e-9)

    @property
    def queries_per_sec(self) -> float:
        return self.queries / self.elapsed

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / max(self.queries, 1)

    @property
    def detect_repair_per_query(self) -> float:
        """Cleaning work amortized per answered query."""
        return (self.detect_calls + self.repair_calls) / max(self.queries, 1)

    def snapshot(self) -> Dict[str, object]:
        return {
            "queries": self.queries,
            "steps": self.steps,
            "executions": self.executions,
            "cache_hits": self.cache_hits,
            "batched": self.batched,
            "detect_calls": self.detect_calls,
            "repair_calls": self.repair_calls,
            "clean_steps": self.clean_steps,
            "skipped_steps": self.skipped_steps,
            "rejected": self.rejected,
            "errors": self.errors,
            "elapsed_s": round(self.elapsed, 6),
            "queries_per_sec": round(self.queries_per_sec, 3),
            "hit_rate": round(self.hit_rate, 4),
            "detect_repair_per_query": round(self.detect_repair_per_query, 4),
            "recent_reports": list(self.recent_reports),
        }
