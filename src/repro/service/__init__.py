"""repro.service — concurrent query serving over the gradually-cleaned
probabilistic instance (DESIGN.md §9), with cost-model-driven background
cleaning behind the serving loop (§10).

The paper's engine cleans *on demand*, driven by the queries users perform;
this package is the layer that takes a stream of analytical queries from
many sessions and shares one Daisy instance — and the cleaning work —
between them:

* ``server``     continuous-batching step loop (after serve/engine.py)
                 over a thread-safe submission queue;
* ``scheduler``  tickets + rule/cluster batching so one clean_sigma pass
                 pays for a whole batch of overlapping-σ queries, and the
                 ``rule_deps`` dependency sets the cache versions against;
* ``cache``      clean-state-aware result cache keyed on (query
                 fingerprint, per-scope version vector);
* ``session``    per-user identity, lineage, and admission limits;
* ``metrics``    queries/sec, cache effectiveness, detect/repair work
                 amortized per query, foreground/background attribution;
* ``background`` the ``BackgroundCleaner``: full-cleans cold rule scopes
                 between serving steps so interactive queries stop paying
                 even the first-touch detect;
* ``qos``        traffic shaping (DESIGN.md §14): SLO classes, the
                 weighted-fair submit queue with its starvation bound,
                 and the overload policy that sheds to tagged-stale
                 cached answers instead of queueing.

Sharing is sound because candidate-overlay merges are commutative and
associative (Lemma 4, core/update.py) and the executor's checked-bit
bookkeeping makes re-cleaning a no-op — concurrent sessions converge on
one clean state, and equal version vectors over a query's dependency
scopes guarantee bit-identical answers.  A concurrent background cleaner
only accelerates that convergence (DESIGN.md §10).
"""

from repro.service.background import BackgroundCleaner, IncrementReport
from repro.service.cache import ResultCache
from repro.service.metrics import ServiceMetrics
from repro.service.qos import (
    DEFAULT_SLO_CLASSES,
    FairQueue,
    QoSPolicy,
    SLOClass,
    vector_staleness,
)
from repro.service.scheduler import Ticket, batch_tickets, cluster_key, rule_deps
from repro.service.server import QueryServer
from repro.service.session import LineageEntry, Session, SessionLimitError

__all__ = [
    "BackgroundCleaner",
    "DEFAULT_SLO_CLASSES",
    "FairQueue",
    "IncrementReport",
    "LineageEntry",
    "QoSPolicy",
    "QueryServer",
    "ResultCache",
    "SLOClass",
    "ServiceMetrics",
    "Session",
    "SessionLimitError",
    "Ticket",
    "batch_tickets",
    "cluster_key",
    "rule_deps",
    "vector_staleness",
]
