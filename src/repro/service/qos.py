"""Traffic shaping for the query service (DESIGN.md §14): weighted fair
queueing, SLO classes, and stale-serve load shedding.

The paper's core claim is that cleaning adapts to the workload, not the
other way round — which only holds up if the *service* keeps its latency
promises while cleaning competes with queries.  The PR 3–7 scheduler was
FIFO-by-cluster with no admission control: one heavy session or an
overload burst starves everyone else, and the background cleaner has no
notion of how urgent the queued traffic is.  This module adds the three
shaping mechanisms, composed with (not replacing) the existing cluster
batching:

* **Weighted fair queueing** (``FairQueue``).  Start-time fair queueing
  (the SFQ variant of WFQ): each ticket gets a virtual *start tag*
  ``S = max(V, F_last(session))`` and *finish tag* ``F = S + 1/w`` at
  submit, where ``V`` is the queue's virtual time (advanced to the start
  tag of every ticket picked) and ``w`` the ticket's effective weight
  (session weight x SLO-class weight).  The server admits each step's
  batch in ascending ``(start tag, seq)`` order; ``batch_tickets`` then
  regroups the admitted batch by cluster, so same-cluster amortization
  survives the reordering but can no longer starve an orphan cluster —
  a singleton-cluster ticket is served in the very step its tag comes
  up.  **Starvation bound** (property-tested in tests/test_qos.py): for
  a ticket that is its session's ``q``-th pending ticket at arrival
  (counting itself), at most ``q * ceil(W / w) + N`` other tickets are
  served before it, where ``W`` is the total weight of the sessions
  that ever submitted and ``N`` their number.  Proof sketch: consecutive
  tickets of one session have start tags at least ``1/w_j`` apart and
  pending tags never sit below ``V``, so session ``j`` can own at most
  ``(S_t - V) * w_j + 1`` tags at or below ``S_t``, and the ticket's own
  chain bounds ``S_t - V <= q / w_i``; summing over sessions gives the
  bound.  Batch admission multiplies the positional bound by at most
  ``max_batch`` (within a step the cluster regrouping may reorder).

* **SLO classes** (``SLOClass``).  Tickets carry a class —
  ``interactive`` / ``batch`` / ``background`` — that sets their WFQ
  weight share, their shed eligibility, and a latency target the
  background cleaner's budget adapts to: a recent interactive arrival
  shrinks ``increment_rows``/``max_strips`` (via the PR 5 preemption
  points) until one increment fits inside the tightest active target
  (``latency_allowance``/``cleaner_budget`` — a small control loop over
  the cleaner's observed increment duration).

* **Stale-serve load shedding**.  Past ``overload_depth`` pending
  tickets, a sheddable ticket is answered AT SUBMIT from the
  version-vector cache's last-known entry for its fingerprint, tagged
  with an explicit ``staleness`` — the L1 distance between the entry's
  stored dependency vector and the current one
  (``vector_staleness``) — instead of queueing.  Never silently: a shed
  answer always carries the tag (0 means the entry is in fact current),
  an un-shed answer never carries one, and a fingerprint with no cached
  entry cannot be shed and queues normally.  This is the
  graceful-degradation ordering of SNIPPETS.md §1 — relax the
  least-valuable guarantee first: result freshness degrades (visibly,
  bounded by the tag) before interactive latency does, while the batch
  class absorbs the backlog by queueing.

Thread-safety: ``SLOClass``/``QoSPolicy`` are frozen and shared freely.
``FairQueue`` is NOT internally locked — the server mutates it only
under its own queue lock, exactly like the deque it replaces.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Mapping, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service class: queueing weight, latency target, shed policy.

    ``weight`` multiplies the session weight into the ticket's WFQ share.
    ``target_s`` is the class's latency objective — ``None`` means "no
    promise" (the cleaner ignores the class when sizing its budget, and
    deadline accounting only applies to tickets that opt in).
    ``sheddable`` marks classes that prefer a tagged slightly-stale
    answer over queueing when the service is past capacity."""

    name: str
    weight: float
    target_s: Optional[float] = None
    sheddable: bool = False

    def __post_init__(self):
        if self.weight <= 0.0:
            raise ValueError(f"SLO class {self.name!r}: weight must be > 0")


#: The default class ladder: interactive traffic holds the latency
#: promise (and may degrade freshness under overload to keep it), batch
#: absorbs backlog, background yields to everyone.
DEFAULT_SLO_CLASSES: Tuple[SLOClass, ...] = (
    SLOClass("interactive", weight=8.0, target_s=0.1, sheddable=True),
    SLOClass("batch", weight=2.0, target_s=2.0, sheddable=False),
    SLOClass("background", weight=1.0, target_s=None, sheddable=False),
)


def vector_staleness(stored, current) -> Optional[int]:
    """L1 distance between a cache entry's stored version (vector or the
    PR 3 plain int) and the current one — the shed tag's value.

    Versions are monotone, so a well-formed pair satisfies
    ``current >= stored`` componentwise and the distance is the number of
    cleaning commits the entry is behind.  Returns ``None`` when the two
    are incomparable (different shapes, non-monotone, or mixed types) —
    the caller must then refuse to shed rather than mis-tag."""
    if isinstance(stored, int) and isinstance(current, int):
        return current - stored if current >= stored else None
    try:
        stored_t, current_t = tuple(stored), tuple(current)
    except TypeError:
        return None
    if len(stored_t) != len(current_t):
        return None
    total = 0
    for s, c in zip(stored_t, current_t):
        if not isinstance(s, int) or not isinstance(c, int) or c < s:
            return None
        total += c - s
    return total


@dataclasses.dataclass(frozen=True)
class QoSPolicy:
    """The traffic-shaping knobs, bundled (frozen: shared by the server,
    the background cleaner, and the CLI without locking).

    ``overload_depth`` is the admission-control threshold: a sheddable
    ticket submitted while more than this many tickets are pending is
    answered stale-from-cache instead of queued (0 disables shedding —
    WFQ and SLO accounting still apply).  ``quiet_s`` is how long after a
    class's last arrival its latency target keeps constraining the
    background cleaner's budget."""

    classes: Tuple[SLOClass, ...] = DEFAULT_SLO_CLASSES
    overload_depth: int = 0
    quiet_s: float = 0.25
    min_increment_rows: int = 32

    def __post_init__(self):
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO class names: {names}")

    # --------------------------------------------------------------- classes
    def slo(self, name: str) -> SLOClass:
        """Look up a class by name; unknown names are submit-time errors
        (a typo must not silently become a default weight)."""
        for c in self.classes:
            if c.name == name:
                return c
        raise KeyError(
            f"unknown SLO class {name!r} (have {[c.name for c in self.classes]})"
        )

    def weight(self, session, slo: str) -> float:
        """A ticket's effective WFQ weight: session weight x class weight
        (sessionless tickets count as weight-1 sessions)."""
        base = session.weight if session is not None else 1.0
        return base * self.slo(slo).weight

    # -------------------------------------------------------------- shedding
    def should_shed(self, slo: str, depth: int) -> bool:
        """Admission decision: shed iff shedding is enabled, the class
        prefers stale answers to queueing, and the pending depth is past
        the overload threshold."""
        return (
            self.overload_depth > 0
            and depth > self.overload_depth
            and self.slo(slo).sheddable
        )

    # ------------------------------------------------- background-cleaner SLA
    def latency_allowance(
        self, now: float, last_arrival: Mapping[str, float]
    ) -> Optional[float]:
        """The tightest latency target among classes that arrived within
        the last ``quiet_s`` — how long the background cleaner may hold
        the executor lock without risking a just-arrived ticket's SLO.
        ``None`` when no target-bearing class is active (cleaner runs at
        its full configured budget)."""
        targets = [
            c.target_s
            for c in self.classes
            if c.target_s is not None
            and now - last_arrival.get(c.name, -math.inf) <= self.quiet_s
        ]
        return min(targets) if targets else None

    def cleaner_budget(
        self,
        allowance: Optional[float],
        est_increment_s: Optional[float],
        base_rows: int,
        base_strips: int,
    ) -> Tuple[int, int]:
        """Shrink the cleaner's per-increment budget so one lock hold fits
        the active latency allowance (DESIGN.md §14).

        ``est_increment_s`` is the cleaner's running estimate of its own
        increment duration at its *current* budget; scaling the budget by
        ``allowance / estimate`` forms a control loop that converges on
        increments of about the allowance: too-slow increments shrink the
        budget, comfortably-fast ones let it climb back toward the base.
        With no estimate yet the first constrained increment runs at the
        minimum (a strip / a quarter of the rows) rather than gambling a
        just-arrived interactive ticket's target on an unknown cost."""
        if allowance is None:
            return base_rows, base_strips
        floor_rows = min(base_rows, max(base_rows // 4, self.min_increment_rows))
        if est_increment_s is None or est_increment_s <= 0.0:
            return floor_rows, 1
        ratio = allowance / est_increment_s
        rows = min(base_rows, max(int(base_rows * ratio), floor_rows))
        strips = min(base_strips, max(int(base_strips * ratio), 1))
        return rows, strips


class FairQueue:
    """The server's pending queue: arrival-ordered storage with either
    FIFO (``policy=None`` — bit-compatible with the PR 3 deque) or
    virtual-time fair pick order (module docstring).  NOT internally
    locked: the owner serializes every call (the server uses its queue
    lock, exactly as it did for the deque this replaces).

    Ingest tickets are BARRIERS in either mode (DESIGN.md §12): fair
    picking only ever reorders tickets within one arrival segment — the
    run of queries between two ingests — so a query never crosses an
    append it arrived before or after.  Virtual time advances to the
    start tag of every picked ticket; within a segment the pick is the
    global minimum, which keeps the pending-tags-never-below-V invariant
    the starvation bound rests on.

    Cancelled tickets (``Ticket.cancel``) are discarded lazily at pick
    time and returned separately from the batch, so the server can count
    them without ever serving them."""

    def __init__(self, policy: Optional[QoSPolicy] = None):
        self.policy = policy
        self._pending: Deque = deque()
        self._vtime = 0.0
        self._finish: Dict[str, float] = {}
        self._depth_by_class: Dict[str, int] = {}

    def __len__(self) -> int:
        """Pending tickets, including not-yet-discarded cancelled ones
        (an overcount the next pick corrects — depth is an admission
        heuristic, not an invariant)."""
        return len(self._pending)

    def depth_by_class(self) -> Dict[str, int]:
        """Pending count per SLO class (same lazy-cancel caveat as
        ``__len__``)."""
        return dict(self._depth_by_class)

    # ------------------------------------------------------------------ push
    def push(self, ticket) -> None:
        """Append one ticket; in fair mode, stamp its virtual start/finish
        tags from its session chain (``ticket.weight`` must be set)."""
        if self.policy is not None and ticket.kind != "ingest":
            key = ticket.session.sid if ticket.session is not None else (
                f"__anon_{ticket.slo}"
            )
            weight = max(float(ticket.weight), 1e-9)
            start = max(self._vtime, self._finish.get(key, 0.0))
            ticket.start_tag = start
            ticket.finish_tag = start + 1.0 / weight
            self._finish[key] = ticket.finish_tag
        self._pending.append(ticket)
        cls = ticket.slo if ticket.kind != "ingest" else "ingest"
        self._depth_by_class[cls] = self._depth_by_class.get(cls, 0) + 1

    # ------------------------------------------------------------------ pick
    def _pick_index(self) -> int:
        """Index of the next ticket to pop: head in FIFO mode; in fair
        mode the minimum ``(start_tag, seq)`` within the head arrival
        segment (an ingest at the head IS the segment)."""
        if self.policy is None or self._pending[0].kind == "ingest":
            return 0
        best, best_key = 0, None
        for i, t in enumerate(self._pending):
            if t.kind == "ingest":
                break  # barrier: never reorder across it
            key = (t.start_tag, t.seq)
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    def pop_batch(self, k: int) -> Tuple[List, List]:
        """Pop up to ``k`` live tickets in pick order; returns
        ``(batch, cancelled)`` where ``cancelled`` are the discarded
        tickets found on the way (their session slots were already
        released by ``Ticket.cancel``)."""
        batch: List = []
        cancelled: List = []
        while len(batch) < k and self._pending:
            i = self._pick_index()
            ticket = self._pending[i]
            del self._pending[i]
            cls = ticket.slo if ticket.kind != "ingest" else "ingest"
            self._depth_by_class[cls] = self._depth_by_class.get(cls, 1) - 1
            if self.policy is not None and ticket.kind != "ingest":
                self._vtime = max(self._vtime, ticket.start_tag)
            if ticket.is_cancelled():
                cancelled.append(ticket)
                continue
            batch.append(ticket)
        return batch, cancelled


__all__ = [
    "DEFAULT_SLO_CLASSES",
    "FairQueue",
    "QoSPolicy",
    "SLOClass",
    "vector_staleness",
]
