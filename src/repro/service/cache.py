"""Clean-state-aware result cache (DESIGN.md §9, refined in §10).

Entries key on ``(query fingerprint, version)``, where the version the
server passes is the *scope-version vector* over the query's dependency
set (``scheduler.rule_deps``): one monotone counter per (table, rule)
whose cleaning commits can change the answer — since DESIGN.md §11 these
counters live in the executor's work ledger, whose per-strip commits
(foreground steps, background strip increments) each bump exactly the
committing rule's entry, so ledger-vector invalidation stays exact at
rule granularity even when cleaning advances one strip at a time.  The
executor bumps a rule's scope version on every candidate-overlay merge
and checked-bit commit for that rule, and its cleaning steps *skip* — no
state change, no bump — whenever a query's scope is already checked.  Re-executing a query
while its dependency vector is unchanged is therefore a pure function of
the probabilistic instance and returns bit-identical answers (the
soundness contract, asserted in tests/test_service.py), so a hit never
serves a stale answer — and a background cleaner's commits on OTHER rules
never invalidate it (exact-at-rule-granularity invalidation, asserted in
tests/test_service_background.py).

The cache itself is version-agnostic: it compares versions by equality
only, so plain ``clean_version`` ints (the PR-3 keying) and dependency
vectors both work.  Entries store the *post*-execution vector — the state
the answer was computed at (``execute`` may itself advance versions while
cleaning for the query; the answer reflects the advanced state).

Thread-safety: NOT internally locked.  The server performs every
lookup/insert while holding the executor's lock (``Daisy.lock``), which
also serializes it against the background cleaner's commits — that lock
is this structure's synchronization.

Cached ``DaisyResult``s are shared by reference across sessions; they are
treated as immutable (device arrays + a report nobody mutates).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional


class ResultCache:
    """LRU over (fingerprint -> (version, result)); see the module
    docstring for the versioning and locking contract."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._entries: OrderedDict[str, tuple[int, object]] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.stale = 0  # fingerprint present but clean_version moved on
        self.evictions = 0

    def get(self, fingerprint: str, clean_version) -> Optional[object]:
        """Return the cached result iff its stored version equals
        ``clean_version`` (int or dependency vector); drop stale entries."""
        entry = self._entries.get(fingerprint)
        if entry is None:
            self.misses += 1
            return None
        version, result = entry
        if version != clean_version:
            # the instance advanced: the stored answer may no longer equal a
            # fresh execution — drop it (re-insertion re-validates).  pop()
            # rather than del: a second step thread may have dropped it first
            # (stats can under/over-count under that misuse, lookups cannot
            # throw).
            self.stale += 1
            self.misses += 1
            self._entries.pop(fingerprint, None)
            return None
        self.hits += 1
        self._entries.move_to_end(fingerprint)
        return result

    def put(self, fingerprint: str, clean_version, result: object) -> None:
        """Insert/refresh an entry at its post-execution version."""
        self._entries[fingerprint] = (clean_version, result)
        self._entries.move_to_end(fingerprint)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def peek(self, fingerprint: str) -> Optional[tuple]:
        """The stored ``(version, result)`` for a fingerprint WHATEVER its
        age — the overload shed path's last-known-answer read (DESIGN.md
        §14).  Unlike ``get`` it mutates nothing: no hit/miss counters, no
        LRU promotion, and crucially no stale-drop, so an entry stays
        available for tagged stale serving until capacity evicts it or a
        regular lookup at a moved version drops it.  The caller tags the
        answer with ``qos.vector_staleness(version, current)`` and must
        refuse to shed when that distance is incomputable."""
        return self._entries.get(fingerprint)

    def version_of(self, fingerprint: str):
        """The stored version of an entry (None when absent) — test hook."""
        entry = self._entries.get(fingerprint)
        return None if entry is None else entry[0]

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, int]:
        """Counter snapshot (plain ints; same locking contract as above)."""
        return {
            "entries": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "stale": self.stale,
            "evictions": self.evictions,
        }
