"""Clean-state-aware result cache (DESIGN.md §9).

Entries key on ``(query fingerprint, clean_version)``.  The executor bumps
``Daisy.clean_version`` on every candidate-overlay merge and checked-bit
commit, and its cleaning steps *skip* — no state change, no bump — whenever
a query's scope is already checked for the rule.  Re-executing a query at
an unchanged version is therefore a pure function of the probabilistic
instance and returns bit-identical answers (the soundness contract,
asserted in tests/test_service.py), so a hit never serves a stale answer:
any cleaning progress since the entry was stored moved the version and
invalidates the entry exactly then.

Entries store the *post*-execution version — the version the instance held
when the answer was computed (``execute`` may itself advance the version
while cleaning for the query; the answer reflects the advanced state).

Cached ``DaisyResult``s are shared by reference across sessions; they are
treated as immutable (device arrays + a report nobody mutates).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Tuple


class ResultCache:
    """LRU over (fingerprint -> (clean_version, result))."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._entries: "OrderedDict[str, Tuple[int, object]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.stale = 0  # fingerprint present but clean_version moved on
        self.evictions = 0

    def get(self, fingerprint: str, clean_version: int) -> Optional[object]:
        entry = self._entries.get(fingerprint)
        if entry is None:
            self.misses += 1
            return None
        version, result = entry
        if version != clean_version:
            # the instance advanced: the stored answer may no longer equal a
            # fresh execution — drop it (re-insertion re-validates).  pop()
            # rather than del: a second step thread may have dropped it first
            # (stats can under/over-count under that misuse, lookups cannot
            # throw).
            self.stale += 1
            self.misses += 1
            self._entries.pop(fingerprint, None)
            return None
        self.hits += 1
        self._entries.move_to_end(fingerprint)
        return result

    def put(self, fingerprint: str, clean_version: int, result: object) -> None:
        self._entries[fingerprint] = (clean_version, result)
        self._entries.move_to_end(fingerprint)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def version_of(self, fingerprint: str) -> Optional[int]:
        entry = self._entries.get(fingerprint)
        return None if entry is None else entry[0]

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, int]:
        return {
            "entries": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "stale": self.stale,
            "evictions": self.evictions,
        }
