"""Admission + rule/cluster batching for the query server (DESIGN.md §9).

A ``Ticket`` is one session's query in flight.  ``batch_tickets`` groups
the tickets admitted into one server step by *cluster key*: the rules the
query overlaps ((X u Y) n (P u W) != {}, §4.1) plus the σ of its equality
predicates on rule attributes — the selection that relaxation expands to a
correlated cluster.  Tickets sharing a cluster run back-to-back, so one
``clean_sigma`` pass pays for the whole batch: the first execution
detects/repairs the cluster and marks it checked; every later ticket in
the group either hits the clean-state-aware cache (identical fingerprint
at an unchanged version) or executes with its cleaning steps skipped
(checked-bit bookkeeping, §4.3).  Groups keep first-arrival order and
tickets keep arrival order within a group, so scheduling only ever pulls
same-cluster work together; the equivalence tests assert the batched
answers stay bit-identical to a serial fresh-instance run.

``rule_deps`` is the cache side of the same overlap computation: the
(table, rule) scopes whose cleaning commits can change a query's answer —
what the server versions cache entries against so a background cleaner's
commits invalidate exactly the overlapping fingerprints (DESIGN.md §10).
Every table read adds its ``(table, __rows__)`` pseudo-scope, bumped only
by ``Daisy.ingest`` — an append invalidates this table's entries exactly
once, even for queries overlapping no rule (DESIGN.md §12).

Ingest tickets (``kind == "ingest"``) are batch BARRIERS: a batch is cut
into segments at each ingest ticket, clustering only within a segment, so
reordering by cluster never moves a query across an append it arrived
before (or after) — arrival order against ingests is preserved.

Pick order is the OTHER half of scheduling and lives in ``qos.FairQueue``
(DESIGN.md §14): the server admits each step's batch FIFO or in weighted
fair order, and only then does ``batch_tickets`` regroup the admitted
batch by cluster — so fairness decides *who* gets in, clustering decides
*how cheaply* they are served together.

Thread-safety: everything here is pure functions over immutable inputs
plus the ``Ticket`` record; a ticket is written by the serving thread and
waited on via its ``event`` by the submitting thread — fields other than
``event`` are read by the submitter only after ``event`` is set.  The
one exception is the pending/serving/cancelled state machine, which both
threads race on and which is guarded by the ticket's own ``_state_lock``.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.constraints import overlaps_query, rule_attrs
from repro.core.ledger import TABLE_ROWS_RULE
from repro.core.operators import Query, _fp_value
from repro.service.session import Session


@dataclasses.dataclass
class Ticket:
    """One submitted request: filled in by the serving thread, waited on by
    the submitting session's thread (``wait`` blocks on ``event``; every
    other field is safe to read only after ``event`` is set).

    ``kind`` is ``"query"`` (the default; ``query`` is set) or ``"ingest"``
    (a streaming append, DESIGN.md §12: ``ingest`` holds ``(table, rows)``
    and ``result`` becomes the ``IngestReport``).  Ingest tickets ride the
    same submit queue so appends serialize with queries in arrival order.

    Traffic shaping (DESIGN.md §14): ``slo`` names the ticket's service
    class, ``weight`` its effective WFQ share, and ``start_tag`` /
    ``finish_tag`` its virtual-time stamps (set by ``qos.FairQueue.push``
    in fair mode).  ``deadline`` is an *absolute* ``perf_counter`` time
    for deadline-miss accounting (``None`` = no deadline).  A shed ticket
    (``shed``) was answered at submit from the version-vector cache;
    ``staleness`` then carries the explicit vector distance between the
    answer's stored dependency vector and the current one — an un-shed
    answer never carries a tag (``None``).

    Lifecycle: ``pending -> serving -> done``, or ``pending -> cancelled``
    via ``cancel()`` (a timed-out ``wait`` cancels; the server discards
    cancelled tickets at pick/serve time without doing any cleaning
    work).  The tiny state machine is the only ticket state two threads
    race on, and it is guarded by its own lock."""

    seq: int
    session: Optional[Session]
    query: Optional[Query]
    fingerprint: str
    # the (table, rule) scopes this query's answer depends on — computed at
    # submit, versioned by the cache (DESIGN.md §10)
    deps: Tuple[Tuple[str, str], ...] = ()
    kind: str = "query"
    ingest: Optional[Tuple[str, Dict[str, object]]] = None  # (table, rows)
    # perf_counter stamp set at submit: the serving thread derives queue-wait
    # spans and end-to-end latency histograms from it (DESIGN.md §13)
    submitted: float = 0.0
    # traffic shaping (DESIGN.md §14)
    slo: str = "interactive"
    weight: float = 1.0
    deadline: Optional[float] = None  # absolute perf_counter deadline
    start_tag: float = 0.0  # virtual start time (fair mode)
    finish_tag: float = 0.0  # virtual finish time (fair mode)
    shed: bool = False  # answered stale-from-cache at submit
    staleness: Optional[int] = None  # version-vector distance of a shed answer
    event: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: Optional[object] = None  # DaisyResult / IngestReport once served
    cached: bool = False
    clean_version: Optional[int] = None
    error: Optional[BaseException] = None
    _state: str = dataclasses.field(default="pending", init=False)
    _state_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, init=False, repr=False, compare=False
    )

    # ------------------------------------------------------------- lifecycle
    def begin_serve(self) -> bool:
        """Claim the ticket for serving (serving thread).  False iff the
        ticket was cancelled first — the caller must then skip it without
        touching the executor (cancellation honored at serve time)."""
        with self._state_lock:
            if self._state != "pending":
                return False
            self._state = "serving"
            return True

    def finish_serve(self) -> None:
        """Mark the ticket served (serving thread; after ``event`` work)."""
        with self._state_lock:
            self._state = "done"

    def cancel(self) -> bool:
        """Abandon a still-pending ticket (submitting thread).  Releases
        the session's admission slot immediately and guarantees the server
        will do no detect/repair work for it.  False when serving already
        started or finished — the result then simply goes unread, and the
        slot is released by the normal completion path."""
        with self._state_lock:
            if self._state != "pending":
                return False
            self._state = "cancelled"
        if self.session is not None:
            self.session.fail(self.slo)
        return True

    def is_cancelled(self) -> bool:
        """True once ``cancel`` won the race (either thread may ask)."""
        with self._state_lock:
            return self._state == "cancelled"

    def wait(self, timeout: Optional[float] = None):
        """Block until served; returns the ``DaisyResult`` or raises the
        execution error.  Raises ``TimeoutError`` if the server did not
        answer in time — after CANCELLING the ticket, so an abandoned
        ticket is never executed with nobody reading the result (its
        session slot is released here, not at some later serve)."""
        if not self.event.wait(timeout):
            self.cancel()
            # cancel() lost only if serving already started; if it also
            # *finished* in the race window the answer is ready after all
            if not self.event.is_set():
                raise TimeoutError(
                    f"ticket {self.seq} not served within {timeout}s; cancelled"
                )
        if self.error is not None:
            raise self.error
        return self.result


def rule_deps(query: Query, rules: Dict[str, Sequence]) -> Tuple[Tuple[str, str], ...]:
    """The (table, rule) scopes whose cleaning can change this query's
    answer: rules on the query's tables whose attributes overlap the
    query's ((X u Y) n (P u W) != {}, §4.1).

    Repairs only ever merge candidates for a rule's own attributes, so a
    commit for a non-overlapping rule cannot move this query's answer —
    the cache keys entries on the version vector over exactly this set
    (DESIGN.md §10).

    Every table read also contributes its ``(table, __rows__)`` pseudo-scope
    (``core.ledger.TABLE_ROWS_RULE``), whose version only ``Daisy.ingest``
    bumps: appended rows can change ANY query's answer over the table —
    including one overlapping no rule — so the cache must go stale exactly
    once per append, and does, while entries over untouched tables survive
    (DESIGN.md §12).
    """
    tables = (query.table,) + tuple(j.right for j in query.joins)
    attrs = query.attrs
    out: List[Tuple[str, str]] = []
    for t in tables:
        for rule in rules.get(t, ()):
            if overlaps_query(rule, attrs):
                out.append((t, rule.name))
        out.append((t, TABLE_ROWS_RULE))
    return tuple(out)


def cluster_key(query: Query, rules: Dict[str, Sequence]) -> Tuple:
    """The (rules, σ) cluster a query's cleaning work belongs to.

    Two queries share a key iff they overlap the same rules on the same
    tables and filter rule attributes with the same equality σ — exactly
    when their relaxations expand to the same correlated cluster and the
    first execution's detect/repair pass covers both.  Queries overlapping
    no rule cluster by fingerprint alone (nothing to share but the cache).
    The ``__rows__`` pseudo-scope is a cache dependency, not a cleaning
    cluster, and is excluded here.
    """
    overlapping = tuple(
        d for d in rule_deps(query, rules) if d[1] != TABLE_ROWS_RULE
    )
    rule_cols: set = set()
    for t, rule_name in overlapping:
        for rule in rules.get(t, ()):
            if rule.name == rule_name:
                rule_cols.update(rule_attrs(rule))
    sigma = tuple(
        sorted(
            (p.col, p.op, _fp_value(p.value))
            for p in query.preds
            if p.col in rule_cols and p.op == "=="
        )
    )
    return (tuple(overlapping), sigma)


def batch_tickets(
    tickets: Sequence[Ticket], rules: Dict[str, Sequence]
) -> List[List[Ticket]]:
    """Group one step's tickets by cluster, first-arrival order throughout.

    Ingest tickets are barriers (module docstring): each one becomes its
    own singleton group, and clustering restarts after it — queries are
    only ever reordered relative to other queries in the same segment,
    never across an append."""
    out: List[List[Ticket]] = []
    groups: "OrderedDict[Tuple, List[Ticket]]" = OrderedDict()
    for ticket in tickets:
        if ticket.kind == "ingest":
            out.extend(groups.values())
            groups = OrderedDict()
            out.append([ticket])
            continue
        key = cluster_key(ticket.query, rules)
        if key == ((), ()):  # no rule overlap: share only via the cache
            key = ("fp", ticket.fingerprint)
        groups.setdefault(key, []).append(ticket)
    out.extend(groups.values())
    return out
