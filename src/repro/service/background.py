"""Cost-model-driven background cleaning behind the serving loop
(DESIGN.md §10).

The paper's engine cleans on demand, so the *first* query to touch a cold
rule/cluster scope pays the full detect/repair latency.  The
``BackgroundCleaner`` removes that first-touch cost from the interactive
path: between serving steps it full-cleans the cold scopes a
foreground query is most likely to touch next, in small preemptible
increments that commit through the executor's normal versioned path —
so by the time the query arrives, its cleaning steps skip and only the
answer is computed.

* **What is cold.**  ``Daisy.cold_rows``: unchecked rows, restricted for
  FDs to statically-dirty groups (clean groups skip via the Fig. 11 gate
  and cost foreground queries nothing — they are not background work
  either).
* **What runs first.**  ``core.cost.prioritize_scopes`` ranks scopes by
  expected foreground pairs saved (the rule's effective full-detect cost
  — dense, or the observed sharded-shuffle cost from
  ``ShardedDetectInfo`` — scaled by the cold fraction) times the
  touch probability aggregated from session lineage (``rule_touches``).
* **How it yields.**  Before each increment the cleaner checks
  ``server.pending_count()`` and defers (``wait_idle``) while foreground
  tickets queue; each increment holds ``Daisy.lock`` for one
  ``clean_scope_increment`` only — bounded for FDs by ``increment_rows``
  (whole lhs groups) and for DCs by ``increment_strips`` ledger strips
  (DESIGN.md §11; one strip x rest-of-dataset scan, NOT a full pairwise
  pass) — so a foreground ticket waits at most one bounded increment
  (the preemption-latency bound tests, FD and DC).
* **Why answers stay sound.**  Increments run the foreground cleaning
  pipeline itself and bump the same per-scope versions, so the cache
  invalidates exactly the fingerprints whose dependency scopes were
  touched; equal version vectors still imply bit-identical answers
  (DESIGN.md §10 has the full argument).

Thread-safety: one cleaner thread (``start``/``stop``); every mutation of
shared cleaning state happens inside ``Daisy.lock`` via
``clean_scope_increment``; metrics go through the ``observe_background``
path (its own lock); session lineage is read under each session's lock.
``step``/``drain`` may instead be called cooperatively from any single
thread (the benchmarks drive idle windows deterministically that way).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.constraints import FD
from repro.core.cost import ScopePriority, prioritize_scopes, sharded_detect_cost
from repro.core.executor import Daisy, StepReport
from repro.core.ledger import TABLE_ROWS_RULE
from repro.service.metrics import ServiceMetrics


@dataclasses.dataclass(frozen=True)
class IncrementReport:
    """What one background increment did (immutable; returned to the
    calling thread only)."""

    table: str
    rule: str
    step: Optional[StepReport]  # None when the executor skipped
    detect_delta: int
    repair_delta: int
    seconds: float
    scope_completed: bool  # the scope went warm with this increment


class BackgroundCleaner:
    """Preemptible background full-cleaner over one shared ``Daisy``.

    Construct with the server to serve behind (preemption + touch
    probabilities + shared metrics) or standalone (``server=None``:
    uniform touch probabilities, no preemption source — cooperative use).
    All configuration is read-only after construction; see the module
    docstring for the threading contract.
    """

    def __init__(
        self,
        daisy: Daisy,
        server=None,
        metrics: Optional[ServiceMetrics] = None,
        increment_rows: int = 512,
        increment_strips: int = 1,
        idle_wait: float = 0.02,
        tracer=None,
        policy=None,
    ):
        self.daisy = daisy
        self.server = server
        self.metrics = metrics if metrics is not None else (
            server.metrics if server is not None else ServiceMetrics()
        )
        # SLO-aware budget (DESIGN.md §14): defaults to the server's qos
        # policy, so one ``QueryServer(qos=...)`` wires the cleaner too.
        # When set, each increment's row/strip budget shrinks so one
        # executor-lock hold fits the tightest latency target among
        # recently-active classes (``QoSPolicy.cleaner_budget``), sized
        # against ``_inc_ewma`` — a running estimate of this cleaner's own
        # increment duration.
        self.policy = policy if policy is not None else (
            getattr(server, "qos", None) if server is not None else None
        )
        self._inc_ewma: Optional[float] = None
        # observability seam (DESIGN.md §13): defaults to the executor's
        # tracer (the server shares it too), so increments, yields and
        # preemption waits land in the same trace as the serving spans.
        self.tracer = tracer if tracer is not None else daisy.tracer
        self.increment_rows = increment_rows
        # DC increments clean this many ledger strips per lock hold
        # (DESIGN.md §11) — the DC analogue of ``increment_rows``
        self.increment_strips = max(int(increment_strips), 1)
        self.idle_wait = idle_wait
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # cached priority ranking, consumed scope-by-scope across increments
        # (cleaner thread only); refreshed when it empties, so a full
        # re-scan — per-rule cold counts under the executor lock plus the
        # session-lineage aggregation — happens once per warmup pass, not
        # once per increment.  Staleness only mis-orders work: every
        # increment re-checks coldness under the lock before cleaning.
        self._ranked: List[ScopePriority] = []

    # ------------------------------------------------------------ priorities
    def rule_touches(self) -> Dict[Tuple[str, str], int]:
        """Aggregate per-scope touch counts across all sessions' lineage
        (the priority model's demand signal; empty without a server).  The
        per-table ``__rows__`` pseudo-scope (cache invalidation on ingest,
        DESIGN.md §12) is not a cleanable scope and stays out of the
        signal."""
        touches: Dict[Tuple[str, str], int] = {}
        if self.server is None:
            return touches
        for session in self.server.session_list():
            for dep, count in session.rule_touches().items():
                if dep[1] == TABLE_ROWS_RULE:
                    continue
                touches[dep] = touches.get(dep, 0) + count
        return touches

    def cold_scopes(self) -> List[ScopePriority]:
        """Cold (table, rule) scopes ranked by expected foreground work
        saved (``core.cost.prioritize_scopes``); empty when warm."""
        daisy = self.daisy
        touches = self.rule_touches()
        keys = [(t, r.name) for t, rs in daisy.rules.items() for r in rs]
        total_touches = sum(touches.values())
        scopes: List[ScopePriority] = []
        for table, rule_name in keys:
            with daisy.lock:
                cold = daisy.cold_count(table, rule_name)
                cm = daisy.cost.get((table, rule_name))
                info = daisy.sharded_info.get((table, rule_name))
                n = int(cm.n) if cm is not None else int(
                    np.asarray(daisy.db[table].num_rows())
                )
                scope_ledger = daisy.ledger.scope(table, rule_name)
                fresh_cold = (
                    scope_ledger.fresh_cold_count if scope_ledger else 0
                )
                pending = daisy.ledger.has_pending(table, rule_name)
            if cm is not None:
                full_cost = cm.df_effective
            elif info is not None:
                full_cost = sharded_detect_cost(info, n_rows=n)
            else:
                rule = daisy._rule_named(table, rule_name)
                full_cost = float(n) if isinstance(rule, FD) else float(n) * n / max(
                    daisy.config.dc_partitions, 1
                )
            # Laplace-smoothed touch probability: every scope keeps a
            # nonzero chance, observed demand dominates as lineage grows
            touch_p = (touches.get((table, rule_name), 0) + 1.0) / (
                total_touches + len(keys)
            )
            scopes.append(
                ScopePriority(
                    table=table,
                    rule=rule_name,
                    cold_rows=cold,
                    expected_pairs=full_cost * cold / max(n, 1),
                    touch_probability=touch_p,
                    # freshly appended rows are the state most likely to
                    # surprise the next foreground query (DESIGN.md §12)
                    fresh_boost=2.0 if (fresh_cold > 0 or pending) else 1.0,
                    pending=pending,
                )
            )
        return prioritize_scopes(scopes)

    # ------------------------------------------------------------ increments
    def budget(self) -> Tuple[int, int]:
        """The (max_rows, max_strips) for the NEXT increment: the
        configured base, shrunk by the qos policy so one executor-lock
        hold fits the tightest latency target among recently-active SLO
        classes (DESIGN.md §14).  An interactive arrival within the
        policy's quiet window therefore makes the cleaner take smaller,
        more preemptible bites — the PR 5 preemption points do the rest.
        Without a policy or a server this is just the configured base."""
        rows, strips = self.increment_rows, self.increment_strips
        if self.policy is None or self.server is None:
            return rows, strips
        state = self.server.qos_state()
        allowance = self.policy.latency_allowance(
            time.perf_counter(), state["last_arrival"]
        )
        return self.policy.cleaner_budget(
            allowance, self._inc_ewma, rows, strips
        )

    def preempted(self) -> bool:
        """True when foreground tickets are queued — the handoff signal
        checked between increments."""
        return self.server is not None and self.server.pending_count() > 0

    def step(self) -> Optional[IncrementReport]:
        """Run ONE increment on the highest-priority cold scope; returns
        its report, or None when every scope is warm.  Does NOT check
        preemption — callers that should yield use ``drain``/``run``.

        A scope can go warm between the priority scan and the increment
        (a foreground query cleaned it first); such a race is not an
        increment — nothing is recorded and the next-priority scope is
        tried instead.  The ranking is cached across increments and only
        rebuilt once consumed (see ``_ranked``)."""
        daisy = self.daisy
        refreshed = False
        while True:
            if not self._ranked:
                if refreshed:
                    return None  # fresh scan found nothing cold
                self._ranked = self.cold_scopes()
                refreshed = True
                continue
            top = self._ranked[0]
            max_rows, max_strips = self.budget()
            t0 = time.perf_counter()
            with self.tracer.span(
                "bg.increment", table=top.table, rule=top.rule
            ) as sp, daisy.lock:
                d0, r0 = daisy.detect_calls, daisy.repair_calls
                step_rep = daisy.clean_scope_increment(
                    top.table, top.rule,
                    max_rows=max_rows,
                    max_strips=max_strips,
                )
                if step_rep is None:  # raced warm / stale ranking entry
                    sp.set(raced_warm=True)
                    self._ranked.pop(0)
                    continue
                dd = daisy.detect_calls - d0
                rd = daisy.repair_calls - r0
                completed = daisy.cold_count(top.table, top.rule) == 0
                progress = daisy.ledger.progress()
                sp.set(mode=step_rep.mode, completed=completed)
            if completed:
                self._ranked.pop(0)
            seconds = time.perf_counter() - t0
            # duration estimate for the SLO budget control loop (§14):
            # slow increments shrink the next budget, fast ones let it
            # climb back toward the configured base
            self._inc_ewma = seconds if self._inc_ewma is None else (
                0.7 * self._inc_ewma + 0.3 * seconds
            )
            self.metrics.observe_background(dd, rd, seconds, completed)
            self.metrics.observe_latency("bg-increment", seconds)
            self.metrics.observe_ledger(progress)
            return IncrementReport(
                table=top.table,
                rule=top.rule,
                step=step_rep,
                detect_delta=dd,
                repair_delta=rd,
                seconds=seconds,
                scope_completed=completed,
            )

    def drain(self, max_increments: Optional[int] = None) -> int:
        """Run increments until warm, preempted, or ``max_increments``;
        returns the number of increments run.  Cooperative entry point —
        the benchmarks call it in deterministic idle windows."""
        done = 0
        while max_increments is None or done < max_increments:
            if self.preempted():
                self.metrics.observe_bg_yield()
                self.tracer.instant("bg.yield")
                break
            if self.step() is None:
                break
            done += 1
        return done

    # ------------------------------------------------------------- lifecycle
    def run(self) -> None:
        """Cleaner-thread loop: wait for the server to go idle, run one
        increment, repeat; re-checks preemption before every increment.
        When everything is warm the re-scan interval backs off
        exponentially (to 1 s) so a long-lived warm server is not polled
        with per-rule cold counts every ``idle_wait``; any successful
        increment resets the backoff."""
        warm_wait = self.idle_wait
        while not self._stop.is_set():
            if self.server is not None and self.preempted():
                self.metrics.observe_bg_yield()
                self.tracer.instant("bg.yield")
                t0 = time.perf_counter()
                self.server.wait_idle(self.idle_wait)
                # how long foreground pressure kept the cleaner off the
                # lock — the preemption-latency track (DESIGN.md §13)
                self.tracer.record(
                    "bg.preempted", t0, time.perf_counter() - t0
                )
                continue
            if self.step() is None:
                self._stop.wait(warm_wait)
                warm_wait = min(warm_wait * 2.0, 1.0)
            else:
                warm_wait = self.idle_wait

    def start(self) -> "BackgroundCleaner":
        """Spawn the daemon cleaner thread (idempotent); returns self."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self.run, name="background-cleaner", daemon=True
            )
            self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Signal the cleaner thread to exit and join it."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
