"""Per-user serving sessions (DESIGN.md §9): identity, lineage, limits.

A ``Session`` is the unit of admission control and provenance.  Lineage
records, per answered query, the fingerprint, the clean-state version the
answer was computed at, the rule scopes the answer depended on, and
whether it came from the cache — enough to re-derive *which*
probabilistic instance a user's past answer reflects (the
gradually-cleaned database changes under them by design, §6), and enough
for the background cleaner's priority model to estimate per-scope touch
probabilities from what sessions actually query (DESIGN.md §10).

Limits are enforced at submit time: ``max_inflight`` bounds a session's
concurrently queued tickets (back-pressure per user), ``max_queries``
bounds its lifetime total (quota).  Violations raise ``SessionLimitError``
— the server surfaces them to the caller without touching the shared
executor.

Thread-safety: every mutating method and every reader of compound state
takes the session's own ``_lock`` (client threads call ``admit``; the
serving thread calls ``complete``/``fail``; the background cleaner calls
``rule_touches``).  Counter fields are only ever written under that lock.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Dict, List, Optional, Tuple


class SessionLimitError(RuntimeError):
    """A submit exceeded the session's inflight or lifetime quota."""


@dataclasses.dataclass(frozen=True)
class LineageEntry:
    """Provenance of one answered query (immutable; safe to share across
    threads once appended to a session's lineage under its lock)."""

    fingerprint: str
    clean_version: int
    result_size: int
    cached: bool
    # the (table, rule) scopes the answer depended on (``rule_deps``) —
    # the background priority model's touch-probability signal
    rules: Tuple[Tuple[str, str], ...] = ()


_SIDS = itertools.count()


class Session:
    """One user's admission state and answer provenance (module docstring
    has the locking contract; ``submitted``/``answered``/``failed`` are
    monotone counters, ``inflight`` is the only one that also decreases)."""

    def __init__(
        self,
        sid: Optional[str] = None,
        max_inflight: int = 64,
        max_queries: Optional[int] = None,
        max_lineage: int = 256,
    ):
        self.sid = sid if sid is not None else f"s{next(_SIDS)}"
        self.max_inflight = max_inflight
        self.max_queries = max_queries
        self.max_lineage = max_lineage
        self.submitted = 0
        self.inflight = 0
        self.answered = 0
        self.failed = 0
        self.lineage: List[LineageEntry] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------ admission
    def admit(self) -> None:
        """Claim one submission slot or raise ``SessionLimitError``."""
        with self._lock:
            if self.max_queries is not None and self.submitted >= self.max_queries:
                raise SessionLimitError(
                    f"session {self.sid}: lifetime quota {self.max_queries} reached"
                )
            if self.inflight >= self.max_inflight:
                raise SessionLimitError(
                    f"session {self.sid}: {self.inflight} tickets already in flight"
                )
            self.submitted += 1
            self.inflight += 1

    def complete(self, entry: LineageEntry) -> None:
        """Record one answered query (serving thread)."""
        with self._lock:
            self.inflight -= 1
            self.answered += 1
            self.lineage.append(entry)
            del self.lineage[: -self.max_lineage]

    def fail(self) -> None:
        """Release the inflight slot of a submission that errored."""
        with self._lock:
            self.inflight -= 1
            self.failed += 1

    # ------------------------------------------------------------- reporting
    def rule_touches(self) -> Dict[Tuple[str, str], int]:
        """How often each (table, rule) scope appeared in this session's
        retained lineage — the background priority model's touch signal
        (recency-weighted for free by the ``max_lineage`` cap)."""
        with self._lock:
            touches: Dict[Tuple[str, str], int] = {}
            for entry in self.lineage:
                for dep in entry.rules:
                    touches[dep] = touches.get(dep, 0) + 1
            return touches

    def snapshot(self) -> Dict[str, object]:
        """JSON-serializable state summary (consistent: taken under the
        session lock)."""
        with self._lock:
            return {
                "sid": self.sid,
                "submitted": self.submitted,
                "inflight": self.inflight,
                "answered": self.answered,
                "failed": self.failed,
                "cached_answers": sum(e.cached for e in self.lineage),
                "last_clean_version": (
                    self.lineage[-1].clean_version if self.lineage else None
                ),
            }
