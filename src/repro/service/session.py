"""Per-user serving sessions (DESIGN.md §9): identity, lineage, limits.

A ``Session`` is the unit of admission control and provenance.  Lineage
records, per answered query, the fingerprint, the clean-state version the
answer was computed at, the rule scopes the answer depended on, and
whether it came from the cache — enough to re-derive *which*
probabilistic instance a user's past answer reflects (the
gradually-cleaned database changes under them by design, §6), and enough
for the background cleaner's priority model to estimate per-scope touch
probabilities from what sessions actually query (DESIGN.md §10).

Limits are enforced at submit time: ``max_inflight`` bounds a session's
concurrently queued tickets (back-pressure per user), ``max_queries``
bounds its lifetime total (quota), and ``class_limits`` bounds the
inflight tickets of individual SLO classes (DESIGN.md §14 — e.g. cap a
user's concurrent ``batch`` tickets without touching their interactive
headroom).  Violations raise ``SessionLimitError`` — the server surfaces
them to the caller without touching the shared executor.

``weight`` is the session's weighted-fair-queueing share (DESIGN.md
§14): the server multiplies it by the ticket's SLO-class weight to get
the effective WFQ weight, so a paying-tier session can be given a larger
slice of the serving order without starving anyone (the qos module's
starvation bound is in terms of these weights).

Thread-safety: every mutating method and every reader of compound state
takes the session's own ``_lock`` (client threads call ``admit``; the
serving thread calls ``complete``/``fail``; the background cleaner calls
``rule_touches``).  Counter fields are only ever written under that lock.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Dict, List, Optional, Tuple


class SessionLimitError(RuntimeError):
    """A submit exceeded the session's inflight or lifetime quota."""


@dataclasses.dataclass(frozen=True)
class LineageEntry:
    """Provenance of one answered query (immutable; safe to share across
    threads once appended to a session's lineage under its lock)."""

    fingerprint: str
    clean_version: int
    result_size: int
    cached: bool
    # the (table, rule) scopes the answer depended on (``rule_deps``) —
    # the background priority model's touch-probability signal
    rules: Tuple[Tuple[str, str], ...] = ()


_SIDS = itertools.count()


class Session:
    """One user's admission state and answer provenance (module docstring
    has the locking contract; ``submitted``/``answered``/``failed`` are
    monotone counters, ``inflight`` is the only one that also decreases)."""

    def __init__(
        self,
        sid: Optional[str] = None,
        max_inflight: int = 64,
        max_queries: Optional[int] = None,
        max_lineage: int = 256,
        weight: float = 1.0,
        class_limits: Optional[Dict[str, int]] = None,
    ):
        if weight <= 0.0:
            raise ValueError(f"session weight must be > 0, got {weight}")
        self.sid = sid if sid is not None else f"s{next(_SIDS)}"
        self.max_inflight = max_inflight
        self.max_queries = max_queries
        self.max_lineage = max_lineage
        # WFQ share (DESIGN.md §14): effective ticket weight is this times
        # the SLO-class weight
        self.weight = float(weight)
        # per-SLO-class inflight caps (DESIGN.md §14); classes absent from
        # the mapping are bounded only by ``max_inflight``
        self.class_limits = dict(class_limits or {})
        self.submitted = 0
        self.inflight = 0
        self.answered = 0
        self.failed = 0
        self.inflight_by_class: Dict[str, int] = {}
        self.lineage: List[LineageEntry] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------ admission
    def admit(self, slo: str = "interactive") -> None:
        """Claim one submission slot for an SLO class or raise
        ``SessionLimitError`` (lifetime quota, total inflight, or the
        class's own inflight cap)."""
        with self._lock:
            if self.max_queries is not None and self.submitted >= self.max_queries:
                raise SessionLimitError(
                    f"session {self.sid}: lifetime quota {self.max_queries} reached"
                )
            if self.inflight >= self.max_inflight:
                raise SessionLimitError(
                    f"session {self.sid}: {self.inflight} tickets already in flight"
                )
            limit = self.class_limits.get(slo)
            in_class = self.inflight_by_class.get(slo, 0)
            if limit is not None and in_class >= limit:
                raise SessionLimitError(
                    f"session {self.sid}: {in_class} {slo!r} tickets already "
                    f"in flight (class limit {limit})"
                )
            self.submitted += 1
            self.inflight += 1
            self.inflight_by_class[slo] = in_class + 1

    def _release(self, slo: str) -> None:
        """Give back one inflight slot (callers hold ``_lock``)."""
        self.inflight -= 1
        in_class = self.inflight_by_class.get(slo, 0)
        if in_class > 0:
            self.inflight_by_class[slo] = in_class - 1

    def complete(self, entry: LineageEntry, slo: str = "interactive") -> None:
        """Record one answered query (serving thread; ``slo`` must match
        the class the ticket was admitted under)."""
        with self._lock:
            self._release(slo)
            self.answered += 1
            self.lineage.append(entry)
            del self.lineage[: -self.max_lineage]

    def fail(self, slo: str = "interactive") -> None:
        """Release the inflight slot of a submission that errored or was
        cancelled (``slo`` must match the admitted class)."""
        with self._lock:
            self._release(slo)
            self.failed += 1

    # ------------------------------------------------------------- reporting
    def rule_touches(self) -> Dict[Tuple[str, str], int]:
        """How often each (table, rule) scope appeared in this session's
        retained lineage — the background priority model's touch signal
        (recency-weighted for free by the ``max_lineage`` cap)."""
        with self._lock:
            touches: Dict[Tuple[str, str], int] = {}
            for entry in self.lineage:
                for dep in entry.rules:
                    touches[dep] = touches.get(dep, 0) + 1
            return touches

    def snapshot(self) -> Dict[str, object]:
        """JSON-serializable state summary (consistent: taken under the
        session lock)."""
        with self._lock:
            return {
                "sid": self.sid,
                "weight": self.weight,
                "submitted": self.submitted,
                "inflight": self.inflight,
                "inflight_by_class": dict(self.inflight_by_class),
                "answered": self.answered,
                "failed": self.failed,
                "cached_answers": sum(e.cached for e in self.lineage),
                "last_clean_version": (
                    self.lineage[-1].clean_version if self.lineage else None
                ),
            }
