"""Concurrent query serving over one shared Daisy instance (DESIGN.md §9,
background cleaning §10).

The step loop is continuous batching in the spirit of
``serve/engine.py``'s slot table: submitted tickets queue in arrival
order; every ``step`` admits up to ``max_batch`` tickets, orders them by
cluster (``scheduler.batch_tickets``), and serves each through the
clean-state-aware cache or the shared executor.  Admission happens every
step — sessions never wait for a "round" to finish.

Threading model: ``submit`` is fully thread-safe (many client threads,
one condition-guarded queue); the step loop is intended to run on ONE
serving thread (``run``), which makes batching deterministic.  Each
ticket is served while holding the executor's lock (``Daisy.lock``), so
the version-vector read, cache lookup, execution, and insert are atomic
with respect to a concurrent ``BackgroundCleaner`` — whose increments
take the same lock, making ticket boundaries the preemption points.  The
executor itself is re-entrant, so even misuse — multiple step threads —
degrades to query-granularity interleaving rather than torn state.

Serving a ticket: consult the cache at the query's *current* dependency
version vector (``scope_versions`` over ``rule_deps`` — so cleaning
commits for non-overlapping rules, foreground or background, never
invalidate it); on a hit the answer is returned without touching the
executor (this is where repeated exploratory workloads win); on a miss
the shared executor runs the query — cleaning the gradually-cleaned
instance as a side effect — and the answer is cached at the
post-execution vector.  Duplicate fingerprints inside one step resolve
the same way: the first execution's vector is current for the second
ticket unless an intervening execution advanced a dependency, in which
case the duplicate re-executes exactly as a serial run would.

The background handoff signal: ``pending_count`` and ``wait_idle`` let a
``BackgroundCleaner`` defer to foreground work — the queue going
non-empty clears the idle event, draining it sets the event again.

Traffic shaping (DESIGN.md §14): constructed with a ``qos.QoSPolicy``,
admission changes in three ways while everything above stays true.
Tickets carry an SLO class and a WFQ weight, and each step's batch is
picked in weighted fair order (``qos.FairQueue``) instead of FIFO —
cluster regrouping still happens, but within the fair batch.  Past the
policy's overload depth, sheddable tickets are answered AT SUBMIT from
the cache's last-known entry with an explicit ``staleness`` tag instead
of queueing (``_try_shed`` — it takes ``daisy.lock``, which is why the
shed gate runs outside the queue lock: ``snapshot`` nests the two locks
the other way).  And cancelled tickets (a timed-out ``wait``) are
discarded at pick/serve time without touching the executor.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.core.executor import Daisy
from repro.core.operators import Query, query_fingerprint
from repro.service.cache import ResultCache
from repro.service.metrics import ServiceMetrics
from repro.service.qos import FairQueue, QoSPolicy, vector_staleness
from repro.service.scheduler import Ticket, batch_tickets, rule_deps
from repro.service.session import LineageEntry, Session, SessionLimitError


class QueryServer:
    """The serving facade: sessions submit queries, one serving thread
    steps them through cache + shared executor (module docstring has the
    full threading contract).  ``sessions`` is guarded by ``_lock``; the
    pending deque by ``_work`` (same lock object as ``_lock``); everything
    the executor owns by ``daisy.lock``."""

    def __init__(
        self,
        daisy: Daisy,
        cache: Optional[ResultCache] = None,
        metrics: Optional[ServiceMetrics] = None,
        max_batch: int = 8,
        tracer=None,
        qos: Optional[QoSPolicy] = None,
    ):
        self.daisy = daisy
        self.cache = cache if cache is not None else ResultCache()
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self.max_batch = max_batch
        # observability seam (DESIGN.md §13): defaults to the executor's
        # tracer so one ``Daisy(tracer=...)`` wires the whole stack.  Spans:
        # per-ticket queue-wait (on a synthetic "queue" track — it overlaps
        # serving-thread spans), batch formation, cache lookup, execute,
        # commit, ingest barriers, idle waits.  End-to-end ticket latency
        # feeds ``metrics.observe_latency`` per ticket class.
        self.tracer = tracer if tracer is not None else daisy.tracer
        # traffic shaping (DESIGN.md §14): None keeps the PR 3 behavior
        # exactly (FIFO admission, no shedding, no class accounting beyond
        # the latency histograms); a policy turns on weighted fair
        # admission, the overload shed gate, and the cleaner's SLO budget.
        self.qos = qos
        self.sessions: Dict[str, Session] = {}
        self._queue = FairQueue(qos)
        # last submit perf_counter stamp per SLO class — what the
        # background cleaner's latency allowance is computed from (§14)
        self._last_arrival: Dict[str, float] = {}
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        # set <=> no ticket queued OR admitted-but-unserved: the background
        # cleaner must stay preempted for a whole in-flight batch, not just
        # until step() pops it off the queue
        self._idle = threading.Event()
        self._idle.set()
        self._inflight_batch = 0
        self._seq = 0
        self._stopping = False

    # ------------------------------------------------------------- sessions
    def open_session(self, sid: Optional[str] = None, **limits) -> Session:
        """Create and register a session (thread-safe)."""
        session = Session(sid, **limits)
        with self._lock:
            self.sessions[session.sid] = session
        return session

    def session_list(self) -> List[Session]:
        """Snapshot of registered sessions (thread-safe; the background
        cleaner aggregates lineage touch counts over it)."""
        with self._lock:
            return list(self.sessions.values())

    # ------------------------------------------------------------ admission
    def submit(
        self,
        session: Session,
        query: Query,
        slo: str = "interactive",
        deadline: Optional[float] = None,
    ) -> Ticket:
        """Queue a query; thread-safe; raises ``SessionLimitError`` on
        quota (total, lifetime, or per-class).

        ``slo`` names the ticket's service class (DESIGN.md §14): with a
        ``qos`` policy it sets the WFQ weight, the shed eligibility, and
        the cleaner-budget pressure; without one it is accounting only.
        ``deadline`` (seconds from now, optional) arms deadline-miss
        accounting for this ticket.

        Admission control: when the policy says the service is past
        ``overload_depth`` and the class is sheddable, the ticket is
        answered HERE — from the cache's last-known entry for its
        fingerprint, with an explicit ``staleness`` tag (the version-
        vector distance to the current state) — and never queued.  A
        fingerprint with no cached entry cannot be shed and queues
        normally; shedding never happens silently or with the policy
        disabled."""
        policy = self.qos
        if policy is not None:
            policy.slo(slo)  # unknown class -> KeyError before any state
        try:
            session.admit(slo)
        except SessionLimitError:
            with self._lock:
                self.metrics.rejected += 1
            raise
        now = time.perf_counter()
        with self._work:
            if self._stopping:
                session.fail(slo)
                raise RuntimeError("server is stopping; submission refused")
            seq = self._seq
            self._seq += 1
            self._last_arrival[slo] = now
            depth = len(self._queue) + self._inflight_batch
        self.metrics.observe_admitted(slo)
        ticket = Ticket(
            seq=seq,
            session=session,
            query=query,
            fingerprint=query_fingerprint(query),
            deps=rule_deps(query, self.daisy.rules),
            submitted=now,
            slo=slo,
            weight=policy.weight(session, slo) if policy is not None else 1.0,
            deadline=(now + deadline) if deadline is not None else None,
        )
        # the shed gate runs OUTSIDE the queue lock: it takes the executor
        # lock (version read + cache peek must be atomic vs the background
        # cleaner), and daisy.lock must never be acquired while holding
        # _work — snapshot() nests them the other way around
        if policy is not None and policy.should_shed(slo, depth):
            if self._try_shed(ticket):
                return ticket
        with self._work:
            if self._stopping:
                session.fail(slo)
                raise RuntimeError("server is stopping; submission refused")
            self._queue.push(ticket)
            self._idle.clear()
            self._work.notify()
        return ticket

    def _try_shed(self, ticket: Ticket) -> bool:
        """Answer an overloaded sheddable ticket from the version-vector
        cache's last-known entry, tagged with its explicit staleness
        (DESIGN.md §14).  False when no entry exists or the stored version
        is incomparable with the current vector — the ticket must then
        queue; a stale answer is never served untagged."""
        daisy = self.daisy
        with daisy.lock:
            entry = self.cache.peek(ticket.fingerprint)
            if entry is None:
                return False
            stored_version, result = entry
            current = daisy.scope_versions(ticket.deps)
            staleness = vector_staleness(stored_version, current)
            if staleness is None:
                return False
            clean_version = daisy.clean_version
        # claim the ticket so a concurrent cancel cannot double-release the
        # session slot (the submitter can't have timed out yet, but the
        # state machine is cheap insurance)
        if not ticket.begin_serve():
            return False
        ticket.shed = True
        ticket.staleness = staleness
        ticket.cached = True
        ticket.result = result
        ticket.clean_version = clean_version
        self.metrics.observe_shed(ticket.slo, staleness)
        ticket.session.complete(
            LineageEntry(
                fingerprint=ticket.fingerprint,
                clean_version=clean_version,
                result_size=result.report.result_size,
                cached=True,
                rules=ticket.deps,
            ),
            slo=ticket.slo,
        )
        ticket.finish_serve()
        ticket.event.set()
        self.tracer.instant(
            "serve.shed", seq=ticket.seq, slo=ticket.slo, staleness=staleness
        )
        self.metrics.observe_latency(
            ticket.slo, time.perf_counter() - ticket.submitted
        )
        return True

    def query(
        self,
        session: Session,
        query: Query,
        timeout: Optional[float] = None,
        slo: str = "interactive",
        deadline: Optional[float] = None,
    ):
        """Submit and block until answered (requires a running serving
        thread; synchronous callers use ``submit`` + ``drain`` instead).
        A timed-out wait CANCELS the ticket (scheduler.Ticket.wait), so an
        abandoned query is never executed for nobody."""
        return self.submit(session, query, slo=slo, deadline=deadline).wait(timeout)

    def ingest(self, table: str, rows, session: Optional[Session] = None) -> Ticket:
        """Queue a streaming append (DESIGN.md §12); thread-safe.

        The returned ticket's ``result`` is the ``IngestReport`` once
        served (``wait()``).  Ingest tickets ride the same queue as
        queries and act as batch barriers (``scheduler.batch_tickets``),
        so every query submitted before the append answers over the old
        rows and every one after it answers over the appended instance —
        arrival order, exactly as a serial client would observe.  No
        session quota applies: appends are producer traffic, not answered
        queries."""
        with self._work:
            if self._stopping:
                raise RuntimeError("server is stopping; submission refused")
            ticket = Ticket(
                seq=self._seq,
                session=session,
                query=None,
                fingerprint=f"ingest:{self._seq}",
                kind="ingest",
                ingest=(table, rows),
                submitted=time.perf_counter(),
            )
            self._seq += 1
            self._queue.push(ticket)
            self._idle.clear()
            self._work.notify()
        return ticket

    # ----------------------------------------------------- background signal
    def pending_count(self) -> int:
        """Number of unserved foreground tickets (queued plus the batch a
        step is currently serving) — the background cleaner checks this
        between increments and yields when > 0.  May transiently count a
        cancelled-but-not-yet-discarded ticket; the next pick corrects it."""
        with self._lock:
            return len(self._queue) + self._inflight_batch

    def qos_state(self) -> Dict[str, object]:
        """Traffic snapshot for the background cleaner's budget decision
        (DESIGN.md §14): pending depth (total and per SLO class) and the
        last arrival stamp per class.  Thread-safe; cheap (host dicts)."""
        with self._lock:
            return {
                "depth": len(self._queue) + self._inflight_batch,
                "depth_by_class": self._queue.depth_by_class(),
                "last_arrival": dict(self._last_arrival),
            }

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until the pending queue is empty (the handoff signal a
        background cleaner waits on); returns False on timeout."""
        return self._idle.wait(timeout)

    # ------------------------------------------------------------- step loop
    def step(self) -> int:
        """Admit up to ``max_batch`` pending tickets — FIFO, or in weighted
        fair order under a qos policy (DESIGN.md §14) — and serve them
        grouped by cluster.  Returns the number of tickets served.  Single
        serving thread only (see module docstring)."""
        with self._lock:
            batch, dropped = self._queue.pop_batch(self.max_batch)
            self._inflight_batch = len(batch)
            if not batch:
                self._idle.set()
        for t in dropped:  # cancelled while queued: no work was done
            self.metrics.observe_cancelled(t.slo)
        if not batch:
            return 0
        try:
            executed_this_step: set = set()
            with self.tracer.span("serve.batch", tickets=len(batch)) as sp:
                groups = batch_tickets(batch, self.daisy.rules)
                sp.set(groups=len(groups))
            for group in groups:
                for ticket in group:
                    self._serve(ticket, executed_this_step)
        finally:
            # the cleaner may resume only once the whole batch is answered
            with self._lock:
                self._inflight_batch = 0
                if not len(self._queue):
                    self._idle.set()
        self.metrics.steps += 1
        return len(batch)

    def _serve(self, ticket: Ticket, executed_this_step: set) -> None:
        """Serve one ticket under the executor lock (atomic versus the
        background cleaner: vector read, cache lookup, execute, insert)."""
        daisy = self.daisy
        if ticket.kind == "ingest":
            self._serve_ingest(ticket)
            return
        if not ticket.begin_serve():
            # cancelled after admission, before serving: honored here — no
            # detect/repair work, no executor touch, slot already released
            self.metrics.observe_cancelled(ticket.slo)
            return
        self._record_queue_wait(ticket)
        with daisy.lock:
            d0, r0 = daisy.detect_calls, daisy.repair_calls
            tl0, ts0 = daisy.tiles_launched, daisy.tiles_skipped
            with self.tracer.span("serve.cache_lookup", seq=ticket.seq) as sp:
                vector = daisy.scope_versions(ticket.deps)
                result = self.cache.get(ticket.fingerprint, vector)
                sp.set(hit=result is not None)
            if result is not None:
                ticket.cached = True
                self.metrics.observe_hit(
                    same_step=ticket.fingerprint in executed_this_step
                )
            else:
                try:
                    with self.tracer.span(
                        "serve.execute", seq=ticket.seq, table=ticket.query.table
                    ):
                        result = daisy.execute(ticket.query)
                except Exception as exc:  # surface to the caller, keep serving
                    self.metrics.errors += 1
                    # partial cleaning work before the failure still happened
                    self.metrics.observe_work(
                        daisy.detect_calls - d0, daisy.repair_calls - r0,
                        daisy.tiles_launched - tl0, daisy.tiles_skipped - ts0,
                    )
                    ticket.error = exc
                    ticket.session.fail(ticket.slo)
                    ticket.finish_serve()
                    ticket.event.set()
                    return
            if not ticket.cached:
                # a pure cache hit publishes nothing, so only executed
                # results get a commit span — keeping the disabled-tracer
                # tax on the hit path to two no-op call sites (the <= 3%
                # overhead gate in tests/test_obs.py)
                with self.tracer.span("serve.commit", seq=ticket.seq):
                    self.cache.put(
                        ticket.fingerprint, daisy.scope_versions(ticket.deps),
                        result,
                    )
                    executed_this_step.add(ticket.fingerprint)
                    self.metrics.observe_execution(result.report)
            self.metrics.observe_work(
                daisy.detect_calls - d0, daisy.repair_calls - r0,
                daisy.tiles_launched - tl0, daisy.tiles_skipped - ts0,
            )
            ticket.result = result
            ticket.clean_version = daisy.clean_version
        ticket.session.complete(
            LineageEntry(
                fingerprint=ticket.fingerprint,
                clean_version=ticket.clean_version,
                result_size=result.report.result_size,
                cached=ticket.cached,
                rules=ticket.deps,
            ),
            slo=ticket.slo,
        )
        ticket.finish_serve()
        ticket.event.set()
        now = time.perf_counter()
        if ticket.deadline is not None and now > ticket.deadline:
            self.metrics.observe_deadline_miss(ticket.slo)
        if ticket.submitted:
            self.metrics.observe_latency("query", now - ticket.submitted)
            if self.qos is not None:
                # per-SLO-class percentiles (DESIGN.md §14); keyed by class
                # name so snapshot()["latency"]["interactive"] is the SLO gate
                self.metrics.observe_latency(ticket.slo, now - ticket.submitted)

    def _record_queue_wait(self, ticket: Ticket) -> None:
        """Span from submit to the moment serving starts, on the synthetic
        "queue" track (it overlaps serving-thread spans, so it must not
        break their nesting — obs/trace.py's thread contract)."""
        if ticket.submitted and self.tracer:
            now = time.perf_counter()
            self.tracer.record(
                "serve.queue_wait", ticket.submitted, now - ticket.submitted,
                thread="queue", seq=ticket.seq, kind=ticket.kind,
            )

    def _serve_ingest(self, ticket: Ticket) -> None:
        """Apply one queued append under the executor lock (DESIGN.md §12).
        The ``__rows__`` version bump inside ``Daisy.ingest`` is what
        invalidates this table's cache entries; no explicit cache work is
        needed here."""
        daisy = self.daisy
        table, rows = ticket.ingest
        if not ticket.begin_serve():
            self.metrics.observe_cancelled(ticket.slo)
            return
        self._record_queue_wait(ticket)
        with daisy.lock:
            try:
                with self.tracer.span(
                    "serve.ingest", seq=ticket.seq, table=table
                ) as sp:
                    report = daisy.ingest(table, rows)
                    sp.set(rows=report.rows)
            except Exception as exc:  # surface to the caller, keep serving
                self.metrics.errors += 1
                ticket.error = exc
                ticket.finish_serve()
                ticket.event.set()
                return
            self.metrics.observe_ingest(report)
            ticket.result = report
            ticket.clean_version = daisy.clean_version
        ticket.finish_serve()
        ticket.event.set()
        if ticket.submitted:
            self.metrics.observe_latency(
                "ingest", time.perf_counter() - ticket.submitted
            )

    # ------------------------------------------------------------ lifecycle
    def drain(self) -> int:
        """Serve everything pending synchronously (no serving thread needed).
        Returns the number of tickets served."""
        total = 0
        while True:
            served = self.step()
            if served == 0:
                return total
            total += served

    def run(self, max_steps: int = 1_000_000, idle_wait: float = 0.05) -> None:
        """Serving-thread loop: step while work arrives; exit once ``stop()``
        was called and the queue drained.  ``max_steps`` is a runaway
        backstop and counts only steps that served work — idling forever is
        fine.  Idle wait time feeds the ``idle_fraction`` gauge (the
        background cleaner's budget)."""
        served_steps = 0
        while served_steps < max_steps:
            if self.step():
                served_steps += 1
                continue
            with self._work:
                if self._stopping and not len(self._queue):
                    return
                with self.tracer.span("serve.idle"):
                    t0 = time.perf_counter()
                    self._work.wait(timeout=idle_wait)
                    self.metrics.observe_idle(time.perf_counter() - t0)

    def stop(self) -> None:
        """Refuse new submissions and wake the serving thread to exit after
        the queue drains (thread-safe)."""
        with self._work:
            self._stopping = True
            self._work.notify_all()

    def snapshot(self) -> Dict[str, object]:
        """JSON-serializable state: metrics (with foreground/background
        attribution and per-scope ledger progress), cache stats, clean
        version, per-session summaries."""
        with self.daisy.lock:  # coverage counts are mutated under this lock
            self.metrics.observe_ledger(self.daisy.ledger.progress())
        snap = self.metrics.snapshot()
        snap["cache"] = self.cache.stats()
        snap["clean_version"] = self.daisy.clean_version
        with self._lock:  # open_session inserts concurrently
            sessions = list(self.sessions.values())
        snap["sessions"] = [s.snapshot() for s in sessions]
        return snap
