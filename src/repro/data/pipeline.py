"""CleanDataPipeline — the paper's technique woven into LM training.

Every training step's batch request is a QUERY over the (dirty) example
metadata relation — "docs with language == L and quality >= q" — and Daisy's
cleaning operators run inside that query's plan (§5): the result is relaxed,
violations of the metadata constraints (e.g. FD source -> language) are
repaired probabilistically, and the delta persists.  The corpus therefore
cleans itself incrementally, driven by what training actually samples —
the exploratory-analysis regime of the paper with the training loop as the
query workload.

A possible-world sampling policy turns probabilistic query results into
concrete batches: a doc qualifies with the probability mass of its
qualifying candidates; ``threshold`` mode keeps docs whose mass exceeds tau.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Iterator, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.constraints import FD
from repro.core.executor import Daisy, DaisyConfig, IngestReport
from repro.core.operators import Pred, Query
from repro.core.relation import make_relation
from repro.data.generators import DirtyDataset, token_metadata_relation


@dataclasses.dataclass
class PipelineConfig:
    batch_docs: int = 32
    seq_len: int = 256
    vocab_size: int = 1024
    qualify: str = "threshold"  # 'threshold' | 'sample'
    tau: float = 0.5
    k: int = 8
    seed: int = 0


class CleanDataPipeline:
    """Query-driven, incrementally-cleaning batch source."""

    def __init__(
        self,
        meta: DirtyDataset,
        rules: Sequence[FD],
        cfg: PipelineConfig,
    ):
        self.cfg = cfg
        self.meta = meta
        n = len(meta.data["doc_id"])
        rel = make_relation(
            meta.data,
            overlay=[a for r in rules for a in r.attrs],
            k=cfg.k,
            rules=[r.name for r in rules],
        )
        self.daisy = Daisy(
            {"docs": rel}, {"docs": list(rules)},
            DaisyConfig(k=cfg.k, use_cost_model=True, expected_queries=64),
        )
        self.rng = np.random.default_rng(cfg.seed)
        # deterministic synthetic tokens per doc (hash-seeded)
        self._doc_seed = np.arange(n, dtype=np.int64) * 2654435761 % (2**31)
        self.queries_run = 0
        self.reports: List = []

    # --------------------------------------------------------------- queries
    def request(self, preds: Sequence[Pred]) -> np.ndarray:
        """Run one cleaned metadata query; returns qualifying doc ids."""
        q = Query("docs", preds=tuple(preds), project=("doc_id",))
        res = self.daisy.execute(q)
        self.queries_run += 1
        self.reports.append(res.report)
        rel = self.daisy.db["docs"]
        mask = np.asarray(res.mask)

        if self.cfg.qualify == "threshold":
            keep = mask
        else:  # sample each doc by its qualifying probability mass
            probs = self._qualify_mass(rel, preds)
            keep = mask & (self.rng.random(len(mask)) < probs)
        return np.asarray(rel.columns["doc_id"])[keep]

    def _qualify_mass(self, rel, preds) -> np.ndarray:
        mass = np.ones(rel.capacity, np.float32)
        for p in preds:
            if p.col in rel.cand:
                probs = np.asarray(rel.probs(p.col))
                vals = np.asarray(rel.cand[p.col])
                ok = _np_op(vals, p.op, p.value)
                has = probs.sum(axis=1) > 0
                base = _np_op(np.asarray(rel.columns[p.col]), p.op, p.value)
                mass *= np.where(has, (probs * ok).sum(axis=1), base.astype(np.float32))
            else:
                mass *= _np_op(np.asarray(rel.columns[p.col]), p.op, p.value)
        return mass

    # --------------------------------------------------------------- streaming
    def ingest_docs(self, data: Mapping[str, np.ndarray]) -> IngestReport:
        """Append a chunk of new docs into the live metadata relation
        through ``Daisy.ingest`` (DESIGN.md §12): the rows arrive dirty and
        cold, later batch requests clean them on demand exactly like the
        seed corpus, and rows already checked absorb the newcomers'
        evidence through the queued ingest-deltas.  Per-doc token seeds
        extend deterministically, so a doc's synthetic tokens are the same
        whether it arrived in the seed corpus or mid-training."""
        report = self.daisy.ingest("docs", data)
        max_id = int(np.max(np.asarray(data["doc_id"]))) + 1 if report.rows else 0
        if max_id > len(self._doc_seed):
            ids = np.arange(len(self._doc_seed), max_id, dtype=np.int64)
            self._doc_seed = np.concatenate(
                [self._doc_seed, ids * 2654435761 % (2**31)]
            )
        return report

    def stream_corpus(
        self, chunks: Iterable[Mapping[str, np.ndarray]]
    ) -> Iterator[IngestReport]:
        """Chunked streaming-ingest source: feed corpus growth through the
        pipeline one chunk at a time, yielding each chunk's
        ``IngestReport``.  Interleave with ``batches`` to train over a
        corpus that grows (and gradually cleans itself) mid-run."""
        for chunk in chunks:
            yield self.ingest_docs(chunk)

    # ---------------------------------------------------------------- batches
    def batches(
        self, workload: Sequence[Sequence[Pred]], steps: int
    ) -> Iterator[Dict[str, jnp.ndarray]]:
        """Cycle the query workload, yielding token batches."""
        for i in range(steps):
            preds = workload[i % len(workload)]
            docs = self.request(preds)
            if len(docs) == 0:
                docs = np.asarray(self.meta.data["doc_id"][:1])
            pick = self.rng.choice(docs, self.cfg.batch_docs, replace=True)
            yield self._tokens_for(pick)

    def _tokens_for(self, doc_ids: np.ndarray) -> Dict[str, jnp.ndarray]:
        b, s = self.cfg.batch_docs, self.cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        for i, d in enumerate(doc_ids):
            r = np.random.default_rng(self._doc_seed[int(d)])
            toks[i] = r.integers(0, self.cfg.vocab_size, s + 1)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }

    # -------------------------------------------------------------- metrics
    def cleaning_progress(self) -> Dict[str, float]:
        rel = self.daisy.db["docs"]
        total = float(np.asarray(rel.num_rows()))
        checked = {}
        for rule in self.daisy.rules["docs"]:
            c = np.asarray(rel.checked.get(rule.name, np.zeros(1)))
            checked[rule.name] = float(c.sum()) / total
        return checked


def _np_op(x, op, v):
    import operator

    return {
        "==": operator.eq, "!=": operator.ne, "<": operator.lt,
        "<=": operator.le, ">": operator.gt, ">=": operator.ge,
    }[op](x, v)


def default_pipeline(
    n_docs: int = 2048, cfg: Optional[PipelineConfig] = None
) -> Tuple[CleanDataPipeline, List[List[Pred]]]:
    """The standard corpus + per-language query workload."""
    cfg = cfg or PipelineConfig()
    meta = token_metadata_relation(n_docs)
    rules = [FD("src_lang", "source", "language")]
    pipe = CleanDataPipeline(meta, rules, cfg)
    workload = [
        [Pred("language", "==", lang), Pred("quality", ">=", 0.25)]
        for lang in range(16)
    ]
    return pipe, workload
