"""Synthetic dataset generators mirroring the paper's evaluation data.

* ``ssb_lineorder``: Star-Schema-Benchmark-style lineorder with a
  configurable orderkey/suppkey cardinality and FD orderkey -> suppkey
  (paper §7: 5K-100K distinct orderkeys, 100-10K suppkeys).
* ``suppliers``: the join partner with FD address -> suppkey.
* ``hospital_like`` / ``sensor_like``: FD / DC evaluation datasets.
* ``inject_fd_errors``: BART-style error injection — edits a fraction of
  rhs values per lhs group, uniformly spread so every query is affected
  (the paper's uniform-error variant), returning ground truth.
* ``inject_dc_errors``: perturbs values to create inequality-DC violating
  pairs at a requested rate.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class DirtyDataset:
    data: Dict[str, np.ndarray]  # dirty columns
    truth: Dict[str, np.ndarray]  # clean ground truth
    error_rows: np.ndarray  # bool mask of edited rows


def ssb_lineorder(
    n: int,
    n_orderkeys: int,
    n_suppkeys: int,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Clean lineorder: suppkey is a function of orderkey (FD holds)."""
    rng = np.random.default_rng(seed)
    order_of_row = rng.integers(0, n_orderkeys, n).astype(np.int32)
    supp_of_order = rng.integers(0, n_suppkeys, n_orderkeys).astype(np.int32)
    return {
        "orderkey": order_of_row,
        "suppkey": supp_of_order[order_of_row],
        "extended_price": rng.uniform(1000, 5000, n).astype(np.float32),
        "discount": rng.uniform(0.0, 0.5, n).astype(np.float32),
        "quantity": rng.integers(1, 50, n).astype(np.int32),
    }


def suppliers(n_suppkeys: int, seed: int = 1) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    addr = rng.permutation(n_suppkeys).astype(np.int32)  # address -> suppkey
    return {
        "suppkey": np.arange(n_suppkeys, dtype=np.int32),
        "address": addr,
        "region": rng.integers(0, 5, n_suppkeys).astype(np.int32),
    }


def inject_fd_errors(
    data: Dict[str, np.ndarray],
    lhs: str,
    rhs: str,
    frac_groups: float = 1.0,
    frac_rows: float = 0.1,
    n_values: Optional[int] = None,
    seed: int = 2,
) -> DirtyDataset:
    """Edit ``frac_rows`` of the rhs values inside ``frac_groups`` of the lhs
    groups (the paper: "randomly editing 10% of the suppliers that
    correspond to each orderkey"), uniform across the dataset."""
    rng = np.random.default_rng(seed)
    truth = {k: v.copy() for k, v in data.items()}
    dirty = {k: v.copy() for k, v in data.items()}
    values = dirty[rhs]
    n_vals = n_values or (int(values.max()) + 1)
    keys = dirty[lhs]
    uniq = np.unique(keys)
    chosen = rng.random(len(uniq)) < frac_groups
    dirty_groups = set(uniq[chosen].tolist())
    in_dirty_group = np.isin(keys, list(dirty_groups))
    edit = in_dirty_group & (rng.random(len(keys)) < frac_rows)
    # edited value: a different random rhs value
    noise = rng.integers(1, max(n_vals, 2), edit.sum()).astype(values.dtype)
    values[edit] = (values[edit] + noise) % n_vals
    dirty[rhs] = values
    return DirtyDataset(dirty, truth, edit)


def inject_dc_errors(
    data: Dict[str, np.ndarray],
    attr: str = "discount",
    frac_rows: float = 0.1,
    magnitude: float = 0.5,
    seed: int = 3,
) -> DirtyDataset:
    """Perturb ``attr`` upward on a row fraction so (price<, discount>)
    inversions appear (the paper's Fig. 12 setup)."""
    rng = np.random.default_rng(seed)
    truth = {k: v.copy() for k, v in data.items()}
    dirty = {k: v.copy() for k, v in data.items()}
    edit = rng.random(len(dirty[attr])) < frac_rows
    dirty[attr] = dirty[attr].copy()
    dirty[attr][edit] = dirty[attr][edit] + magnitude
    return DirtyDataset(dirty, truth, edit)


def hospital_like(n: int, error_frac: float = 0.05, seed: int = 4) -> DirtyDataset:
    """FD zip -> city / county-style dataset with a known clean version."""
    rng = np.random.default_rng(seed)
    n_zip = max(n // 20, 4)
    zipc = rng.integers(0, n_zip, n).astype(np.int32)
    city_of_zip = rng.integers(0, max(n_zip // 2, 2), n_zip).astype(np.int32)
    state_of_zip = rng.integers(0, 50, n_zip).astype(np.int32)
    data = {
        "zip": zipc,
        "city": city_of_zip[zipc],
        "state": state_of_zip[zipc],
        "beds": rng.integers(10, 500, n).astype(np.int32),
    }
    ds = inject_fd_errors(data, "zip", "city", 1.0, error_frac, seed=seed + 1)
    ds2 = inject_fd_errors(ds.data, "zip", "state", 1.0, error_frac, seed=seed + 2)
    return DirtyDataset(ds2.data, ds.truth, ds.error_rows | ds2.error_rows)


def token_metadata_relation(
    n_docs: int,
    n_sources: int = 64,
    error_frac: float = 0.1,
    seed: int = 5,
) -> DirtyDataset:
    """Training-corpus metadata: doc -> (source, language, quality_score).
    FD source -> language is the cleaning target of the data pipeline
    (a mislabeled language corrupts sampling filters)."""
    rng = np.random.default_rng(seed)
    source = rng.integers(0, n_sources, n_docs).astype(np.int32)
    lang_of_source = rng.integers(0, 16, n_sources).astype(np.int32)
    data = {
        "doc_id": np.arange(n_docs, dtype=np.int32),
        "source": source,
        "language": lang_of_source[source],
        "quality": rng.uniform(0, 1, n_docs).astype(np.float32),
        "length": rng.integers(100, 4096, n_docs).astype(np.int32),
    }
    return inject_fd_errors(data, "source", "language", 1.0, error_frac, seed=seed + 1)
