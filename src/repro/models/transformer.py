"""The pattern-unit transformer: one model covering the full zoo.

The layer stack is ``n_units`` repeats of the config's pattern (e.g. jamba's
[attn, mamba x7], gemma3's [local x5, global]).  Units run under a two-level
rematerialized scan: the outer scan saves only group-boundary residuals and
the checkpointed group body recomputes its interior — sqrt(L) activation
memory, the standard TPU fit strategy for deep stacks.

Entry points
------------
forward(params, cfg, batch)            -> (logits, aux)   training/prefill
loss_fn(params, cfg, batch)            -> (loss, metrics)
prefill(params, cfg, batch)            -> (logits_last, cache)
decode_step(params, cfg, cache, token) -> (logits, cache)  one-token serve
init_cache(cfg, b, s_max, dtype)       -> cache tree (shardable)
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attend_cache,
    attend_cross,
    attend_full,
    qkv_project,
    slice_true_kv,
    update_cache,
)
from repro.models.config import BlockSpec, ModelConfig, SSMConfig
from repro.models.layers import apply_norm, embed, mlp, unembed
from repro.models.mamba import (
    MambaState,
    mamba_decode_step,
    mamba_mixer,
)
from repro.models.moe import moe_mlp
from repro.models.params import cast_params
from repro.dist.hints import hint


def _compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def _group_size(u: int) -> int:
    """Divisor of u closest to sqrt(u) (two-level remat grouping)."""
    best, target = 1, math.sqrt(u)
    for g in range(1, u + 1):
        if u % g == 0 and abs(g - target) < abs(best - target):
            best = g
    return best


# --------------------------------------------------------------- block apply
def _apply_block(
    x: jnp.ndarray,
    bp: Dict,
    blk: BlockSpec,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    enc_kv,
    mamba_chunk: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One pattern-position block (pre-norm residual). Returns (x, aux)."""
    aux = jnp.float32(0.0)
    h = apply_norm(x, bp["pre_norm"], cfg.norm)
    if blk.mixer == "attn":
        t = qkv_project(
            h, bp["attn"], positions, cfg.rope, cfg.rope_theta,
            cfg.partial_rotary, cfg.qk_norm,
        )
        window = cfg.window if blk.attn_type == "local" else None
        x = x + attend_full(t, causal=True, window=window, params=bp["attn"])
    else:
        ssm = cfg.ssm or SSMConfig()
        x = x + mamba_mixer(h, bp["mamba"], ssm.d_state, ssm.d_conv, mamba_chunk)

    if enc_kv is not None and "cross" in bp:
        h = apply_norm(x, bp["cross_norm"], cfg.norm)
        x = x + attend_cross(h, enc_kv, bp["cross"])

    if "moe" in bp:
        h = apply_norm(x, bp["post_norm"], cfg.norm)
        m = cfg.moe
        out, aux = moe_mlp(
            h, bp["moe"], m.n_experts, m.top_k, m.capacity_factor, cfg.mlp,
            n_groups=cfg.moe_groups,
        )
        x = x + out
    elif "mlp" in bp:
        h = apply_norm(x, bp["post_norm"], cfg.norm)
        x = x + mlp(h, bp["mlp"], cfg.mlp)
    return x, aux


def _unit_stack(
    x: jnp.ndarray,
    units: Dict,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    enc_kv,
    mamba_chunk: int,
    remat: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scan the stacked units over x (two-level remat grouping)."""
    u = cfg.n_units

    def unit_body(x, unit_params):
        x = hint(x, "dp", None, None)  # pin residual stream batch-sharded
        aux = jnp.float32(0.0)
        for i, blk in enumerate(cfg.pattern):
            apply = _apply_block
            if remat and len(cfg.pattern) > 1:
                # long heterogeneous units (jamba: 8 blocks) additionally
                # remat per block, so one unit's backward holds one BLOCK's
                # interior, not eight.
                apply = jax.checkpoint(
                    _apply_block, static_argnums=(2, 3, 6)
                )
            x, a = apply(
                x, unit_params[f"block_{i}"], blk, cfg, positions, enc_kv,
                mamba_chunk,
            )
            aux = aux + a
        return x, aux

    if u == 1:
        x, aux = unit_body(x, jax.tree.map(lambda p: p[0], units))
        return x, aux

    g = _group_size(u) if remat else u
    ng = u // g

    def group_body(x, group_params):
        # the unit body is checkpointed AGAIN inside the group: when the
        # group replays during backward, each unit rematerializes its own
        # interior instead of stacking g units' activations (true sqrt-L).
        x, auxs = jax.lax.scan(jax.checkpoint(unit_body), x, group_params)
        return x, jnp.sum(auxs)

    if remat and ng > 1:
        grouped = jax.tree.map(
            lambda p: p.reshape(ng, g, *p.shape[1:]), units
        )
        x, auxs = jax.lax.scan(jax.checkpoint(group_body), x, grouped)
        return x, jnp.sum(auxs)

    body = jax.checkpoint(unit_body) if remat else unit_body
    x, auxs = jax.lax.scan(body, x, units)
    return x, jnp.sum(auxs)


# ------------------------------------------------------------------ encoder
def _run_encoder(params: Dict, cfg: ModelConfig, enc_frames: jnp.ndarray):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend).  Returns per-layer-shared encoder output (b, se, d)."""
    enc = params["encoder"]
    dtype = _compute_dtype(cfg)
    x = enc_frames.astype(dtype) + enc["pos_embed"][None].astype(dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def unit_body(x, up):
        h = apply_norm(x, up["pre_norm"], cfg.norm)
        from repro.models.attention import qkv_project as proj

        t = proj(h, up["attn"], positions, "none", cfg.rope_theta, 0.5, False)
        x = x + attend_full(t, causal=False, window=None, params=up["attn"])
        h = apply_norm(x, up["post_norm"], cfg.norm)
        x = x + mlp(h, up["mlp"], cfg.mlp)
        return x, jnp.float32(0.0)

    x, _ = jax.lax.scan(
        jax.checkpoint(unit_body), x, enc["units"]["block_0"]
    )
    return apply_norm(x, enc["final_norm"], cfg.norm)


def _cross_kv(params: Dict, cfg: ModelConfig, enc_out: jnp.ndarray):
    """Cross-attention K/V per decoder unit, precomputed once.

    Returns stacked (U, b, se, hq, hd) pairs consumed inside the unit scan.
    NOTE: whisper cross-attention has as many kv heads as q heads."""
    cross = params["units"]["block_0"]["cross"]
    k = jnp.einsum("bsd,udhk->ubshk", enc_out, cross["wk"])
    v = jnp.einsum("bsd,udhk->ubshk", enc_out, cross["wv"])
    return k, v


# ------------------------------------------------------------------ forward
def forward(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict,
    mamba_chunk: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  batch keys:

    tokens (b, s_text) int32; [enc_frames (b, se, d)] audio stub;
    [patch_embeds (b, vis, d)] vision stub.
    Returns (logits (b, s, V) float32, aux_loss scalar).
    """
    dtype = _compute_dtype(cfg)
    params = cast_params(params, cfg)
    tokens = batch["tokens"]
    x = embed(tokens, params["embed"], dtype)
    if cfg.frontend == "vision":
        x = jnp.concatenate([batch["patch_embeds"].astype(dtype), x], axis=1)
    x = hint(x, "dp", None, None)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    if cfg.rope == "none":
        x = x + params["pos_embed"][:s][None].astype(dtype)

    enc_kv = None
    if cfg.enc_dec:
        enc_out = _run_encoder(params, cfg, batch["enc_frames"])
        ck, cv = _cross_kv(params, cfg, enc_out)
        # cross kv are per-unit: fold into the scan by closure over index —
        # simplest exact form: treat them as scan xs alongside the params.
        enc_kv = (ck, cv)

    if enc_kv is None:
        x, aux = _unit_stack(
            x, params["units"], cfg, positions, None, mamba_chunk, cfg.remat
        )
    else:
        # scan with per-unit cross kv
        ck, cv = enc_kv

        def unit_body(x, xs):
            unit_params, k_u, v_u = xs
            aux = jnp.float32(0.0)
            for i, blk in enumerate(cfg.pattern):
                x, a = _apply_block(
                    x, unit_params[f"block_{i}"], blk, cfg, positions,
                    (k_u, v_u), mamba_chunk,
                )
                aux = aux + a
            return x, aux

        x, auxs = jax.lax.scan(
            jax.checkpoint(unit_body), x, (params["units"], ck, cv)
        )
        aux = jnp.sum(auxs)

    x = apply_norm(x, params["final_norm"], cfg.norm)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x, table)
    return logits, aux


def loss_fn(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict,
    aux_weight: float = 0.01,
    mamba_chunk: int = 128,
) -> Tuple[jnp.ndarray, Dict]:
    """Next-token cross-entropy (+ MoE aux).  labels: (b, s) int32, -1 = pad."""
    logits, aux = forward(params, cfg, batch, mamba_chunk)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        vis = logits.shape[1] - labels.shape[1]
        logits = logits[:, vis:]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll) / denom
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux, "tokens": denom}


# -------------------------------------------------------------------- cache
class LayerCache(NamedTuple):
    """Per-pattern-position stacked cache (U leading dim).

    attn blocks: k/v (U, b, S, kvp, hd); mamba blocks: MambaState stacked.
    """

    kind: str
    data: Tuple


def init_cache(cfg: ModelConfig, b: int, s_max: int, dtype=jnp.bfloat16):
    """Cache pytree: dict block_i -> per-kind stacked state."""
    u = cfg.n_units
    kvp = cfg.n_kv_heads  # cache stores TRUE kv heads (padding heads are
    # exact replicas — see params._attn_params; storing them would only
    # multiply HBM)
    hd = cfg.hd
    ssm = cfg.ssm or SSMConfig()
    d_in = ssm.expand * cfg.d_model
    cache: Dict = {"t": jnp.zeros((), jnp.int32)}
    for i, blk in enumerate(cfg.pattern):
        if blk.mixer == "attn":
            s_cache = min(s_max, cfg.window) if blk.attn_type == "local" else s_max
            kv_dt = jnp.int8 if cfg.kv_quant else dtype
            cache[f"block_{i}"] = {
                "k": jnp.zeros((u, b, s_cache, kvp, hd), kv_dt),
                "v": jnp.zeros((u, b, s_cache, kvp, hd), kv_dt),
            }
            if cfg.kv_quant:
                cache[f"block_{i}"]["k_scale"] = jnp.zeros(
                    (u, b, s_cache, kvp), jnp.bfloat16
                )
                cache[f"block_{i}"]["v_scale"] = jnp.zeros(
                    (u, b, s_cache, kvp), jnp.bfloat16
                )
        else:
            cache[f"block_{i}"] = {
                "h": jnp.zeros((u, b, d_in, ssm.d_state), jnp.float32),
                "conv": jnp.zeros((u, b, ssm.d_conv - 1, d_in), jnp.float32),
            }
    if cfg.enc_dec:
        hqp = cfg.n_heads_padded or cfg.n_heads
        cache["cross_k"] = jnp.zeros((u, b, cfg.enc_seq, hqp, hd), dtype)
        cache["cross_v"] = jnp.zeros((u, b, cfg.enc_seq, hqp, hd), dtype)
    return cache


def decode_step(
    params: Dict,
    cfg: ModelConfig,
    cache: Dict,
    token: jnp.ndarray,  # (b, 1) int32
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode: returns (logits (b, V) f32, updated cache)."""
    dtype = _compute_dtype(cfg)
    params = cast_params(params, cfg)
    t = cache["t"]
    x = embed(token, params["embed"], dtype)  # (b, 1, d)
    if cfg.rope == "none":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], t, 1, axis=0
        )[None].astype(dtype)
    positions = t[None, None] + jnp.zeros((x.shape[0], 1), jnp.int32)
    ssm = cfg.ssm or SSMConfig()

    def unit_body(x, xs):
        unit_params = xs["params"]
        new_cache = {}
        for i, blk in enumerate(cfg.pattern):
            bp = unit_params[f"block_{i}"]
            h = apply_norm(x, bp["pre_norm"], cfg.norm)
            if blk.mixer == "attn":
                tt = qkv_project(
                    h, bp["attn"], positions, cfg.rope, cfg.rope_theta,
                    cfg.partial_rotary, cfg.qk_norm,
                )
                ck, cv = xs[f"block_{i}"]["k"], xs[f"block_{i}"]["v"]
                mha = cfg.n_kv_heads == cfg.n_heads
                new_k = slice_true_kv(tt.k, ck.shape[2], mha)
                new_v = slice_true_kv(tt.v, ck.shape[2], mha)
                if cfg.kv_quant:
                    from repro.models.attention import quantize_kv

                    new_k, new_ks = quantize_kv(new_k)
                    new_v, new_vs = quantize_kv(new_v)
                s_cache = ck.shape[1]
                if blk.attn_type == "local":
                    slot = jnp.remainder(t, s_cache)  # ring buffer
                    t_eff = jnp.minimum(t + 1, s_cache)
                else:
                    slot = t
                    t_eff = t + 1
                ck, cv = update_cache(ck, cv, new_k, new_v, slot)
                kws = {}
                blk_cache = {"k": ck, "v": cv}
                if cfg.kv_quant:
                    cks = jax.lax.dynamic_update_slice(
                        xs[f"block_{i}"]["k_scale"], new_ks, (0, slot, 0)
                    )
                    cvs = jax.lax.dynamic_update_slice(
                        xs[f"block_{i}"]["v_scale"], new_vs, (0, slot, 0)
                    )
                    kws = {"k_scale": cks, "v_scale": cvs}
                    blk_cache.update(kws)
                # ring-buffer local windows attend over the whole (small)
                # buffer; global attends over [0, t]
                o = attend_cache(
                    tt.q, ck, cv,
                    t_eff if blk.attn_type == "local" else t + 1,
                    None, bp["attn"], **kws,
                )
                x = x + o
                new_cache[f"block_{i}"] = blk_cache
            else:
                st = MambaState(xs[f"block_{i}"]["h"], xs[f"block_{i}"]["conv"])
                o, st = mamba_decode_step(h, st, bp["mamba"], ssm.d_state, ssm.d_conv)
                x = x + o
                new_cache[f"block_{i}"] = {"h": st.h, "conv": st.conv}
            if cfg.enc_dec and "cross" in bp:
                hq = apply_norm(x, bp["cross_norm"], cfg.norm)
                q = jnp.einsum("bsd,dhk->bshk", hq, bp["cross"]["wq"])
                o = attend_cache(
                    q, xs["cross_k"], xs["cross_v"],
                    jnp.int32(cfg.enc_seq), None, bp["cross"],
                )
                x = x + o
            if "moe" in bp:
                h = apply_norm(x, bp["post_norm"], cfg.norm)
                m = cfg.moe
                out, _ = moe_mlp(
                    h, bp["moe"], m.n_experts, m.top_k, m.capacity_factor,
                    cfg.mlp, n_groups=cfg.moe_groups,
                )
                x = x + out
            elif "mlp" in bp:
                h = apply_norm(x, bp["post_norm"], cfg.norm)
                x = x + mlp(h, bp["mlp"], cfg.mlp)
        return x, new_cache

    xs = {"params": params["units"]}
    for i in range(len(cfg.pattern)):
        xs[f"block_{i}"] = cache[f"block_{i}"]
    if cfg.enc_dec:
        xs["cross_k"] = cache["cross_k"]
        xs["cross_v"] = cache["cross_v"]

    x, new_blocks = jax.lax.scan(unit_body, x, xs)
    new_cache = dict(cache)
    for i in range(len(cfg.pattern)):
        new_cache[f"block_{i}"] = new_blocks[f"block_{i}"]
    new_cache["t"] = t + 1

    x = apply_norm(x, params["final_norm"], cfg.norm)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x[:, 0], table)
    return logits, new_cache


def prefill(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict,
    s_max: Optional[int] = None,
    cache_dtype=jnp.bfloat16,
    mamba_chunk: int = 128,
) -> Tuple[jnp.ndarray, Dict]:
    """Run the full prompt, building the KV cache for subsequent decode.

    Functionally: forward + per-layer K/V stashes.  To keep the HLO scan
    one-unit-sized we re-project K/V inside the same scan; XLA CSEs the
    shared projections.
    """
    dtype = _compute_dtype(cfg)
    params = cast_params(params, cfg)
    tokens = batch["tokens"]
    x = embed(tokens, params["embed"], dtype)
    if cfg.frontend == "vision":
        x = jnp.concatenate([batch["patch_embeds"].astype(dtype), x], axis=1)
    b, s = x.shape[0], x.shape[1]
    s_max = s_max or s
    positions = jnp.arange(s, dtype=jnp.int32)
    if cfg.rope == "none":
        x = x + params["pos_embed"][:s][None].astype(dtype)
    ssm = cfg.ssm or SSMConfig()

    enc_kv_stacked = None
    if cfg.enc_dec:
        enc_out = _run_encoder(params, cfg, batch["enc_frames"])
        enc_kv_stacked = _cross_kv(params, cfg, enc_out)

    def unit_body(x, xs):
        unit_params = xs if enc_kv_stacked is None else xs[0]
        stash = {}
        for i, blk in enumerate(cfg.pattern):
            bp = unit_params[f"block_{i}"]
            enc_kv = None if enc_kv_stacked is None else (xs[1], xs[2])
            h = apply_norm(x, bp["pre_norm"], cfg.norm)
            if blk.mixer == "attn":
                tt = qkv_project(
                    h, bp["attn"], positions, cfg.rope, cfg.rope_theta,
                    cfg.partial_rotary, cfg.qk_norm,
                )
                window = cfg.window if blk.attn_type == "local" else None
                x = x + attend_full(tt, causal=True, window=window, params=bp["attn"])
                mha = cfg.n_kv_heads == cfg.n_heads
                k_true = slice_true_kv(tt.k, cfg.n_kv_heads, mha)
                v_true = slice_true_kv(tt.v, cfg.n_kv_heads, mha)
                if blk.attn_type == "local":
                    # ring-buffer layout: position p lives at index p % s_cache
                    s_cache = min(s_max, cfg.window)
                    k_keep = k_true[:, -s_cache:].astype(cache_dtype)
                    v_keep = v_true[:, -s_cache:].astype(cache_dtype)
                    pad = s_cache - k_keep.shape[1]
                    if pad:
                        k_keep = jnp.pad(k_keep, ((0, 0), (0, pad), (0, 0), (0, 0)))
                        v_keep = jnp.pad(v_keep, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    if s > s_cache:
                        shift = (s - s_cache) % s_cache
                        k_keep = jnp.roll(k_keep, shift, axis=1)
                        v_keep = jnp.roll(v_keep, shift, axis=1)
                else:
                    k_keep = k_true.astype(cache_dtype)
                    v_keep = v_true.astype(cache_dtype)
                    pad = s_max - s
                    if pad:
                        k_keep = jnp.pad(k_keep, ((0, 0), (0, pad), (0, 0), (0, 0)))
                        v_keep = jnp.pad(v_keep, ((0, 0), (0, pad), (0, 0), (0, 0)))
                stash[f"block_{i}"] = {"k": k_keep, "v": v_keep}
            else:
                x = x + mamba_mixer(h, bp["mamba"], ssm.d_state, ssm.d_conv,
                                    mamba_chunk)
                # final state recomputed cheaply for the cache via decode on
                # the last token is avoided: mixer recomputation with state
                # output would double compute; we instead stash a fresh
                # forward state below.
                st = _mamba_final_state(h, bp["mamba"], ssm)
                stash[f"block_{i}"] = {"h": st.h, "conv": st.conv}
            if enc_kv is not None and "cross" in bp:
                hq = apply_norm(x, bp["cross_norm"], cfg.norm)
                x = x + attend_cross(hq, enc_kv, bp["cross"])
            if "moe" in bp:
                h = apply_norm(x, bp["post_norm"], cfg.norm)
                m = cfg.moe
                out, _ = moe_mlp(
                    h, bp["moe"], m.n_experts, m.top_k, m.capacity_factor,
                    cfg.mlp, n_groups=cfg.moe_groups,
                )
                x = x + out
            elif "mlp" in bp:
                h = apply_norm(x, bp["post_norm"], cfg.norm)
                x = x + mlp(h, bp["mlp"], cfg.mlp)
        return x, stash

    xs = params["units"] if enc_kv_stacked is None else (
        params["units"], enc_kv_stacked[0], enc_kv_stacked[1]
    )
    x, stashes = jax.lax.scan(jax.checkpoint(unit_body), x, xs)

    cache = {"t": jnp.int32(s)}
    for i in range(len(cfg.pattern)):
        cache[f"block_{i}"] = stashes[f"block_{i}"]
    if cfg.enc_dec:
        cache["cross_k"] = enc_kv_stacked[0].astype(cache_dtype)
        cache["cross_v"] = enc_kv_stacked[1].astype(cache_dtype)

    x = apply_norm(x, params["final_norm"], cfg.norm)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x[:, -1], table)
    return logits, cache


def _mamba_final_state(h, mp, ssm, chunk: int = 128) -> MambaState:
    """Final SSM state after consuming h (b, s, d) — a chunked linear scan
    carrying only the (b, d_in, N) boundary state (no output projections)."""
    from repro.models.mamba import _causal_conv, _ssm_params

    b, s, _ = h.shape
    xz = jnp.einsum("bsd,dtc->bstc", h, mp["in_proj"])
    x_conv = xz[..., 0, :]
    xin = jax.nn.silu(_causal_conv(x_conv, mp["conv_w"], None) + mp["conv_b"])
    dt_rank = mp["dt_proj"].shape[0]
    dt, B, _ = _ssm_params(xin, mp, dt_rank, ssm.d_state)
    A = -jnp.exp(mp["A_log"].astype(jnp.float32))
    d_in = xin.shape[-1]
    ch = min(chunk, s)
    n_chunks = -(-s // ch)
    s_pad = n_chunks * ch
    if s_pad != s:  # dt=0 padding: state passes through (see mamba.py)
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        xin = jnp.pad(xin, pad)
        dt = jnp.pad(dt, pad)
        B = jnp.pad(B, pad)
    xf = xin.astype(jnp.float32).reshape(b, n_chunks, ch, d_in)
    dts = dt.reshape(b, n_chunks, ch, d_in)
    Bs = B.reshape(b, n_chunks, ch, ssm.d_state)

    def body(hc, inputs):
        xc, dtc, Bc = inputs
        a = jnp.exp(dtc[..., None] * A[None, None])
        u = (dtc * xc)[..., None] * Bc[..., None, :]

        def combine(a, b):
            return a[0] * b[0], a[1] * b[0] + b[1]

        aa, uu = jax.lax.associative_scan(combine, (a, u), axis=1)
        return aa[:, -1] * hc + uu[:, -1], None

    h0 = jnp.zeros((b, d_in, ssm.d_state), jnp.float32)
    h_fin, _ = jax.lax.scan(
        body, h0,
        (xf.swapaxes(0, 1), dts.swapaxes(0, 1), Bs.swapaxes(0, 1)),
    )
    conv = x_conv[:, -(ssm.d_conv - 1):].astype(jnp.float32)
    return MambaState(h=h_fin, conv=conv)
