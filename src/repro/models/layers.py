"""Shared neural layers: norms, RoPE variants, MLPs, embeddings.

Pure functions over parameter dicts (pytrees of jnp arrays).  Compute dtype
is controlled by the caller (params are cast on entry to each block);
normalization statistics and RoPE tables always run in float32.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(
    x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x: jnp.ndarray, params: dict, kind: str) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


# -------------------------------------------------------------------- RoPE
def rope_freqs(
    hd: int, theta: float, rotary_dim: Optional[int] = None
) -> jnp.ndarray:
    """(rotary_dim/2,) inverse frequencies."""
    rd = rotary_dim or hd
    return 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float = 10_000.0,
    mode: str = "standard",
    partial: float = 0.5,
) -> jnp.ndarray:
    """Rotary embedding.

    x: (..., seq, hd); positions: broadcastable to (..., seq).
    mode 'standard': rotate the full head dim (interleaved-pair convention).
    mode 'partial':  rotate only the first ``partial * hd`` dims (chatglm's
    2d-RoPE decoder form: half the head rotates, half passes through).
    mode 'none':     identity here (the model adds a learned-position table).
    mode 'nope':     identity (no positional encoding at all — jamba).
    """
    if mode in ("none", "nope"):
        return x
    hd = x.shape[-1]
    rd = hd if mode == "standard" else int(hd * partial) // 2 * 2
    freqs = rope_freqs(hd, theta, rd)  # (rd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, rd/2)
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    xr = x[..., :rd].astype(jnp.float32)
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rotated = jnp.stack([out1, out2], axis=-1).reshape(xr.shape)
    if rd == hd:
        return rotated.astype(x.dtype)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rd:]], axis=-1)


# --------------------------------------------------------------------- MLPs
def mlp(x: jnp.ndarray, params: dict, kind: str) -> jnp.ndarray:
    """Position-wise MLP.  kinds: swiglu | sq_relu | gelu.

    swiglu params:  wi (d, 2, f) fused gate+up, wo (f, d)
    others params:  wi (d, f), wo (f, d)
    """
    if kind == "swiglu":
        gate_up = jnp.einsum("...d,dtf->...tf", x, params["wi"])
        gate, up = gate_up[..., 0, :], gate_up[..., 1, :]
        h = jax.nn.silu(gate) * up
    elif kind == "sq_relu":
        h = jnp.einsum("...d,df->...f", x, params["wi"])
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jnp.einsum("...d,df->...f", x, params["wi"])
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# --------------------------------------------------------------- embeddings
def embed(tokens: jnp.ndarray, table: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Logits in float32 (loss numerics), vocab-sharded over TP — the
    (b, s, V) f32 buffer must never materialize replicated."""
    from repro.dist.hints import hint

    logits = jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32)
    )
    if logits.ndim == 3:
        return hint(logits, "dp", None, "tp")
    return hint(logits, "dp", "tp")
