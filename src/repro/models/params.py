"""Parameter initialization for the architecture zoo.

The tree layout is scan-friendly: every per-layer parameter is stacked over
the pattern-unit dimension U (leading axis), so the layer stack lowers to a
single `lax.scan` over units and the HLO stays one-unit-sized at any depth.

``abstract_params`` builds the same tree as ShapeDtypeStructs via
``jax.eval_shape`` — the dry-run path; nothing is allocated.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SSMConfig


def _norm_params(cfg: ModelConfig, d: int, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def _stack_norm(cfg: ModelConfig, u: int, d: int, dtype):
    p = {"scale": jnp.ones((u, d), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((u, d), dtype)
    return p


def _init(key, shape, dtype, fan_in):
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(key, cfg: ModelConfig, u: int, dtype, cross: bool = False):
    """Head padding preserves the model's math exactly:

    * KV heads replicate-pad (``jnp.repeat`` consecutively): padded head j
      is a copy of true head j // r, and the GQA q->kv group mapping under
      the padded count reproduces the true grouping (DESIGN.md §4).
    * Padded q heads (whisper's 20 -> 32) zero-init wq AND wo rows: they
      attend to nothing and contribute nothing.
    """
    d, hd = cfg.d_model, cfg.hd
    hq_true, kv_true = cfg.n_heads, cfg.n_kv_heads
    hq = cfg.n_heads_padded or hq_true
    kvp = cfg.n_kv_heads_padded or kv_true
    ks = jax.random.split(key, 4)

    wq = _init(ks[0], (u, d, hq_true, hd), dtype, d)
    if hq > hq_true:
        wq = jnp.concatenate(
            [wq, jnp.zeros((u, d, hq - hq_true, hd), dtype)], axis=2
        )
    wk = _init(ks[1], (u, d, kv_true, hd), dtype, d)
    wv = _init(ks[2], (u, d, kv_true, hd), dtype, d)
    if kvp > kv_true:
        if kv_true == hq_true:
            # MHA (whisper 20 heads): zero-pad alongside the q heads — the
            # padded kv heads are only read by padded (zero-output) q heads.
            wk = jnp.concatenate(
                [wk, jnp.zeros((u, d, kvp - kv_true, hd), dtype)], axis=2
            )
            wv = jnp.concatenate(
                [wv, jnp.zeros((u, d, kvp - kv_true, hd), dtype)], axis=2
            )
        else:
            assert kvp % kv_true == 0, (cfg.name, kvp, kv_true)
            r = kvp // kv_true
            wk = jnp.repeat(wk, r, axis=2)
            wv = jnp.repeat(wv, r, axis=2)
    wo = _init(ks[3], (u, hq_true, hd, d), dtype, hq_true * hd)
    if hq > hq_true:
        wo = jnp.concatenate(
            [wo, jnp.zeros((u, hq - hq_true, hd, d), dtype)], axis=1
        )
    p = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((u, hd), dtype)
        p["k_norm"] = jnp.ones((u, hd), dtype)
    return p


def _mlp_params(key, cfg: ModelConfig, u: int, d_ff: int, dtype):
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    if cfg.mlp == "swiglu":
        return {
            "wi": _init(k1, (u, d, 2, d_ff), dtype, d),
            "wo": _init(k2, (u, d_ff, d), dtype, d_ff),
        }
    return {
        "wi": _init(k1, (u, d, d_ff), dtype, d),
        "wo": _init(k2, (u, d_ff, d), dtype, d_ff),
    }


def _moe_params(key, cfg: ModelConfig, u: int, dtype):
    m = cfg.moe
    d = cfg.d_model
    e = m.n_experts_padded or m.n_experts
    f = m.d_ff_expert
    ks = jax.random.split(key, 5)
    if cfg.mlp == "swiglu":
        p = {
            "we_i": _init(ks[0], (u, e, d, 2, f), dtype, d),
            "we_o": _init(ks[1], (u, e, f, d), dtype, f),
        }
    else:
        p = {
            "we_i": _init(ks[0], (u, e, d, f), dtype, d),
            "we_o": _init(ks[1], (u, e, f, d), dtype, f),
        }
    p["router"] = _init(ks[2], (u, d, e), jnp.float32, d)
    if m.n_shared:
        fs = f * m.n_shared
        if cfg.mlp == "swiglu":
            p["shared_wi"] = _init(ks[3], (u, d, 2, fs), dtype, d)
        else:
            p["shared_wi"] = _init(ks[3], (u, d, fs), dtype, d)
        p["shared_wo"] = _init(ks[4], (u, fs, d), dtype, fs)
    return p


def _mamba_params(key, cfg: ModelConfig, u: int, dtype):
    ssm = cfg.ssm or SSMConfig()
    d = cfg.d_model
    d_in = ssm.expand * d
    r = ssm.dt_rank or -(-d // 16)
    n = ssm.d_state
    ks = jax.random.split(key, 6)
    a_init = jnp.tile(
        jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None, None, :], (u, d_in, 1)
    )
    return {
        "in_proj": _init(ks[0], (u, d, 2, d_in), dtype, d),
        "conv_w": _init(ks[1], (u, d_in, ssm.d_conv), dtype, ssm.d_conv),
        "conv_b": jnp.zeros((u, d_in), dtype),
        "x_proj": _init(ks[2], (u, d_in, r + 2 * n), dtype, d_in),
        "dt_proj": _init(ks[3], (u, r, d_in), dtype, r),
        "dt_bias": jnp.full((u, d_in), -4.0, dtype),  # softplus ~ 0.018
        "A_log": a_init,  # float32
        "D": jnp.ones((u, d_in), jnp.float32),
        "out_proj": _init(ks[4], (u, d_in, d), dtype, d_in),
    }


def init_params(key, cfg: ModelConfig) -> Dict:
    """Concrete parameter tree (smoke tests / examples)."""
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    u = cfg.n_units
    vocab = cfg.vocab_padded or cfg.vocab_size
    keys = iter(jax.random.split(key, 64))

    params: Dict = {
        "embed": _init(next(keys), (vocab, cfg.d_model), dtype, cfg.d_model),
        "final_norm": _norm_params(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(
            next(keys), (vocab, cfg.d_model), dtype, cfg.d_model
        )
    if cfg.rope == "none":
        params["pos_embed"] = _init(
            next(keys), (cfg.max_seq, cfg.d_model), dtype, cfg.d_model
        )

    units: Dict = {}
    for i, blk in enumerate(cfg.pattern):
        bp: Dict = {"pre_norm": _stack_norm(cfg, u, cfg.d_model, dtype)}
        if blk.mixer == "attn":
            bp["attn"] = _attn_params(next(keys), cfg, u, dtype)
        else:
            bp["mamba"] = _mamba_params(next(keys), cfg, u, dtype)
        if blk.moe and cfg.moe is not None:
            bp["post_norm"] = _stack_norm(cfg, u, cfg.d_model, dtype)
            bp["moe"] = _moe_params(next(keys), cfg, u, dtype)
        elif cfg.mlp != "none" and cfg.d_ff > 0:
            bp["post_norm"] = _stack_norm(cfg, u, cfg.d_model, dtype)
            bp["mlp"] = _mlp_params(next(keys), cfg, u, cfg.d_ff, dtype)
        if cfg.enc_dec:
            bp["cross_norm"] = _stack_norm(cfg, u, cfg.d_model, dtype)
            bp["cross"] = _attn_params(next(keys), cfg, u, dtype, cross=True)
        units[f"block_{i}"] = bp
    params["units"] = units

    if cfg.enc_dec:
        eu = cfg.enc_layers
        params["encoder"] = {
            "pos_embed": _init(
                next(keys), (cfg.enc_seq, cfg.d_model), dtype, cfg.d_model
            ),
            "units": {
                "block_0": {
                    "pre_norm": _stack_norm(cfg, eu, cfg.d_model, dtype),
                    "attn": _attn_params(next(keys), cfg, eu, dtype),
                    "post_norm": _stack_norm(cfg, eu, cfg.d_model, dtype),
                    "mlp": _mlp_params(next(keys), cfg, eu, cfg.d_ff, dtype),
                }
            },
            "final_norm": _norm_params(cfg, cfg.d_model, dtype),
        }
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct tree — dry-run path, no allocation."""
    return jax.eval_shape(
        partial(init_params, cfg=cfg), jax.random.key(0)
    )


# parameters whose numerics require float32 regardless of compute dtype
_KEEP_F32 = {"router", "A_log", "D", "dt_bias"}


def cast_params(params, cfg: ModelConfig):
    """Mixed precision: bf16 compute copy of the float params (router and
    SSM dynamics stay f32).  The f32 master copy is what the optimizer
    updates; this cast happens once per step."""
    if cfg.compute_dtype != "bfloat16":
        return params
    import jax.tree_util as jtu

    def f(path, x):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if x.dtype == jnp.float32 and key not in _KEEP_F32:
            return x.astype(jnp.bfloat16)
        return x

    return jtu.tree_map_with_path(f, params)
