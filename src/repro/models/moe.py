"""Mixture-of-Experts MLP: top-k routing, shared experts, EP-shardable.

GShard-style GROUPED sort-based dispatch: tokens split into ``n_groups``
groups (one per data shard in production — the group dim shards over DP),
each group routes its tokens into per-(group, expert) capacity slots via a
sorted run-rank.  Expert buffers are (G@dp, E@tp, C, d):

* group-local gathers/scatters never cross data shards,
* the (G, E) exchange is the canonical EP all-to-all,
* per-device expert compute is the group's slice of the expert load —
  without the group dim every data shard recomputes the expert's FULL
  global token load (a measured 7x compute inflation), and without
  group-local capacity the combine gathers all-gather the global expert
  buffers (a measured 3x collective inflation).

Capacity (and overflow drops) are per (group, expert) — GShard semantics.
Load-balancing aux loss follows Switch: E * sum_e f_e * P_e.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.hints import hint


def _run_rank(sorted_ids: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element within its run of equal (sorted) ids."""
    n = sorted_ids.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    new_run = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    )
    run_start = jnp.where(new_run, idx, 0)
    start = jax.lax.associative_scan(jnp.maximum, run_start)
    return idx - start


def moe_mlp(
    x: jnp.ndarray,  # (b, s, d)
    params: dict,
    n_experts: int,  # true expert count (router width)
    top_k: int,
    capacity_factor: float,
    mlp_kind: str,
    n_groups: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss).  Expert weights in params:

    we_i : (E_pad, d, 2, f) swiglu  |  (E_pad, d, f) otherwise
    we_o : (E_pad, f, d)
    router: (d, E_pad)
    [shared_wi / shared_wo: always-on shared-expert MLP (qwen2-moe)]
    """
    b, s, d = x.shape
    e_pad = params["we_o"].shape[0]
    n_tok = b * s
    if n_tok % n_groups:
        n_groups = 1
    tg = n_tok // n_groups
    g = n_groups
    xg = hint(x.reshape(g, tg, d), "dp", None, None)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    if e_pad > n_experts:
        pad_mask = jnp.arange(e_pad) >= n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)  # (g, tg, E)

    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (g, tg, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    capacity = max(int(capacity_factor * top_k * tg / e_pad), 1)

    def route(gate_idx_g):
        """One group's slot assignment: (tg, k) -> tables."""
        flat_e = gate_idx_g.reshape(-1).astype(jnp.int32)  # (tg*k,)
        flat_tok = jnp.arange(tg * top_k, dtype=jnp.int32) // top_k
        order = jnp.argsort(flat_e, stable=True)
        rank_sorted = _run_rank(flat_e[order])
        rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
        keep = rank < capacity
        pos = jnp.minimum(rank, capacity - 1).astype(jnp.int32)
        slot_e = jnp.where(keep, flat_e, e_pad)
        token_of_slot = jnp.full((e_pad + 1, capacity), tg, jnp.int32)
        token_of_slot = token_of_slot.at[slot_e, pos].set(flat_tok, mode="drop")
        return (
            token_of_slot[:e_pad],
            pos.reshape(tg, top_k),
            keep.reshape(tg, top_k),
            slot_e,
        )

    token_of_slot, pos, keep, slot_e = jax.vmap(route)(gate_idx)

    # group-local gather into expert buffers (empty slot -> 0 row)
    xg_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    expert_in = jax.vmap(lambda xp, idx: xp[idx])(xg_pad, token_of_slot)
    expert_in = hint(expert_in, "dp", "tp", None, None)  # (g, E, C, d)

    if mlp_kind == "swiglu":
        gate_up = jnp.einsum("gecd,edtf->gectf", expert_in, params["we_i"])
        h = jax.nn.silu(gate_up[..., 0, :]) * gate_up[..., 1, :]
    else:
        h = jnp.einsum("gecd,edf->gecf", expert_in, params["we_i"])
        h = jax.nn.gelu(h)
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["we_o"])
    expert_out = hint(expert_out, "dp", "tp", None, None)  # (g, E, C, d)

    # combine: group-local gather of each (token, k) slot's output.  The
    # gate multiply stays in compute dtype (an f32 upcast here drags the
    # whole expert backward chain to f32 — 2x activation memory).
    out_k = jax.vmap(lambda eo, e, p: eo[e, p])(expert_out, gate_idx, pos)
    w = (gate_vals * keep).astype(out_k.dtype)  # (g, tg, k)
    out = jnp.einsum("gtkd,gtk->gtd", out_k, w).astype(x.dtype)
    out = out.reshape(n_tok, d)

    if "shared_wi" in params:
        from repro.models.layers import mlp as dense_mlp

        out = out + dense_mlp(
            x.reshape(n_tok, d),
            {"wi": params["shared_wi"], "wo": params["shared_wo"]},
            mlp_kind,
        )

    # Switch aux loss over the true experts (scatter-add counts — never
    # materialize a (t, k, E) one-hot)
    counts = jax.vmap(
        lambda se: jnp.zeros((e_pad + 1,), jnp.float32).at[se].add(1.0)
    )(slot_e).sum(axis=0)
    f = counts[:e_pad] / n_tok
    p = jnp.mean(probs, axis=(0, 1))
    aux = n_experts * jnp.sum(f[:n_experts] * p[:n_experts])
    return out.reshape(b, s, d), aux
