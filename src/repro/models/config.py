"""Model configuration for the architecture zoo.

A single flexible decoder (+ optional encoder) transformer family covers all
10 assigned architectures through a **pattern-unit** description: the layer
stack is ``n_units`` repeats of a short heterogeneous unit (e.g. jamba's
1 attention + 7 mamba, gemma3's 5 local + 1 global).  Uniform stacks are the
1-block unit special case.  Units scan with stacked parameters so the HLO
stays one-unit sized regardless of depth.

TP-degree canonicalization (DESIGN.md §4): KV heads and vocab are padded so
every sharded dim divides the model axis; the pad amounts are recorded on the
config for the roofline's useful-FLOPs accounting.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared (always-on) experts
    every: int = 1  # MoE replaces the MLP every ``every`` blocks
    capacity_factor: float = 1.25
    n_experts_padded: int = 0  # set by canonicalize (EP divisibility)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One position inside the pattern unit."""

    mixer: str = "attn"  # 'attn' | 'mamba'
    attn_type: str = "global"  # 'global' | 'local'
    moe: bool = False  # MoE MLP at this position?


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    mlp: str = "swiglu"  # 'swiglu' | 'sq_relu' | 'gelu'
    norm: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    rope: str = "standard"  # 'standard' | 'partial' | 'none' (learned abs pos)
    rope_theta: float = 10_000.0
    partial_rotary: float = 0.5  # used when rope == 'partial' (chatglm 2d rope)
    qk_norm: bool = False
    window: int = 4096  # sliding window for 'local' attention blocks
    pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 0
    # modality frontend stubs
    frontend: str = "none"  # 'none' | 'audio' | 'vision'
    vis_tokens: int = 0  # vision prefix length (internvl)
    max_seq: int = 32_768  # learned-pos table size when rope == 'none'
    tie_embeddings: bool = True
    param_dtype: str = "float32"  # 'float32' | 'bfloat16'
    compute_dtype: str = "bfloat16"
    remat: bool = True
    kv_quant: bool = False  # int8 KV cache (per-head-token scales)
    moe_groups: int = 1  # GShard dispatch groups (set to the DP degree)
    # training-memory knobs (per-shape overrides live in input shapes)
    optimizer: str = "adamw"  # 'adamw' | 'adafactor'
    # --- canonicalization records (filled by canonicalize) ---
    n_kv_heads_padded: int = 0
    n_heads_padded: int = 0
    vocab_padded: int = 0

    # ------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_units(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers {self.n_layers} not a multiple of the "
            f"pattern length {len(self.pattern)}"
        )
        return self.n_layers // len(self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid, or all-local+KV-linear-global
        decode (gemma3's 5:1 — decode-time attention is KV-linear)."""
        mixers = {b.mixer for b in self.pattern}
        if "mamba" in mixers:
            return True
        local = sum(b.attn_type == "local" for b in self.pattern)
        return local > 0 and local >= len(self.pattern) - 1

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper via its decoder)

    def canonicalize(self, tp: int) -> "ModelConfig":
        """Pad heads / KV heads / vocab / experts to the TP degree (recorded).

        Padded q heads get zero output-projection rows (harmless replicas);
        padded KV heads are replicas that multiply the cache; both pads are
        charged against the roofline's useful-FLOPs ratio."""
        hp = self.n_heads
        if hp % tp:
            hp = math.ceil(hp / tp) * tp
        kvp = self.n_kv_heads
        if kvp < tp:
            kvp = tp  # replicate-pad KV heads up to the TP degree
        elif kvp % tp:
            kvp = math.ceil(kvp / tp) * tp
        vp = math.ceil(self.vocab_size / (tp * 128)) * (tp * 128)
        moe = self.moe
        if moe is not None:
            ep = math.ceil(moe.n_experts / tp) * tp
            moe = dataclasses.replace(moe, n_experts_padded=ep)
        return dataclasses.replace(
            self, n_heads_padded=hp, n_kv_heads_padded=kvp, vocab_padded=vp, moe=moe
        )

    # ------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Exact parameter count of the constructed model (unpadded dims)."""
        d, hd = self.d_model, self.hd
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        if self.rope == "none":
            total += self.max_seq * d
        for blk in self.pattern:
            unit = 0
            if blk.mixer == "attn":
                unit += d * self.n_heads * hd  # wq
                unit += 2 * d * self.n_kv_heads * hd  # wk, wv
                unit += self.n_heads * hd * d  # wo
            else:
                ssm = self.ssm or SSMConfig()
                d_in = ssm.expand * d
                dt_rank = ssm.dt_rank or -(-d // 16)
                unit += d * 2 * d_in  # in_proj
                unit += d_in * ssm.d_conv  # conv
                unit += d_in * (dt_rank + 2 * ssm.d_state)  # x_proj
                unit += dt_rank * d_in  # dt_proj
                unit += d_in * ssm.d_state + d_in  # A, D
                unit += d_in * d  # out_proj
            if blk.moe and self.moe is not None:
                m = self.moe
                mult = 3 if self.mlp == "swiglu" else 2
                unit += m.n_experts * mult * d * m.d_ff_expert
                unit += m.n_shared * mult * d * m.d_ff_expert
                unit += d * m.n_experts  # router
            else:
                mult = 3 if self.mlp == "swiglu" else 2
                unit += mult * d * self.d_ff
            unit += 2 * d  # norms
            total += unit * self.n_units
        total += d  # final norm
        if self.enc_dec:
            enc_unit = 4 * d * d + (3 if self.mlp == "swiglu" else 2) * d * self.d_ff + 2 * d
            # cross attention per decoder layer
            total += self.n_layers * (4 * d * d + d)
            total += self.enc_layers * enc_unit + d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        mult = 3 if self.mlp == "swiglu" else 2
        moe_positions = sum(1 for b in self.pattern if b.moe) * self.n_units
        all_e = m.n_experts * mult * self.d_model * m.d_ff_expert
        act_e = (m.top_k + m.n_shared) * mult * self.d_model * m.d_ff_expert
        return self.param_count() - moe_positions * (all_e - (act_e - m.n_shared * mult * self.d_model * m.d_ff_expert) - m.n_shared * mult * self.d_model * m.d_ff_expert) if False else (
            self.param_count() - moe_positions * (all_e - act_e)
        )
