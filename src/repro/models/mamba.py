"""Mamba-1 selective SSM, TPU-adapted (chunked scan), plus O(1) decode.

State recurrence (per channel c of d_in, per state n of N):

    h_t = exp(dt_t * A[c,n]) * h_{t-1} + dt_t * B_t[n] * x_t[c]
    y_t[c] = sum_n C_t[n] * h_t[c,n] + D[c] * x_t[c]

TPU adaptation (DESIGN.md §5): the canonical CUDA kernel fuses the sequential
scan in shared memory.  We instead use a **chunked log-space formulation**:
the sequence is split into chunks of length ``chunk``; within a chunk the
contribution of every j <= t is computed in closed form from cumulative sums
of ``dt*A`` (log-decay), and the chunk boundary state is carried through a
``lax.scan``.  Working set per chunk is (b, chunk, d_in, N) — chosen to fit
VMEM-scale tiles — and the scan body is rematerialized in the backward pass,
so only the (b, d_in, N) boundary states persist.  d_in is sharded over the
model axis (all per-channel ops are elementwise in d_in).

Decode is the plain O(1) recurrence over a carried (b, d_in, N) state.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class MambaState(NamedTuple):
    h: jnp.ndarray  # (b, d_in, N) float32
    conv: jnp.ndarray  # (b, d_conv - 1, d_in) rolling conv window


def _ssm_params(x, params, dt_rank: int, n_state: int):
    """Project x -> (dt, B, C); x: (b, s, d_in)."""
    proj = jnp.einsum("bsc,cp->bsp", x, params["x_proj"])  # (b, s, r + 2N)
    dt = proj[..., :dt_rank]
    B = proj[..., dt_rank : dt_rank + n_state]
    C = proj[..., dt_rank + n_state :]
    dt = jnp.einsum("bsr,rc->bsc", dt, params["dt_proj"]) + params["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))  # (b, s, d_in)
    return dt, B.astype(jnp.float32), C.astype(jnp.float32)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, prefix: jnp.ndarray | None):
    """Depthwise causal conv1d.  x: (b, s, c); w: (c, k)."""
    k = w.shape[1]
    if prefix is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = prefix.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    return sum(
        xp[:, i : i + x.shape[1], :] * w[:, i][None, None, :] for i in range(k)
    )


def mamba_mixer(
    x: jnp.ndarray,  # (b, s, d_model)
    params: dict,
    n_state: int,
    d_conv: int,
    chunk: int = 128,
) -> jnp.ndarray:
    """Full-sequence mixer (training / prefill)."""
    from repro.dist.hints import hint

    b, s, _ = x.shape
    xz = jnp.einsum("bsd,dtc->bstc", x, params["in_proj"])  # (b, s, 2, d_in)
    xz = hint(xz, "dp", None, None, "tp")  # d_in channel-parallel over TP
    xin, z = xz[..., 0, :], xz[..., 1, :]
    xin = _causal_conv(xin, params["conv_w"], None) + params["conv_b"]
    xin = jax.nn.silu(xin)
    xin = hint(xin, "dp", None, "tp")

    dt_rank = params["dt_proj"].shape[0]
    dt, B, C = _ssm_params(xin, params, dt_rank, n_state)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (d_in, N), negative

    d_in = xin.shape[-1]
    ch = min(chunk, s)
    n_chunks = -(-s // ch)
    s_pad = n_chunks * ch
    if s_pad != s:
        # pad with dt=0 steps: decay exp(0)=1, input contribution 0 — the
        # state passes through unchanged and padded outputs are dropped.
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        xin = jnp.pad(xin, pad)
        dt = jnp.pad(dt, pad)
        B = jnp.pad(B, pad)
        C = jnp.pad(C, pad)

    xf = xin.astype(jnp.float32)
    # per-chunk views: (b, n_chunks, ch, ...)
    xs = hint(xf.reshape(b, n_chunks, ch, d_in), "dp", None, None, "tp")
    dts = hint(dt.reshape(b, n_chunks, ch, d_in), "dp", None, None, "tp")
    Bs = B.reshape(b, n_chunks, ch, n_state)
    Cs = C.reshape(b, n_chunks, ch, n_state)

    def chunk_body(h, inputs):
        xc, dtc, Bc, Cc = inputs  # (b, ch, d_in), (b, ch, d_in), (b, ch, N) x2
        # element decays a_t = exp(dt_t * A) in (0, 1] and drives u_t; the
        # in-chunk recurrence h_t = a_t h_{t-1} + u_t runs as a log-depth
        # associative scan (numerically safe: only products of <=1 factors).
        a = jnp.exp(dtc[..., None] * A[None, None])  # (b, ch, d_in, N)
        u = (dtc * xc)[..., None] * Bc[..., None, :]  # (b, ch, d_in, N)

        def combine(left, right):
            a_l, u_l = left
            a_r, u_r = right
            return a_l * a_r, u_l * a_r + u_r

        aa, uu = jax.lax.associative_scan(combine, (a, u), axis=1)
        h_all = aa * h[:, None] + uu  # (b, ch, d_in, N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, Cc)
        return h_all[:, -1], y

    h0 = jnp.zeros((b, d_in, n_state), jnp.float32)
    scan_in = (
        xs.swapaxes(0, 1),
        dts.swapaxes(0, 1),
        Bs.swapaxes(0, 1),
        Cs.swapaxes(0, 1),
    )
    _, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, scan_in)
    y = ys.swapaxes(0, 1).reshape(b, s_pad, d_in)[:, :s]
    y = y + params["D"][None, None] * xf[:, :s]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bsc,cd->bsd", y, params["out_proj"])


def mamba_decode_step(
    x: jnp.ndarray,  # (b, 1, d_model)
    state: MambaState,
    params: dict,
    n_state: int,
    d_conv: int,
) -> Tuple[jnp.ndarray, MambaState]:
    """O(1) single-token step carrying (h, conv window)."""
    xz = jnp.einsum("bsd,dtc->bstc", x, params["in_proj"])
    xin, z = xz[..., 0, :], xz[..., 1, :]  # (b, 1, d_in)
    window = jnp.concatenate([state.conv.astype(xin.dtype), xin], axis=1)
    w = params["conv_w"]  # (d_in, k)
    conv_out = jnp.einsum("bkc,ck->bc", window, w)[:, None] + params["conv_b"]
    xin = jax.nn.silu(conv_out)  # (b, 1, d_in)

    dt_rank = params["dt_proj"].shape[0]
    dt, B, C = _ssm_params(xin, params, dt_rank, n_state)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt_, B_, C_ = dt[:, 0], B[:, 0], C[:, 0]  # (b, d_in), (b, N), (b, N)
    xf = xin[:, 0].astype(jnp.float32)
    decay = jnp.exp(dt_[..., None] * A[None])  # (b, d_in, N)
    h = decay * state.h + (dt_ * xf)[..., None] * B_[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C_) + params["D"][None] * xf
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsc,cd->bsd", y, params["out_proj"])
    new_conv = window[:, 1:].astype(state.conv.dtype)
    return out, MambaState(h=h, conv=new_conv)


def init_mamba_state(b: int, d_in: int, n_state: int, d_conv: int) -> MambaState:
    return MambaState(
        h=jnp.zeros((b, d_in, n_state), jnp.float32),
        conv=jnp.zeros((b, d_conv - 1, d_in), jnp.float32),
    )
