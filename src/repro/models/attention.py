"""GQA attention: training/prefill (flash kernel) and decode (KV cache).

Layouts
-------
activations     (b, s, d)
q/k/v heads     (b, s, h, hd)  — kernel path transposes to (b, h, s, hd)
KV cache        (b, S, kv, hd) — the SEQ dim is shardable over the model
                axis for long-context decode (flash-decoding style: XLA
                partial-reduces the softmax over the sharded S dim).

KV heads are padded to the canonicalized count (cfg.n_kv_heads_padded) so the
head dim always divides the TP degree; padding heads are exact replicas and
the output projection folds them back (wo only reads the true heads' rows
broadcast over the replication group — constructed at init).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.layers import apply_rope, rms_norm


class AttnTemps(NamedTuple):
    q: jnp.ndarray  # (b, s, hq, hd)
    k: jnp.ndarray  # (b, s, kvp, hd)
    v: jnp.ndarray  # (b, s, kvp, hd)


def qkv_project(
    x: jnp.ndarray,
    params: dict,
    positions: jnp.ndarray,
    rope: str,
    rope_theta: float,
    partial_rotary: float,
    qk_norm: bool,
) -> AttnTemps:
    from repro.dist.hints import hint

    q = hint(jnp.einsum("bsd,dhk->bshk", x, params["wq"]), "dp", None, "tp", None)
    k = hint(jnp.einsum("bsd,dhk->bshk", x, params["wk"]), "dp", None, "tp", None)
    v = hint(jnp.einsum("bsd,dhk->bshk", x, params["wv"]), "dp", None, "tp", None)
    if qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = _rope_heads(q, positions, rope_theta, rope, partial_rotary)
    k = _rope_heads(k, positions, rope_theta, rope, partial_rotary)
    return AttnTemps(q, k, v)


def _rope_heads(x, positions, theta, mode, partial):
    """x: (b, s, h, hd); positions: (b, s) or (s,)."""
    if mode in ("none", "nope"):
        return x
    xt = x.swapaxes(1, 2)  # (b, h, s, hd)
    pos = positions if positions.ndim == 2 else positions[None]
    out = apply_rope(xt, pos[:, None, :], theta, mode, partial)
    return out.swapaxes(1, 2)


def attend_full(
    t: AttnTemps,
    causal: bool,
    window: Optional[int],
    params: dict,
) -> jnp.ndarray:
    """Training / prefill attention over the whole sequence."""
    q = t.q.swapaxes(1, 2)  # (b, hq, s, hd)
    k = t.k.swapaxes(1, 2)
    v = t.v.swapaxes(1, 2)
    o = kops.flash_attention(q, k, v, causal=causal, window=window)
    o = o.swapaxes(1, 2)  # (b, s, hq, hd)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


def attend_cache(
    x_q: jnp.ndarray,  # (b, 1, hq, hd) — new-token query (post-rope)
    cache_k: jnp.ndarray,  # (b, S, kvp, hd)
    cache_v: jnp.ndarray,  # (b, S, kvp, hd)
    t_pos: jnp.ndarray,  # () int32 — number of valid cache positions
    window: Optional[int],
    params: dict,
    k_scale: Optional[jnp.ndarray] = None,  # (b, S, kvp) int8-cache scales
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """One-token decode over a (possibly seq-sharded) KV cache.

    Written as a plain masked stable-softmax over the full cache S so the
    SPMD partitioner turns the max/sum reductions into partial reductions +
    all-reduce when S is sharded (flash-decoding without a hand-rolled
    collective schedule).
    """
    b, _, hq, hd = x_q.shape
    S, kvp = cache_k.shape[1], cache_k.shape[2]
    # padded q heads beyond kv * group are zero-output heads (whisper's
    # MHA zero-padding) — they attend to nothing; restore them as zeros.
    group = max(hq // kvp, 1)
    used_q = kvp * group
    x_q = x_q[:, :, :used_q]
    scale = 1.0 / (hd ** 0.5)
    q = x_q[:, 0].reshape(b, kvp, group, hd)  # (b, kvp, g, hd)
    kf = cache_k.astype(jnp.float32)
    if k_scale is not None:  # int8 cache: dequant fuses into the dot
        kf = kf * k_scale.astype(jnp.float32)[..., None]
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", q.astype(jnp.float32), kf
    ) * scale
    k_pos = jnp.arange(S, dtype=jnp.int32)
    mask = k_pos[None, None, None, :] < t_pos
    if window is not None:
        mask = mask & (k_pos[None, None, None, :] > t_pos - 1 - window)
    logits = jnp.where(mask, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.maximum(m, -1e30)  # no-visible-key guard
    p = jnp.exp(logits - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    vf = cache_v.astype(jnp.float32)
    if v_scale is not None:
        vf = vf * v_scale.astype(jnp.float32)[..., None]
    o = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    o = o.reshape(b, 1, used_q, hd).astype(x_q.dtype)
    if used_q < hq:
        o = jnp.pad(o, ((0, 0), (0, 0), (0, hq - used_q), (0, 0)))
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


def attend_cross(
    x: jnp.ndarray,  # (b, s, d) decoder states
    enc_kv: Tuple[jnp.ndarray, jnp.ndarray],  # (b, se, h, hd) each
    params: dict,
) -> jnp.ndarray:
    """Encoder-decoder cross attention (whisper): non-causal over enc_kv."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"]).swapaxes(1, 2)
    k, v = enc_kv
    o = kops.flash_attention(
        q, k.swapaxes(1, 2), v.swapaxes(1, 2), causal=False
    ).swapaxes(1, 2)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


def slice_true_kv(k: jnp.ndarray, kv_true: int, mha: bool) -> jnp.ndarray:
    """Strip padding kv heads before caching.  k: (b, s, kvp, hd).

    MHA zero-padding -> the first kv_true heads are the real ones;
    GQA replicate-padding (consecutive repeats) -> every r-th head.
    """
    kvp = k.shape[2]
    if kvp == kv_true:
        return k
    if mha:
        return k[:, :, :kv_true]
    r = kvp // kv_true
    return k[:, :, ::r]


def update_cache(
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    new_k: jnp.ndarray,  # (b, 1, kvp, hd)
    new_v: jnp.ndarray,
    t_pos: jnp.ndarray,  # () int32
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    ck = jax.lax.dynamic_update_slice(
        cache_k, new_k.astype(cache_k.dtype), (0, t_pos, 0, 0)
    )
    cv = jax.lax.dynamic_update_slice(
        cache_v, new_v.astype(cache_v.dtype), (0, t_pos, 0, 0)
    )
    return ck, cv


# ------------------------------------------------------------ int8 KV cache
def quantize_kv(k: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(token, head) symmetric int8 quantization.  k: (b, s, kv, hd).

    Returns (int8 values, bf16 scales (b, s, kv)).  Halves decode HBM
    traffic vs bf16; the dequant multiply fuses into the attention dots.
    """
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(
        jnp.round(k.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
