"""Parameter / batch / cache PartitionSpec derivation.

``_PARAM_RULES`` maps a parameter's leaf name (or ``parent/name`` when the
bare name is ambiguous, e.g. attention vs MLP ``wo``) to a per-dim rule
tuple over ``{None, "fsdp", "tp"}``:

    "tp"    shard over the tensor-parallel ``model`` axis
    "fsdp"  shard over the data-parallel axes (only when ``fsdp=True``)
    None    replicate

Rules are written for the *stacked* (max-rank) form of each parameter —
leading unit dim U first.  Lower-rank variants of the same name (the
unstacked final-norm ``scale``, non-swiglu ``wi`` without the gate dim)
drop interior entries: alignment keeps the outer halves of the rule and
removes from the middle, which is exactly where the optional broadcast
dims sit.  Specs always come back full-length (len == ndim) because the
optimizer-state derivation in launch/dryrun.py slices them positionally.

Any rule axis that does not divide the dim evenly is dropped to None —
specs are advice to GSPMD, never a crash.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.hints import TP_AXIS, dp_axes  # noqa: F401 — re-exported

# name (or parent/name) -> per-dim rule for the stacked parameter layout of
# models/params.py.  Covered shapes noted inline; U = pattern-unit stack.
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embedding / unembedding: (vocab, d) — vocab over TP (matches the
    # tp-sharded logits hint in models/layers.py), d over FSDP
    "embed": ("tp", "fsdp"),
    "lm_head": ("tp", "fsdp"),
    "pos_embed": (None, "fsdp"),  # (max_seq, d)
    # norms: tiny, replicated.  (U, d) stacked / (d,) final
    "scale": (None, None),
    "bias": (None, None),
    "q_norm": (None, None),  # (U, hd)
    "k_norm": (None, None),
    # attention: qkv (U, d, heads, hd) head-sharded over TP, d over FSDP;
    # output proj (U, heads, hd, d) contracts the TP-sharded head dim
    "wq": (None, "fsdp", "tp", None),
    "wk": (None, "fsdp", "tp", None),
    "wv": (None, "fsdp", "tp", None),
    "attn/wo": (None, "tp", None, "fsdp"),
    "cross/wo": (None, "tp", None, "fsdp"),
    # dense mlp: wi (U, d, 2, ff) swiglu / (U, d, ff); wo (U, ff, d)
    "mlp/wi": (None, "fsdp", None, "tp"),
    "mlp/wo": (None, "tp", "fsdp"),
    # MoE: experts over TP (expert parallelism shares the model axis — the
    # moe_mlp hint shards expert_in (g, E, C, d) as ("dp", "tp", ...)),
    # shared expert like a dense mlp.  we_i (U, E, d, 2, f) / (U, E, d, f)
    "we_i": (None, "tp", "fsdp", None, None),
    "we_o": (None, "tp", None, "fsdp"),  # (U, E, f, d)
    "router": (None, None, None),  # (U, d, E) f32, tiny
    "shared_wi": (None, "fsdp", None, "tp"),
    "shared_wo": (None, "tp", "fsdp"),
    # Mamba: channel (d_in) dim over TP, mirroring the mamba_mixer hints
    "in_proj": (None, "fsdp", None, "tp"),  # (U, d, 2, d_in)
    "conv_w": (None, "tp", None),  # (U, d_in, d_conv)
    "conv_b": (None, "tp"),
    "x_proj": (None, "tp", None),  # (U, d_in, r + 2n)
    "dt_proj": (None, None, "tp"),  # (U, r, d_in)
    "dt_bias": (None, "tp"),
    "A_log": (None, "tp", None),  # (U, d_in, n) f32
    "D": (None, "tp"),
    "out_proj": (None, "tp", "fsdp"),  # (U, d_in, d)
}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        out.append(k.key if hasattr(k, "key") else str(k))
    return tuple(out)


def rule_for(path) -> Optional[Tuple[Optional[str], ...]]:
    """Resolve the rule for a param path (tuple of str keys), most specific
    key first: ``parent/name`` then bare ``name``.  None if unmatched."""
    names = _path_names(path)
    if len(names) >= 2:
        qualified = f"{names[-2]}/{names[-1]}"
        if qualified in _PARAM_RULES:
            return _PARAM_RULES[qualified]
    return _PARAM_RULES.get(names[-1])


def _align(rule: Tuple, rank: int) -> Tuple:
    """Fit a rule to a param rank.  Shorter params drop the rule's interior
    entries (optional broadcast dims); extra leading dims replicate."""
    rule = tuple(rule)
    if len(rule) == rank:
        return rule
    if len(rule) < rank:
        return (None,) * (rank - len(rule)) + rule
    head, tail = (rank + 1) // 2, rank // 2
    return rule[:head] + (rule[len(rule) - tail:] if tail else ())


def _axis_entry(axes: Tuple[str, ...], mesh, dim: int):
    """PartitionSpec entry for sharding ``dim`` over ``axes`` (with even-
    divisibility fallback: full axis set, then the innermost axis alone)."""
    for cand in (axes, axes[-1:]):
        if not cand:
            continue
        if dim % int(np.prod([mesh.shape[a] for a in cand])) == 0:
            return cand if len(cand) > 1 else cand[0]
    return None


def _spec_for(x, rule, mesh, fsdp: bool) -> P:
    entries = []
    for i, r in enumerate(_align(rule, x.ndim)):
        if r == "tp" and TP_AXIS in mesh.axis_names and mesh.shape[TP_AXIS] > 1:
            entries.append(_axis_entry((TP_AXIS,), mesh, x.shape[i]))
        elif r == "fsdp" and fsdp and dp_axes(mesh):
            entries.append(_axis_entry(dp_axes(mesh), mesh, x.shape[i]))
        else:
            entries.append(None)
    return P(*entries)


def param_specs(aparams, mesh, fsdp: bool = True):
    """PartitionSpec tree for a parameter tree (``_PARAM_RULES``-driven).

    Unmatched leaves raise — every param name must carry an explicit rule
    (tests assert coverage across all 10 architecture configs).
    """

    def leaf(path, x):
        rule = rule_for(path)
        if rule is None:
            raise KeyError(
                f"no _PARAM_RULES entry for param "
                f"{'/'.join(_path_names(path))} (shape {tuple(x.shape)})"
            )
        return _spec_for(x, rule, mesh, fsdp)

    return jax.tree_util.tree_map_with_path(leaf, aparams)


def param_specs_dp_only(aparams, mesh):
    """Pure-FSDP specs: no tensor-parallel dim; each weight fully sharded
    over ALL mesh axes on its largest evenly-divisible dim (the TP
    right-sizing experiment in launch/dryrun.py)."""
    all_axes = tuple(mesh.axis_names)
    total = int(np.prod([mesh.shape[a] for a in all_axes]))

    def leaf(x):
        entries = [None] * x.ndim
        dims = sorted(range(x.ndim), key=lambda i: -x.shape[i])
        for i in dims:
            if x.shape[i] % total == 0:
                entries[i] = all_axes if len(all_axes) > 1 else all_axes[0]
                break
        return P(*entries)

    return jax.tree.map(leaf, aparams)


def batch_specs(specs, mesh, all_axes: bool = False):
    """Batch inputs: dim 0 sharded over the DP axes (or every axis when
    ``all_axes`` — the dp-only experiment spreads batch over TP too)."""
    axes = tuple(mesh.axis_names) if all_axes else dp_axes(mesh)

    def leaf(x):
        if x.ndim == 0:
            return P()
        entries = [None] * x.ndim
        entries[0] = _axis_entry(axes, mesh, x.shape[0]) if axes else None
        return P(*entries)

    return jax.tree.map(leaf, specs)


# cache leaves: which dim (beyond batch) is TP-shardable, by name
_CACHE_TP_DIM = {
    "k": 3, "v": 3,            # (U, b, s, kv_heads, hd)
    "cross_k": 3, "cross_v": 3,
    "k_scale": 3, "v_scale": 3,  # (U, b, s, kv_heads)
    "h": 2,                    # (U, b, d_in, d_state)
    "conv": 3,                 # (U, b, d_conv-1, d_in)
}


def cache_specs(acache, mesh):
    """KV / SSM cache: batch (dim 1) over DP; heads / channels over TP when
    they divide evenly (true-kv-head counts often don't — then replicate)."""
    dp = dp_axes(mesh)

    def leaf(path, x):
        if x.ndim < 2:
            return P()  # step counter "t"
        entries = [None] * x.ndim
        entries[1] = _axis_entry(dp, mesh, x.shape[1]) if dp else None
        name = _path_names(path)[-1]
        tp_dim = _CACHE_TP_DIM.get(name)
        if (
            tp_dim is not None
            and tp_dim < x.ndim
            and TP_AXIS in mesh.axis_names
            and mesh.shape[TP_AXIS] > 1
        ):
            entries[tp_dim] = _axis_entry((TP_AXIS,), mesh, x.shape[tp_dim])
        return P(*entries)

    return jax.tree_util.tree_map_with_path(leaf, acache)


def shardings(specs, mesh):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
