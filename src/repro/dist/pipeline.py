"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

``pipeline_apply(fn, stage_params, x, mesh, stages)`` places stage ``s``'s
parameter slice on mesh coordinate ``s``, splits the batch into
microbatches, and runs the classic fill/steady/drain schedule: at step
``t`` stage ``s`` processes microbatch ``t - s``, shifting activations to
the next stage with ``ppermute`` between steps.  Stage functions must be
shape-preserving (activation in == activation out), which is the
transformer-block case this targets.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.4.35 re-export
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def pipeline_apply(
    fn: Callable,
    stage_params,
    x: jnp.ndarray,
    mesh,
    stages: Optional[int] = None,
    axis: str = "stage",
    n_micro: Optional[int] = None,
) -> jnp.ndarray:
    """Apply ``stages`` copies of ``fn`` sequentially, pipelined.

    ``stage_params`` is a pytree whose leaves carry a leading ``stages``
    dim (stage s uses slice s).  ``x`` is the global batch; ``n_micro``
    defaults to one microbatch per batch row.
    """
    stages = stages or mesh.shape[axis]
    n_micro = n_micro or x.shape[0]
    if x.shape[0] % n_micro:
        raise ValueError(f"batch {x.shape[0]} not divisible by {n_micro} microbatches")
    mb = x.shape[0] // n_micro
    perm = [(i, (i + 1) % stages) for i in range(stages)]

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    def run(p_local, xg):
        p = jax.tree.map(lambda a: a[0], p_local)  # drop the local stage dim
        s = jax.lax.axis_index(axis)
        is_first = s == 0
        is_last = s == stages - 1
        micro = xg.reshape((n_micro, mb) + xg.shape[1:])
        buf = jnp.zeros_like(micro[0])
        out = jnp.zeros_like(micro)
        for t in range(n_micro + stages - 1):
            # stage 0 injects microbatch t; later stages consume the
            # activation shifted in from stage s-1 last step
            state_in = jnp.where(is_first, micro[min(t, n_micro - 1)], buf)
            y = fn(p, state_in)
            m = t - (stages - 1)  # microbatch finishing at the last stage
            if 0 <= m < n_micro:
                out = out.at[m].set(jnp.where(is_last, y, 0.0))
            buf = jax.lax.ppermute(y, axis, perm)
        # only the last stage wrote non-zeros; psum replicates its result
        return jax.lax.psum(out.reshape(xg.shape), axis)

    return run(stage_params, x)
