"""Sharded violation detection over the key-routed shuffle (DESIGN.md §8).

The paper's general-DC detection is a partitioned theta-join over the
comparison space (§4.2): the O(n^2) pairwise matrix is split so each
partition scans independently.  Here the partitioning is the equality-atom
key: a violating pair (t1, t2) must satisfy every atom, so for any
equality atom ``t1.a == t2.a`` both rows agree on ``a`` — hash-routing
every row by its combined equality-key value (``shuffle_by_key``) puts all
of a row's potential partners on its own shard, and the existing
``dc_pairs`` role scans run locally per shard with no cross-shard pairs
lost.  The same argument shards FD detection by the lhs (groups live
whole on one shard), and — via a second routing pass keyed on the rhs —
the swapped P(lhs | rhs) grouping too.

Correctness invariants (enforced bit-exactly by tests/test_dist_detect.py):

* every row appears at most once in the routed layout, so the local scans'
  diagonal exclusion still means "never pair a row with itself";
* counts are sums and stats are min/max over a row's partner set, all of
  which lives on the row's shard — per-shard results equal the dense
  scan's row-for-row, not just in aggregate;
* rows outside both scopes are not routed at all; they get count 0 and the
  reduce identity, exactly as the dense scan gives them.

Skewed keys overflow the shuffle's per-shard capacity; the driver retries
with a doubled capacity factor until the overflow flag clears (a factor of
``n_shards`` provably cannot overflow, so the loop terminates).

``n_shards`` is a *logical* shard count: the routed leading dim.  When the
mesh has data-parallel axes whose extent divides it, the per-shard scans
run under ``shard_map`` (each device scans only its resident shards);
otherwise they run as a ``vmap`` over the logical shards on one device —
identical numerics, which is what lets the equivalence tests run on CPU.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.4.35 re-export
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.constraints import DC, FD, equality_key_attrs, flip_op
from repro.core.detect import DCDetectResult, FDDetectResult, _T1_REDUCE
from repro.core.relation import Relation
from repro.core.setops import group_distinct_candidates
from repro.obs.trace import NULL_TRACER
from repro.kernels import ops as kops
from repro.kernels.ref import _identity
from repro.dist.sharding import dp_axes
from repro.dist.shuffle import CAPACITY_FACTOR, shuffle_by_key


@dataclasses.dataclass
class ShardedDetectInfo:
    """What the routing actually did — consumed by launch/dryrun.py's
    pair-count report, the executor's cost model, and asserted on by the
    overflow-retry tests."""

    n_shards: int
    capacity_factor: float  # the factor that finally fit
    retries: int  # shuffles beyond the first
    routed_rows: int  # valid rows after routing
    per_shard_rows: List[int]  # routed row count per shard
    dense_pairs: int  # cap^2 — the dense scan's comparison space
    sharded_pairs: int  # sum_s rows_s^2 — what the shards scanned
    # distinct SOURCE ledger strips (DESIGN.md §11) each shard's routed rows
    # came from, when the caller passed its strip size: how a key-routed
    # shard's work maps back onto the work ledger's strip grid (the per-host
    # work partition the sharded service will consume).  None when the
    # caller did not report a strip size.
    per_shard_strips: Optional[List[int]] = None
    # launch geometry of the per-shard scans (DESIGN.md §15): the routed
    # layout compacts valid rows to a per-shard slot prefix, so each
    # shard's fused scan restricts to the occupied block range — tile
    # pairs over empty slack slots never launch.  DC path only (0 for FDs).
    tiles_launched: int = 0
    tiles_total: int = 0


def default_n_shards(mesh) -> int:
    """Logical shard count for a mesh: the data-parallel extent (1 when the
    mesh has no data axes to spread over)."""
    axes = dp_axes(mesh)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


# ----------------------------------------------------------------- routing
def _transport(col: jnp.ndarray) -> jnp.ndarray:
    """View a column as int32 for payload transport (bit-exact round trip)."""
    if col.dtype == jnp.int32:
        return col
    return jax.lax.bitcast_convert_type(col.astype(jnp.float32), jnp.int32)


def _untransport(col: jnp.ndarray, dtype) -> jnp.ndarray:
    if dtype == jnp.int32:
        return col
    return jax.lax.bitcast_convert_type(col, jnp.float32).astype(dtype)


def _combine_keys(cols: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Hash-combine key columns into one int32 routing key.

    Equal value tuples MUST produce equal keys (collisions merely co-locate
    unrelated keys, which costs capacity, never correctness) — so float
    columns collapse -0.0 onto +0.0 before the bit view.
    """
    h = None
    for c in cols:
        if c.dtype != jnp.int32:
            c = c.astype(jnp.float32)
            c = jnp.where(c == 0.0, jnp.float32(0.0), c)
            ci = jax.lax.bitcast_convert_type(c, jnp.int32)
        else:
            ci = c
        h = ci if h is None else (h * jnp.int32(1_000_003)) ^ ci
    return h


def _route(
    key: jnp.ndarray,  # (cap,) int32
    payload_cols: Sequence[jnp.ndarray],  # (cap,) each, int32-transported
    valid: jnp.ndarray,  # (cap,) bool
    mesh,
    n_shards: int,
    capacity_factor: float,
    tracer=None,
):
    """Shuffle rows by key with overflow-retry.  Returns (result, factor,
    retries) where ``result`` has leading dims (n_shards, cap_routed).
    ``tracer`` spans the whole routing (``dist.shuffle``) and marks each
    overflow retry with an instant (DESIGN.md §13)."""
    tracer = tracer if tracer is not None else NULL_TRACER
    cap = key.shape[0]
    n_local = -(-cap // n_shards)
    padded = n_shards * n_local
    # factor >= 1 keeps the routed slot space at least ``padded`` wide, so
    # _unroute's scatter target covers every source index (and the empty-
    # slot sentinel ``padded`` stays filtered by the valid mask, never OOB
    # into a smaller buffer).
    capacity_factor = max(capacity_factor, 1.0)

    def shard_view(x, fill=0):
        return jnp.pad(x, [(0, padded - cap)] + [(0, 0)] * (x.ndim - 1),
                       constant_values=fill).reshape((n_shards, n_local) + x.shape[1:])

    keys2 = shard_view(key)
    payload2 = shard_view(jnp.stack(payload_cols, axis=-1))
    valid2 = shard_view(valid, fill=False)

    factor, retries = capacity_factor, 0
    with tracer.span("dist.shuffle", n_shards=n_shards, rows=int(cap)) as sp:
        while True:
            res = shuffle_by_key(
                keys2, payload2, valid2, mesh, capacity_factor=factor
            )
            if not bool(np.asarray(res.overflow)) or factor >= n_shards:
                sp.set(retries=retries, capacity_factor=float(factor))
                return res, factor, retries
            factor = min(factor * 2.0, float(n_shards))
            retries += 1
            tracer.instant(
                "dist.shuffle_overflow_retry", capacity_factor=float(factor)
            )


@functools.lru_cache(maxsize=None)
def _per_shard_fn(fn, mesh, n_shards: int):
    """Jitted runner for ``fn`` (one logical shard -> pytree of per-row
    outputs) over the leading shard dim: ``shard_map`` over the data axes
    when they divide ``n_shards`` (each device vmaps its resident shards),
    plain ``vmap`` otherwise.

    Cached so repeated detect calls (the executor's incremental steps)
    reuse one jit cache instead of retracing — ``fn`` must come from a
    cached builder (``_dc_local_scan`` / ``_fd_local_group``) so its
    identity is stable across calls."""
    batched = jax.vmap(fn)
    axes = dp_axes(mesh)
    extent = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if extent > 1 and n_shards % extent == 0:
        spec = P(axes if len(axes) > 1 else axes[0])
        sharded = _shard_map(
            batched,
            mesh=mesh,
            # one positional argument; the bare spec is a pytree prefix
            # applying to every leaf of the args pytree
            in_specs=(spec,),
            out_specs=spec,
            check_rep=False,
        )
        return jax.jit(sharded)
    return jax.jit(batched)


def _per_shard(fn, mesh, n_shards: int, args, tracer=None, span_attrs=None):
    tracer = tracer if tracer is not None else NULL_TRACER
    with tracer.span(
        "dist.shard_scan", n_shards=n_shards, **(span_attrs or {})
    ), mesh:
        return _per_shard_fn(fn, mesh, n_shards)(args)


def _unroute(routed: jnp.ndarray, src: jnp.ndarray, valid: jnp.ndarray,
             cap: int, init):
    """Scatter per-slot results back to original row order.  ``init`` fills
    rows that were never routed (the dense scan's value for them)."""
    flat = routed.reshape((-1,) + routed.shape[2:])
    idx = jnp.where(valid.reshape(-1), src.reshape(-1), src.size)
    out = jnp.full((src.size,) + flat.shape[1:], init, flat.dtype)
    return out.at[idx].set(flat, mode="drop")[:cap]


def _info(res, n_shards, factor, retries, cap,
          strip_rows: Optional[int] = None) -> ShardedDetectInfo:
    per_shard = np.asarray(jnp.sum(res.valid.astype(jnp.int32), axis=1))
    per_shard_strips = None
    if strip_rows:
        # distinct source strips per shard: the routed slots' original row
        # indices (res.src), bucketed by the caller's ledger strip grid
        src = np.asarray(res.src)
        valid = np.asarray(res.valid)
        per_shard_strips = [
            len(np.unique(src[s][valid[s]] // int(strip_rows)))
            for s in range(src.shape[0])
        ]
    return ShardedDetectInfo(
        n_shards=n_shards,
        capacity_factor=factor,
        retries=retries,
        routed_rows=int(per_shard.sum()),
        per_shard_rows=[int(c) for c in per_shard],
        dense_pairs=int(cap) ** 2,
        sharded_pairs=int((per_shard.astype(np.int64) ** 2).sum()),
        per_shard_strips=per_shard_strips,
    )


# ---------------------------------------------------------------- DC path
@functools.lru_cache(maxsize=None)
def _dc_local_scan(ops: Tuple[str, ...], flipped: Tuple[str, ...],
                   t1_red: Tuple[str, ...], t2_red: Tuple[str, ...],
                   block: int, hi: int):
    """One logical shard's FUSED both-role scan (DESIGN.md §15); cached so
    its identity (and thus the jit cache in ``_per_shard_fn``) is stable
    across calls.  ``hi`` is the occupied block range of the routed slot
    prefix — the shuffle compacts valid rows to slots ``[0, count_s)``, so
    restricting every shard to blocks ``[0, hi)`` (``hi`` from the MAX
    occupancy, a static host value under vmap/shard_map) launches no tile
    pair over pure capacity slack while staying bit-identical."""

    def local_scan(args):
        lc, rc, lrs, lcs = args
        res = kops.dc_pair_scan(
            lc, rc, ops, flipped, lrs, lcs, t1_red, t2_red, block=block,
            row_blocks=(0, hi), col_blocks=(0, hi),
        )
        return (res.t1_count, res.t2_count, res.t1_stat, res.t2_stat)

    return local_scan


def detect_dc_sharded_info(
    rel: Relation,
    dc: DC,
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    mesh,
    n_shards: Optional[int] = None,
    block: int = 256,
    capacity_factor: float = CAPACITY_FACTOR,
    strip_rows: Optional[int] = None,
    tracer=None,
) -> Tuple[DCDetectResult, ShardedDetectInfo]:
    """Sharded ``detect_dc``: bit-identical to the dense scan for DCs with
    at least one same-attribute equality atom.  Also returns routing info
    (``strip_rows`` adds the per-shard source-strip coverage report,
    DESIGN.md §11)."""
    key_attrs = equality_key_attrs(dc)
    if not key_attrs:
        raise ValueError(
            f"DC {dc.name!r} has no same-attribute equality atom — "
            "sharded detection cannot route it; use the dense detect_dc"
        )
    n_shards = n_shards or default_n_shards(mesh)
    if n_shards < 2:
        raise ValueError("n_shards must be >= 2 (use detect_dc on one shard)")

    cap = rel.capacity
    row_scope = row_scope & rel.valid
    col_scope = col_scope & rel.valid
    participate = row_scope | col_scope

    # payload: every atom column (deduped) + the two scope masks
    attrs: List[str] = []
    for a in dc.atoms:
        for name in (a.left, a.right):
            if name not in attrs:
                attrs.append(name)
    dtypes = {name: rel.columns[name].dtype for name in attrs}
    payload_cols = [_transport(rel.columns[name]) for name in attrs]
    payload_cols.append(row_scope.astype(jnp.int32))
    payload_cols.append(col_scope.astype(jnp.int32))

    key = _combine_keys([rel.columns[a] for a in key_attrs])
    res, factor, retries = _route(
        key, payload_cols, participate, mesh, n_shards, capacity_factor,
        tracer=tracer,
    )

    cols = {
        name: _untransport(res.payload[..., i], dtypes[name])
        for i, name in enumerate(attrs)
    }
    rs = (res.payload[..., -2] > 0) & res.valid
    cs = (res.payload[..., -1] > 0) & res.valid

    ops = tuple(a.op for a in dc.atoms)
    flipped = tuple(flip_op(op) for op in ops)
    t1_red = tuple(_T1_REDUCE[op] for op in ops)
    t2_red = tuple(_T1_REDUCE[op] for op in flipped)
    l_names = [a.left for a in dc.atoms]
    r_names = [a.right for a in dc.atoms]

    args = (
        tuple(cols[n] for n in l_names),
        tuple(cols[n] for n in r_names),
        rs,
        cs,
    )

    # Occupied block range of the routed slot prefix (DESIGN.md §15): the
    # shuffle compacts each shard's valid rows to slots [0, count_s), so the
    # fused scan restricts to blocks [0, hi) with hi sized by the fullest
    # shard — a static host value, shared by all shards under vmap.
    cap_routed = int(res.valid.shape[-1])
    nb_local = max(-(-cap_routed // block), 1)
    occupancy = int(np.asarray(jnp.sum(res.valid.astype(jnp.int32), axis=1)).max())
    hi = min(nb_local, max(-(-occupancy // block), 1))
    tiles_launched = n_shards * hi * hi
    tiles_total = n_shards * nb_local * nb_local

    t1c, t2c, t1s, t2s = _per_shard(
        _dc_local_scan(ops, flipped, t1_red, t2_red, block, hi), mesh,
        n_shards, args, tracer=tracer,
        span_attrs={
            "tiles_launched": tiles_launched,
            "tiles_skipped": tiles_total - tiles_launched,
        },
    )

    t1_count = _unroute(t1c, res.src, res.valid, cap, jnp.int32(0))
    t2_count = _unroute(t2c, res.src, res.valid, cap, jnp.int32(0))
    t1_stat = tuple(
        _unroute(s, res.src, res.valid, cap, _identity(dtypes[n], red))
        for s, n, red in zip(t1s, r_names, t1_red)
    )
    t2_stat = tuple(
        _unroute(s, res.src, res.valid, cap, _identity(dtypes[n], red))
        for s, n, red in zip(t2s, l_names, t2_red)
    )
    per_tile = kops._tile_bytes(
        kops.distinct_columns(args[0], args[1])[0], args[0], args[1], block
    )
    det = DCDetectResult(
        t1_count, t2_count, t1_stat, t2_stat,
        tiles_launched=tiles_launched, tiles_total=tiles_total,
        bytes_moved=tiles_launched * per_tile,
    )
    info = _info(res, n_shards, factor, retries, cap, strip_rows=strip_rows)
    info.tiles_launched = tiles_launched
    info.tiles_total = tiles_total
    return det, info


def detect_dc_sharded(
    rel: Relation,
    dc: DC,
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    mesh,
    n_shards: Optional[int] = None,
    block: int = 256,
    capacity_factor: float = CAPACITY_FACTOR,
) -> DCDetectResult:
    det, _ = detect_dc_sharded_info(
        rel, dc, row_scope, col_scope, mesh,
        n_shards=n_shards, block=block, capacity_factor=capacity_factor,
    )
    return det


# ---------------------------------------------------------------- FD path
@functools.lru_cache(maxsize=None)
def _fd_local_group(k: int):
    def local(args):
        ks, v, m = args
        return group_distinct_candidates(ks, v, m, k)

    return local


def _grouped_candidates_sharded(
    key_cols: Sequence[jnp.ndarray],
    value_col: jnp.ndarray,
    scope: jnp.ndarray,
    k: int,
    mesh,
    n_shards: int,
    capacity_factor: float,
    strip_rows: Optional[int] = None,
    tracer=None,
):
    """Sharded ``group_distinct_candidates``: route rows by the group key so
    each group lives whole on one shard, group locally, un-route."""
    cap = value_col.shape[0]
    dtypes = [c.dtype for c in key_cols] + [value_col.dtype]
    payload = [_transport(c) for c in key_cols] + [_transport(value_col)]
    res, factor, retries = _route(
        _combine_keys(key_cols), payload, scope, mesh, n_shards,
        capacity_factor, tracer=tracer,
    )
    n_keys = len(key_cols)
    keys_r = [_untransport(res.payload[..., i], dtypes[i]) for i in range(n_keys)]
    value_r = _untransport(res.payload[..., n_keys], dtypes[n_keys])

    cand, count, violated, overflow = _per_shard(
        _fd_local_group(k), mesh, n_shards, (tuple(keys_r), value_r, res.valid),
        tracer=tracer,
    )
    return (
        _unroute(cand, res.src, res.valid, cap, jnp.zeros((), value_col.dtype)),
        _unroute(count, res.src, res.valid, cap, jnp.float32(0.0)),
        _unroute(violated, res.src, res.valid, cap, False),
        jnp.any(overflow),
        _info(res, n_shards, factor, retries, cap, strip_rows=strip_rows),
    )


def detect_fd_sharded_info(
    rel: Relation,
    fd: FD,
    scope: jnp.ndarray,
    mesh,
    k: Optional[int] = None,
    n_shards: Optional[int] = None,
    capacity_factor: float = CAPACITY_FACTOR,
    strip_rows: Optional[int] = None,
    tracer=None,
) -> Tuple[FDDetectResult, ShardedDetectInfo]:
    """Sharded ``detect_fd``: lhs groups route whole onto one shard; the
    swapped P(lhs | rhs) grouping (single-attribute lhs) uses a second
    routing pass keyed on the rhs.  Bit-identical to the dense path.
    ``strip_rows`` adds the per-shard strip-coverage report (§11)."""
    k = k or max(rel.k, 2)
    n_shards = n_shards or default_n_shards(mesh)
    if n_shards < 2:
        raise ValueError("n_shards must be >= 2 (use detect_fd on one shard)")
    scope = scope & rel.valid
    lhs_cols = [rel.columns[a] for a in fd.lhs]
    rhs_col = rel.columns[fd.rhs]

    rhs_cand, rhs_count, violated, overflow, info = _grouped_candidates_sharded(
        lhs_cols, rhs_col, scope, k, mesh, n_shards, capacity_factor,
        strip_rows=strip_rows, tracer=tracer,
    )
    lhs_cand = lhs_count = None
    if len(fd.lhs) == 1:
        lhs_cand, lhs_count, _, ovf2, _ = _grouped_candidates_sharded(
            [rhs_col], lhs_cols[0], scope, k, mesh, n_shards, capacity_factor,
            tracer=tracer,
        )
        overflow = overflow | ovf2
    det = FDDetectResult(violated, rhs_cand, rhs_count, lhs_cand, lhs_count, overflow)
    return det, info


def detect_fd_sharded(
    rel: Relation,
    fd: FD,
    scope: jnp.ndarray,
    mesh,
    k: Optional[int] = None,
    n_shards: Optional[int] = None,
    capacity_factor: float = CAPACITY_FACTOR,
) -> FDDetectResult:
    det, _ = detect_fd_sharded_info(
        rel, fd, scope, mesh, k=k, n_shards=n_shards,
        capacity_factor=capacity_factor,
    )
    return det


# ------------------------------------------------------------- reporting
def pair_count_report(n_rows: int, n_shards: int,
                      capacity_factor: float = CAPACITY_FACTOR) -> dict:
    """Capacity-planning arithmetic for the dry-run (DESIGN.md §8): dense
    vs sharded comparison-space size under uniform keys.  The sharded scan
    touches ``n_shards * (n_rows / n_shards)^2`` pairs — an ``n_shards``-x
    saving — at the cost of one all-to-all of the routed payload."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    per_shard = -(-n_rows // n_shards)
    dense = int(n_rows) ** 2
    sharded = n_shards * per_shard**2
    return {
        "n_rows": int(n_rows),
        "n_shards": int(n_shards),
        "dense_pairs": dense,
        "sharded_pairs_uniform": sharded,
        "pair_savings_x": (dense / sharded) if sharded else 1.0,
        "per_shard_capacity_rows": int(per_shard * capacity_factor),
    }
