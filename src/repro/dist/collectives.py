"""Compressed cross-replica collectives (DESIGN.md §6).

Gradient all-reduce with int8 quantization and error feedback: each data-
parallel rank quantizes (gradient + carried residual) to int8 with a single
per-tensor scale, all-reduces the dequantized value, and carries the
quantization error into the next step (1-bit-Adam / DGC style error
feedback, which keeps SGD convergence despite the lossy wire format).

The contract callers rely on (wired into train/steps.py behind the
``grad_compress`` flag):

* **quantization** is symmetric per-tensor int8: ``q = round(x / scale)``
  clipped to [-127, 127] with ``scale = amax / 127`` (``scale = 1`` for an
  all-zero tensor, so zeros round-trip exactly);
* **error feedback**: the value quantized is ``gradient + residual``; the
  new residual is ``(gradient + residual) - dequantize(q)``, a per-leaf
  f32 pytree the CALLER carries between steps (``opt_state["gerr"]`` in
  the training step — ``init_opt_state(grad_compress=True)`` allocates
  it).  Residuals are rank-local state and are never reduced;
* **reduction** is a mean over the data-parallel mesh axes of the
  dequantized value, so the result has gradient dtype and magnitude —
  drop-in for the uncompressed mean-reduce;
* **shapes/dtypes**: any pytree of real-valued leaves; residual leaves are
  f32 with the leaf's shape regardless of gradient dtype.

This is the reference form: inputs enter replicated, which pins the
numerics but means no int8 crosses the wire standalone — realizing the
bytes-on-wire saving needs ``per_rank`` fused inside a manual-DP
``shard_map`` of the step itself (see ``grad_allreduce_compressed``).
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.4.35 re-export
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.dist.sharding import dp_axes


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (q int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def grad_allreduce_compressed(grads, errors, mesh):
    """Mean-reduce a gradient pytree over the data-parallel axes with int8
    compression + error feedback.  ``errors`` is the residual pytree from
    the previous step (zeros at step 0).  Returns (reduced, new_errors).

    This is the reference form: inputs enter replicated (in_specs P()),
    which pins the numerics — quantize(grad + residual), pmean the
    dequantized value, carry the quantization error — but means no int8
    actually crosses the wire standalone.  Realizing the bytes-on-wire
    saving requires fusing ``per_rank`` inside the training step's own
    shard_map, where each DP rank still holds a distinct local gradient
    (the ROADMAP wiring step); the compression math and tests carry over
    unchanged.
    """
    axes = dp_axes(mesh)

    def per_rank(g, e):
        compensated = g.astype(jnp.float32) + e
        q, scale = quantize_int8(compensated)
        dq = dequantize_int8(q, scale)
        reduced = jax.lax.pmean(dq, axes) if axes else dq
        return reduced.astype(g.dtype), compensated - dq

    @partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=(P(), P()),
        check_rep=False,
    )
    def run(gs, es):
        pairs = jax.tree.map(per_rank, gs, es)
        red = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return red, err

    return run(grads, errors)
