"""Distribution layer: sharding hints and rules, compressed collectives,
key-routed shuffle, and pipeline parallelism.

Modules (kept import-light — model code imports ``hints`` at trace time):

    hints       ``hint(x, *axis_names)`` activation sharding constraints
    sharding    ``_PARAM_RULES`` / ``param_specs`` / ``batch_specs`` /
                ``cache_specs`` / ``shardings`` — the dry-run lowering grid
    collectives int8-compressed gradient all-reduce with error feedback
    shuffle     ``shuffle_by_key`` — hash-route rows so each key lives on
                exactly one shard (the substrate for sharded detect_dc)
    pipeline    ``pipeline_apply`` — GPipe over a "stage" mesh axis
"""
