"""Distribution layer: sharding hints and rules, compressed collectives,
key-routed shuffle, sharded detection, and pipeline parallelism
(DESIGN.md §6).

Modules:

    hints       ``hint(x, *axis_names)`` activation sharding constraints
    sharding    ``_PARAM_RULES`` / ``param_specs`` / ``batch_specs`` /
                ``cache_specs`` / ``shardings`` — the dry-run lowering grid
    collectives int8-compressed gradient all-reduce with error feedback,
                wired into train/steps.py behind ``grad_compress``; see
                that module's docstring for the wire contract (per-tensor
                symmetric scale, f32 residual carried by the caller in
                ``opt_state["gerr"]``, mean-reduce over the data-parallel
                axes)
    shuffle     ``shuffle_by_key`` — hash-route rows so each key lives on
                exactly one shard; returns the inverse permutation
                (``src``) and an overflow flag for skewed keys
    detect      ``detect_dc_sharded`` / ``detect_fd_sharded`` — violation
                detection over the routed layout, bit-identical to the
                dense scans in core/detect.py (DESIGN.md §8)
    pipeline    ``pipeline_apply`` — GPipe over a "stage" mesh axis

The package re-exports the sharded-detection surface below — in
particular ``ShardedDetectInfo``, the routing observation (per-shard row
counts, retry history) the executor feeds back into the cost model so the
full/partial decision and the background cleaner's priority model price
the shuffle path (DESIGN.md §10).  That import pulls jax; model code on
the trace path that only needs activation hints keeps importing
``repro.dist.hints`` directly (submodule imports stay cheap relative to
the jax import the model already paid).
"""

from repro.dist.detect import (
    ShardedDetectInfo,
    detect_dc_sharded,
    detect_fd_sharded,
    pair_count_report,
)

__all__ = [
    "ShardedDetectInfo",
    "detect_dc_sharded",
    "detect_fd_sharded",
    "pair_count_report",
]
