"""Distribution layer: sharding hints and rules, compressed collectives,
key-routed shuffle, sharded detection, and pipeline parallelism
(DESIGN.md §6).

Modules (kept import-light — model code imports ``hints`` at trace time):

    hints       ``hint(x, *axis_names)`` activation sharding constraints
    sharding    ``_PARAM_RULES`` / ``param_specs`` / ``batch_specs`` /
                ``cache_specs`` / ``shardings`` — the dry-run lowering grid
    collectives int8-compressed gradient all-reduce with error feedback;
                see that module's docstring for the wire contract (per-
                tensor symmetric scale, f32 residual carried by the caller,
                mean-reduce over the data-parallel axes)
    shuffle     ``shuffle_by_key`` — hash-route rows so each key lives on
                exactly one shard; returns the inverse permutation
                (``src``) and an overflow flag for skewed keys
    detect      ``detect_dc_sharded`` / ``detect_fd_sharded`` — violation
                detection over the routed layout, bit-identical to the
                dense scans in core/detect.py (DESIGN.md §8)
    pipeline    ``pipeline_apply`` — GPipe over a "stage" mesh axis
"""
