"""Key-routed shuffle: the distributed analogue of the paper's partition-
by-key comparison space (Daisy §4.2).

``shuffle_by_key`` hash-routes every valid row to shard ``key % n_shards``
so all rows sharing a key land on exactly one shard — after the shuffle, a
per-shard violation detector (detect_dc over equality atoms) sees every
conflicting pair locally, with no cross-shard comparisons.  Outputs carry a
2x capacity slack per shard plus an overflow flag for skewed key
distributions (the caller re-shuffles with a larger factor on overflow).

The routed layout is computed as one jit-compiled gather/scatter with the
leading (shard) dim placed on the mesh's data axis via ``out_shardings`` —
under GSPMD the cross-shard moves lower to all-to-all style collectives.
``shuffle_by_key_host`` is the pure-numpy reference with identical routing
and capacity semantics.

Every output slot carries its source row's flat index (``src``) — the
inverse permutation.  Consumers that compute per-row results in the routed
layout (dist/detect.py) scatter them back to the original row order with
``out[src[slot]] = result[slot]``; empty slots hold the out-of-bounds
sentinel ``n_shards * n`` and drop out of the scatter.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import dp_axes

CAPACITY_FACTOR = 2.0


class ShuffleResult(NamedTuple):
    """Routed layout: ``(n_shards, cap)`` leading dims, plus the inverse
    permutation ``src`` (flat source row index per slot; ``n_shards * n``
    for empty slots) and the scalar ``overflow`` flag."""

    keys: jnp.ndarray  # (n_shards, cap)
    payload: jnp.ndarray  # (n_shards, cap, ...)
    valid: jnp.ndarray  # (n_shards, cap) bool
    src: jnp.ndarray  # (n_shards, cap) int32 flat source index
    overflow: jnp.ndarray  # () bool


def _capacity(n_cols: int, capacity_factor: float) -> int:
    return max(int(n_cols * capacity_factor), 1)


def shuffle_by_key_host(
    keys: np.ndarray,
    payload: np.ndarray,
    valid: np.ndarray,
    n_shards: int,
    capacity_factor: float = CAPACITY_FACTOR,
):
    """Numpy reference: same routing (key % n_shards) and capacity."""
    keys = np.asarray(keys)
    payload = np.asarray(payload)
    valid = np.asarray(valid)
    n = keys.shape[1]
    total = keys.shape[0] * n
    cap = _capacity(n, capacity_factor)
    out_k = np.zeros((n_shards, cap), keys.dtype)
    out_p = np.zeros((n_shards, cap) + payload.shape[2:], payload.dtype)
    out_v = np.zeros((n_shards, cap), bool)
    out_src = np.full((n_shards, cap), total, np.int32)
    counts = np.zeros(n_shards, np.int64)
    overflow = False
    for s in range(keys.shape[0]):
        for i in range(n):
            if not valid[s, i]:
                continue
            d = int(keys[s, i]) % n_shards
            if counts[d] >= cap:
                overflow = True
                continue
            out_k[d, counts[d]] = keys[s, i]
            out_p[d, counts[d]] = payload[s, i]
            out_v[d, counts[d]] = True
            out_src[d, counts[d]] = s * n + i
            counts[d] += 1
    return ShuffleResult(out_k, out_p, out_v, out_src, overflow)


@functools.lru_cache(maxsize=None)
def _routed_fn(mesh, n_shards: int, n: int, cap: int):
    """Jitted shuffle for one (mesh, layout) — cached so the executor's
    repeated detect shuffles (and overflow retries at each factor) reuse
    one jit cache instead of retracing per call."""
    total = n_shards * n
    axes = dp_axes(mesh)
    row_spec = P(axes if len(axes) > 1 else (axes[0] if axes else None))

    def impl(keys, payload, valid):
        fk = keys.reshape(total)
        fv = valid.reshape(total)
        fp = payload.reshape((total,) + payload.shape[2:])
        # invalid rows park in a virtual bucket n_shards and never scatter
        dest = jnp.where(fv, fk % n_shards, n_shards)
        onehot = dest[:, None] == jnp.arange(n_shards + 1)[None, :]
        ranks = jnp.cumsum(onehot, axis=0) - 1
        rank = jnp.take_along_axis(ranks, dest[:, None], axis=1)[:, 0]
        counts = onehot[:, :n_shards].sum(axis=0)
        overflow = jnp.any(counts > cap)
        ok = fv & (rank < cap)
        slot = jnp.where(ok, dest * cap + rank, n_shards * cap)  # OOB -> drop
        out_k = jnp.zeros(n_shards * cap, keys.dtype).at[slot].set(fk, mode="drop")
        out_v = jnp.zeros(n_shards * cap, bool).at[slot].set(ok, mode="drop")
        out_p = (
            jnp.zeros((n_shards * cap,) + fp.shape[1:], payload.dtype)
            .at[slot]
            .set(fp, mode="drop")
        )
        out_src = (
            jnp.full(n_shards * cap, total, jnp.int32)
            .at[slot]
            .set(jnp.arange(total, dtype=jnp.int32), mode="drop")
        )
        return (
            out_k.reshape(n_shards, cap),
            out_p.reshape((n_shards, cap) + fp.shape[1:]),
            out_v.reshape(n_shards, cap),
            out_src.reshape(n_shards, cap),
            overflow,
        )

    out_shardings = (
        NamedSharding(mesh, row_spec),
        NamedSharding(mesh, row_spec),
        NamedSharding(mesh, row_spec),
        NamedSharding(mesh, row_spec),
        NamedSharding(mesh, P()),
    )
    return jax.jit(impl, out_shardings=out_shardings)


def shuffle_by_key(
    keys: jnp.ndarray,  # (n_shards, n) int
    payload: jnp.ndarray,  # (n_shards, n, ...) rides along
    valid: jnp.ndarray,  # (n_shards, n) bool
    mesh,
    capacity_factor: float = CAPACITY_FACTOR,
) -> ShuffleResult:
    """Route rows so each key lives on exactly one shard.

    Returns a ``ShuffleResult`` with the same per-shard layout widened to
    ``capacity_factor * n`` columns; ``overflow`` is a scalar bool — True
    when some shard received more rows than its capacity (those rows are
    dropped; re-shuffle with a larger factor).  ``src`` maps every routed
    slot back to its flat source row index (the inverse permutation).
    """
    n_shards, n = keys.shape
    cap = _capacity(n, capacity_factor)
    with mesh:
        out = _routed_fn(mesh, n_shards, n, cap)(keys, payload, valid)
    return ShuffleResult(*out)
