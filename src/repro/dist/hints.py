"""Logical-axis sharding hints for activations.

``hint(x, "dp", None, "tp")`` pins an intermediate to the mesh currently in
scope: logical axis ``"dp"`` maps to the data-parallel mesh axes (``pod``
composed with ``data``), ``"tp"`` maps to the tensor-parallel ``model``
axis, ``None`` leaves a dim unconstrained.  Outside any mesh — or on a
single device — it is an exact no-op (returns ``x`` itself), so model code
can sprinkle hints unconditionally and CPU smoke tests see plain arrays.

A dim whose size is not divisible by the mapped axes' extent is left
unconstrained rather than erroring: the hint is advice to the partitioner,
never a hard requirement.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# outer -> inner data-parallel axes; ``pod`` composes with ``data`` as the
# outer DP axis on the multi-pod mesh (launch/mesh.py)
DP_AXES = ("pod", "data")
TP_AXIS = "model"


def dp_axes(mesh):
    """Data-parallel mesh axes present with extent > 1 (outer first)."""
    return tuple(
        a for a in DP_AXES if a in mesh.axis_names and mesh.shape[a] > 1
    )


_warned_no_mesh_api = False


def current_mesh():
    """The physical mesh installed by ``with mesh:``, or None."""
    global _warned_no_mesh_api
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover — jax internals moved
        if not _warned_no_mesh_api:
            _warned_no_mesh_api = True
            import warnings

            warnings.warn(
                "repro.dist.hints: jax no longer exposes "
                "jax._src.mesh.thread_resources — sharding hints are now "
                "no-ops everywhere. Update current_mesh() for this jax."
            )
        return None
    if mesh is None or mesh.empty:
        return None
    return mesh


def _resolve(mesh, name, dim):
    """Map one logical axis name to a PartitionSpec entry for ``dim``."""
    if name is None:
        return None
    if name == "dp":
        axes = dp_axes(mesh)
    elif name == "tp":
        axes = (
            (TP_AXIS,)
            if TP_AXIS in mesh.axis_names and mesh.shape[TP_AXIS] > 1
            else ()
        )
    else:  # a raw mesh axis name
        axes = (name,) if name in mesh.axis_names and mesh.shape[name] > 1 else ()
    if not axes:
        return None
    if dim % int(np.prod([mesh.shape[a] for a in axes])) != 0:
        return None  # uneven split: let the partitioner decide
    return axes if len(axes) > 1 else axes[0]


def hint(x, *axis_names):
    """Constrain ``x``'s sharding on the current mesh; no-op off-mesh."""
    mesh = current_mesh()
    if mesh is None or mesh.size <= 1:
        return x
    entries = [
        _resolve(mesh, name, x.shape[i])
        for i, name in enumerate(axis_names[: x.ndim])
    ]
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))
