"""Optimizers with shardable state: AdamW (fp32 or bf16 moments) and
Adafactor (factored second moment — the 340B/398B single-pod fit option).

State trees mirror the parameter tree, so the parameter PartitionSpecs apply
verbatim (dist/sharding.opt_state_specs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adamw_bf16 | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig, grad_compress: bool = False) -> Dict:
    """``grad_compress`` adds the int8 all-reduce's error-feedback residual
    ``gerr`` (f32, param-shaped — the param PartitionSpecs apply) so the
    quantization error carries across steps (train/steps.py opt-in)."""
    mdt = jnp.bfloat16 if cfg.name == "adamw_bf16" else jnp.float32
    if cfg.name in ("adamw", "adamw_bf16"):
        state = {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        }
    elif cfg.name == "adafactor":
        def vr(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        state = {
            "step": jnp.zeros((), jnp.int32),
            "vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
        }
    else:
        raise ValueError(cfg.name)
    if grad_compress:
        state["gerr"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return state


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params, grads, state: Dict, cfg: OptConfig):
    """One optimizer step.  Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)

    if cfg.name in ("adamw", "adamw_bf16"):
        b1, b2 = cfg.b1, cfg.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

        flat_p, tree = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tree, [o[0] for o in out])
        new_m = jax.tree.unflatten(tree, [o[1] for o in out])
        new_v = jax.tree.unflatten(tree, [o[2] for o in out])
        new_state = {"step": step, "m": new_m, "v": new_v}
        return new_p, new_state, {"lr": lr, "grad_norm": gnorm}

    # adafactor (factored v, no first moment, update clipping)
    def upd(p, g, vr, vc):
        g2 = jnp.square(g) + 1e-30
        decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8
        if p.ndim >= 2:
            vr2 = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
            vc2 = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr2, axis=-1, keepdims=True), 1e-30)
            vhat = (
                vr2[..., :, None] * vc2[..., None, :] / denom[..., None]
            )
        else:
            vr2 = decay * vr + (1 - decay) * g2
            vc2 = vc
            vhat = vr2
        u = g / jnp.sqrt(vhat + 1e-30)
        # update clipping (rms <= 1)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * u
        return newp.astype(p.dtype), vr2, vc2

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_vr = jax.tree.leaves(state["vr"])
    flat_vc = jax.tree.leaves(state["vc"])
    out = [upd(p, g, r, c) for p, g, r, c in zip(flat_p, flat_g, flat_vr, flat_vc)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_vr = jax.tree.unflatten(tree, [o[1] for o in out])
    new_vc = jax.tree.unflatten(tree, [o[2] for o in out])
    new_state = {"step": step, "vr": new_vr, "vc": new_vc}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
