"""train_step / serve_step — the functions the dry-run lowers and the
trainer executes.

``make_train_step`` builds a donated, microbatched (gradient-accumulation)
step: the global batch reshapes to (n_micro, mb, ...) and a ``lax.scan``
accumulates gradients before one optimizer application.  Peak activation
memory is one microbatch's remat stash; the accumulation buffer is the f32
gradient tree (sharded like the params).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, loss_fn
from repro.train.optim import OptConfig, apply_updates


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptConfig,
    n_micro: int = 1,
    mamba_chunk: int = 128,
    grad_compress: bool = False,
    mesh=None,
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_compress`` (opt-in, needs ``mesh``) routes the accumulated
    gradients through the int8 error-feedback all-reduce
    (dist/collectives.py): the quantize -> mean-reduce -> dequantize
    numerics run end-to-end in the step and the residual carries across
    steps in ``opt_state["gerr"]`` (init_opt_state(grad_compress=True)),
    so convergence under the lossy wire format is measurable.  Under
    GSPMD the gradients enter already globally reduced, so this models
    the compression exactly but does not yet shrink bytes-on-wire — that
    needs the manual-DP fusion noted in the collectives module docstring.
    """
    if grad_compress and mesh is None:
        raise ValueError("grad_compress=True requires a mesh")

    def micro_loss(params, micro_batch):
        return loss_fn(params, cfg, micro_batch, mamba_chunk=mamba_chunk)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                micro_loss, has_aux=True
            )(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
                batch,
            )
            # accumulate in f32 when masters are f32; bf16 masters (the
            # 340B/398B single-pod fit path) accumulate in bf16 to halve the
            # gradient buffer (documented tradeoff, DESIGN.md §5)
            acc_dt = jax.tree.leaves(params)[0].dtype
            grad_zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            )

            def acc_body(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = jax.value_and_grad(micro_loss, has_aux=True)(
                    params, mb
                )
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype) / n_micro, gacc, grads
                )
                return (gacc, lacc + loss / n_micro), None

            (grads, loss), _ = jax.lax.scan(
                acc_body, (grad_zero, jnp.float32(0.0)), micro
            )
        new_err = None
        if grad_compress:
            from repro.dist.collectives import grad_allreduce_compressed

            if "gerr" not in opt_state:
                raise ValueError(
                    "grad_compress=True needs the error-feedback residual "
                    "opt_state['gerr']: initialize with "
                    "init_opt_state(..., grad_compress=True)"
                )
            grads, new_err = grad_allreduce_compressed(
                grads, opt_state["gerr"], mesh
            )
        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg
        )
        if new_err is not None:
            new_opt["gerr"] = new_err
        out = {"loss": loss, **opt_metrics}
        return new_params, new_opt, out

    return train_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """(params, cache, token) -> (next_token_logits, new_cache)."""

    def serve_step(params, cache, token):
        logits, new_cache = decode_step(params, cfg, cache, token)
        return logits, new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, s_max: int, mamba_chunk: int = 128) -> Callable:
    from repro.models.transformer import prefill

    def prefill_step(params, batch):
        return prefill(params, cfg, batch, s_max=s_max, mamba_chunk=mamba_chunk)

    return prefill_step
