"""Fault tolerance for 1000+-node runs: retry/restart policy, straggler
monitoring, elastic re-mesh planning.

On a real cluster, node failure surfaces as a collective timeout / jax
runtime error inside the step; the policy here is the standard one:

    failure -> checkpoint-restore restart, excluding the bad host
            -> re-mesh onto the surviving device count (elastic)
            -> replay from the last checkpoint (bitwise, since data order
               is keyed by step)

This module implements the pieces that are testable without hardware: the
retry wrapper, the EWMA straggler detector, and the elastic mesh planner
(which factorizations survive losing k hosts).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class RetryPolicy:
    max_restarts: int = 3
    backoff_s: float = 1.0
    backoff_mult: float = 2.0
    retryable: Tuple[type, ...] = (RuntimeError, OSError)


def run_with_restarts(
    step_fn: Callable[[], None],
    restore_fn: Callable[[], None],
    policy: RetryPolicy,
    sleep=time.sleep,
) -> int:
    """Drive ``step_fn`` with restart-on-failure.  Returns restart count."""
    restarts = 0
    backoff = policy.backoff_s
    while True:
        try:
            step_fn()
            return restarts
        except policy.retryable:
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            sleep(backoff)
            backoff *= policy.backoff_mult
            restore_fn()


class StragglerMonitor:
    """Per-step wall-time EWMA + variance; flags steps beyond k sigma.

    On TPU pods a straggling host shows up as a slow step for EVERYONE
    (collectives synchronize), so the monitor runs on the coordinator and
    the report carries which host's input pipeline lagged (per-host
    timestamps, when available)."""

    def __init__(self, alpha: float = 0.1, k_sigma: float = 4.0, warmup: int = 8):
        self.alpha = alpha
        self.k = k_sigma
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self.flagged: List[Tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = False
        if self.n > self.warmup:
            sigma = math.sqrt(max(self.var, 1e-12))
            if dt > self.mean + self.k * sigma and dt > 1.5 * self.mean:
                is_straggler = True
                self.flagged.append((step, dt))
        # EWMA update (straggler steps excluded so the mean stays clean)
        if not is_straggler:
            delta = dt - self.mean
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return is_straggler


def elastic_mesh_plan(
    n_devices: int,
    model_parallel: int,
    devices_per_host: int = 4,
) -> Dict[str, int]:
    """Largest (data, model) factorization that fits ``n_devices`` while
    keeping the TP degree — the re-mesh used after excluding failed hosts.

    TP groups must not span failed hosts, so data-parallel replicas drop in
    units of whole TP groups."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep TP={model_parallel} with {n_devices} devices"
        )
    data = n_devices // model_parallel
    return {
        "data": data,
        "model": model_parallel,
        "used_devices": data * model_parallel,
        "idle_devices": n_devices - data * model_parallel,
    }


@dataclasses.dataclass
class HeartbeatTracker:
    """Host liveness from periodic heartbeats (coordinator side)."""

    timeout_s: float = 60.0
    last_seen: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host_id: int, now: float) -> None:
        self.last_seen[host_id] = now

    def dead_hosts(self, now: float) -> List[int]:
        return [
            h for h, t in self.last_seen.items() if now - t > self.timeout_s
        ]
