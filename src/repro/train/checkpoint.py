"""Sharded, manifest-driven, atomic checkpointing with elastic restore.

Layout (one directory per step):

    ckpt_dir/step_000123/
        manifest.json        tree structure, shapes, dtypes, mesh shape
        shard_00000.npz      this host's param/opt leaves (flat index keys)
    ckpt_dir/LATEST          text file: "step_000123"  (atomic rename)

* **Atomicity**: writes land in ``step_X.tmp`` and are renamed after the
  manifest is fsynced — a crash mid-write never corrupts LATEST.
* **Elastic restore**: the manifest records logical shapes only; restore
  loads the full arrays and re-shards onto WHATEVER mesh the new job built
  (device_put against the new sharding), so a 2-pod checkpoint restarts on
  1 pod and vice versa.
* On a real multi-host cluster each host writes only its addressable
  shards; on this single-host container shard_00000 is the whole tree.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(ckpt_dir: str, step: int, state: Dict[str, Any]) -> str:
    """state: {'params': tree, 'opt': tree, 'extra': json-able}."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:06d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrays = {}
    manifest: Dict[str, Any] = {"step": step, "trees": {}, "extra": state.get("extra", {})}
    for tree_name in ("params", "opt"):
        if tree_name not in state:
            continue
        flat = _flatten(state[tree_name])
        manifest["trees"][tree_name] = {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()
        }
        for k, v in flat.items():
            arrays[f"{tree_name}::{k}"] = v

    np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST update
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(
    ckpt_dir: str,
    like: Dict[str, Any],
    shardings: Optional[Dict[str, Any]] = None,
    step: Optional[int] = None,
) -> Tuple[Dict[str, Any], int]:
    """Restore into the structure of ``like`` ({'params':..., 'opt':...}),
    placing leaves with ``shardings`` when given (elastic re-shard)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:06d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_00000.npz"))

    out: Dict[str, Any] = {"extra": manifest.get("extra", {})}
    for tree_name in ("params", "opt"):
        if tree_name not in like:
            continue
        flat, treedef = jax.tree_util.tree_flatten_with_path(like[tree_name])
        shard_flat = (
            jax.tree_util.tree_flatten_with_path(shardings[tree_name])[0]
            if shardings and tree_name in shardings
            else None
        )
        leaves = []
        for i, (pth, leaf) in enumerate(flat):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
            arr = data[f"{tree_name}::{key}"]
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i][1]))
            else:
                leaves.append(jnp.asarray(arr))
        out[tree_name] = jax.tree_util.tree_unflatten(treedef, leaves)
    return out, step


def prune_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.isdir(os.path.join(ckpt_dir, d))
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))
