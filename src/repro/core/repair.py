"""Probabilistic repair (paper §4.1-§4.3).

Turns detection results into candidate overlays:

* **FD repair**: a violated cell's rhs candidates are the distinct rhs values
  co-occurring with its lhs (frequency-weighted -> P(rhs|lhs)); symmetrically
  lhs candidates from P(lhs|rhs) when the lhs is a single attribute.  Both
  sides are kept, mirroring the paper's "two instances per tuple" candidate
  pairs (Example 2 / Table 2b).
* **DC repair** (Example 4): for each violated inequality atom the touched
  attribute keeps its original value OR takes the open range inverting the
  atom against *all* violating partners (bound = extremal partner value from
  the theta-join scan).  Original and range fix get equal weight — Example
  4's {<2000 50%, 3000 50%}.  Equality atoms contribute detection only; their
  value fixes are the FD machinery's job (DESIGN.md §2 assumption (c)).

Counts (not normalized probabilities) are stored so that the multi-rule merge
is a plain union-sum — exactly commutative/associative (Lemma 4); probability
normalization happens on read (``Relation.probs``).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax.numpy as jnp

from repro.core.constraints import DC, FD
from repro.core.detect import DCDetectResult, FDDetectResult
from repro.core.relation import CAND_GT, CAND_LT, CAND_VALUE, Relation


class Candidates(NamedTuple):
    """Per-row candidate overlay delta for one attribute."""

    values: jnp.ndarray  # (cap, K)
    counts: jnp.ndarray  # (cap, K) float32; 0 == empty slot
    kinds: jnp.ndarray  # (cap, K) int8
    rows: jnp.ndarray  # (cap,) bool — rows the delta applies to


def fd_repair_candidates(
    rel: Relation, fd: FD, det: FDDetectResult, scope: jnp.ndarray
) -> Tuple[Tuple[str, Candidates], ...]:
    """Candidate deltas per attribute for FD violations inside ``scope``."""
    rows = det.violated & scope & rel.valid
    out = []
    kinds = jnp.zeros(det.rhs_cand.shape, jnp.int8)
    out.append((fd.rhs, Candidates(det.rhs_cand, det.rhs_count, kinds, rows)))
    if det.lhs_cand is not None and len(fd.lhs) == 1:
        lkinds = jnp.zeros(det.lhs_cand.shape, jnp.int8)
        out.append(
            (fd.lhs[0], Candidates(det.lhs_cand, det.lhs_count, lkinds, rows))
        )
    return tuple(out)


# fix kind that inverts a violated atom ``row.x op partner.y`` for ALL partners
_FIX_KIND = {"<": CAND_GT, "<=": CAND_GT, ">": CAND_LT, ">=": CAND_LT}


def _role_candidates(
    rel: Relation,
    attrs: Sequence[str],
    ops: Sequence[str],
    count: jnp.ndarray,
    stats: Sequence[jnp.ndarray],
    scope: jnp.ndarray,
    k: int,
):
    """Original-value + range-fix candidate pair per violated inequality atom.

    Both slots carry the row's violating-PAIR count for this role (not a
    per-merge constant), so the {orig, range} pair stays Example 4's 50/50
    within a role AND a partitioned scan — partner strips, the ingest
    delta's [checked x fresh] pass (DESIGN.md §12) — merges to exactly the
    counts one full scan produces: pair counts sum over partner partitions,
    and same-kind range bounds coalesce to the tightest (update.py)."""
    rows = (count > 0) & scope & rel.valid
    weight = count.astype(jnp.float32)
    out = []
    for attr, op, stat in zip(attrs, ops, stats):
        if op not in _FIX_KIND:
            continue  # equality atom: no range fix (see module docstring)
        col = rel.columns[attr]
        cap = col.shape[0]
        values = jnp.zeros((cap, k), col.dtype)
        counts = jnp.zeros((cap, k), jnp.float32)
        kinds = jnp.zeros((cap, k), jnp.int8)
        values = values.at[:, 0].set(col)  # original value
        values = values.at[:, 1].set(stat.astype(col.dtype))  # range bound
        counts = counts.at[:, 0].set(weight).at[:, 1].set(weight)
        kinds = kinds.at[:, 1].set(_FIX_KIND[op])
        out.append((attr, Candidates(values, counts, kinds, rows)))
    return out


def dc_repair_candidates(
    rel: Relation, dc: DC, det: DCDetectResult, scope: jnp.ndarray, k: int | None = None
) -> Tuple[Tuple[str, Candidates], ...]:
    """Candidate deltas for DC violations: both tuple roles (Example 4)."""
    from repro.core.constraints import flip_op

    k = k or max(rel.k, 2)
    # role t1: atoms as written — fix on the LEFT attribute of each atom.
    t1 = _role_candidates(
        rel,
        [a.left for a in dc.atoms],
        [a.op for a in dc.atoms],
        det.t1_count,
        det.t1_stat,
        scope,
        k,
    )
    # role t2: flipped atoms — fix on the RIGHT attribute.
    t2 = _role_candidates(
        rel,
        [a.right for a in dc.atoms],
        [flip_op(a.op) for a in dc.atoms],
        det.t2_count,
        det.t2_stat,
        scope,
        k,
    )
    return tuple(t1 + t2)


def repaired_value(rel: Relation, attr: str) -> jnp.ndarray:
    """Most-probable concrete candidate per cell (ties -> first slot); cells
    without an overlay keep their primary value.  Range candidates cannot be
    materialized to a single value, so CAND_VALUE slots are preferred."""
    if attr not in rel.cand:
        return rel.columns[attr]
    counts = rel.ccount[attr]
    kinds = rel.ckind[attr]
    eff = jnp.where(kinds == CAND_VALUE, counts, -1.0)
    best = jnp.argmax(eff, axis=1)
    rows = jnp.arange(counts.shape[0])
    has = jnp.any(counts > 0, axis=1)
    return jnp.where(has, rel.cand[attr][rows, best], rel.columns[attr])
