"""Columnar, fixed-capacity, probabilistic relation.

TPU adaptation of Daisy's Spark RDD rows (DESIGN.md §2):

* columns are dense ``int32``/``float32`` arrays of a fixed ``capacity`` with a
  validity mask — no dynamic row sets, everything is mask/scatter based so every
  operator JITs to a static shape;
* string attributes are dictionary-encoded to ``int32`` codes host-side
  (``Dictionary``); equality of codes == equality of strings, so FD semantics
  are unchanged;
* attribute-level uncertainty (Suciu-style, §4 of the paper) is a dense overlay:
  up to ``K`` candidate values per cell with *counts* (probabilities are derived
  ``count / sum(count)``).  Keeping raw counts makes the multi-rule merge of
  Lemma 4 exactly commutative/associative;
* general-DC range candidates carry a per-candidate kind code
  (``CAND_VALUE`` / ``CAND_LT`` / ``CAND_GT``), matching the paper's
  "original value or a value satisfying the range" fixes (Example 4);
* provenance to the original values (``orig``) and per-rule ``checked`` flags
  are first-class, which is what enables the incremental multi-rule behaviour
  of Table 7.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel pushed to the end of sorts; also the "invalid" key. Encoded values
# produced by Dictionary start at 0 and stay well below this.
SENTINEL = np.int32(2**31 - 1)

# Candidate kinds (attribute-level uncertainty cells).
CAND_VALUE = np.int8(0)  # candidate is a concrete value
CAND_LT = np.int8(1)  # candidate is the open range (-inf, bound)
CAND_GT = np.int8(2)  # candidate is the open range (bound, +inf)


class Dictionary:
    """Host-side string dictionary (string -> int32 code)."""

    def __init__(self, values: Optional[Sequence[str]] = None):
        self._to_code: Dict[str, int] = {}
        self._to_str: List[str] = []
        if values is not None:
            for v in values:
                self.encode(v)

    def encode(self, value: str) -> int:
        code = self._to_code.get(value)
        if code is None:
            code = len(self._to_str)
            self._to_code[value] = code
            self._to_str.append(value)
        return code

    def encode_many(self, values: Sequence[str]) -> np.ndarray:
        return np.asarray([self.encode(v) for v in values], dtype=np.int32)

    def decode(self, code: int) -> str:
        return self._to_str[int(code)]

    def __len__(self) -> int:
        return len(self._to_str)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Relation:
    """Fixed-capacity columnar relation with a probabilistic overlay.

    Attributes
    ----------
    columns:   name -> (cap,) primary value per cell (the current best value —
               candidate 0 of the overlay when the cell is uncertain).
    valid:     (cap,) bool row validity.
    cand:      name -> (cap, K) candidate values        (overlay attrs only)
    ccount:    name -> (cap, K) float32 candidate counts (0 == empty slot)
    ckind:     name -> (cap, K) int8 candidate kinds (CAND_VALUE/LT/GT)
    orig:      name -> (cap,) provenance: the pre-cleaning original value
    checked:   rule name -> (cap,) bool "tuple checked for this rule"
    """

    columns: Dict[str, jnp.ndarray]
    valid: jnp.ndarray
    cand: Dict[str, jnp.ndarray]
    ccount: Dict[str, jnp.ndarray]
    ckind: Dict[str, jnp.ndarray]
    orig: Dict[str, jnp.ndarray]
    checked: Dict[str, jnp.ndarray]

    # ---------------------------------------------------------------- pytree
    def tree_flatten(self):
        names = sorted(self.columns)
        onames = sorted(self.cand)
        gnames = sorted(self.orig)
        rnames = sorted(self.checked)
        leaves = (
            [self.columns[n] for n in names]
            + [self.valid]
            + [self.cand[n] for n in onames]
            + [self.ccount[n] for n in onames]
            + [self.ckind[n] for n in onames]
            + [self.orig[n] for n in gnames]
            + [self.checked[n] for n in rnames]
        )
        aux = (tuple(names), tuple(onames), tuple(gnames), tuple(rnames))
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        names, onames, gnames, rnames = aux
        it = iter(leaves)
        columns = {n: next(it) for n in names}
        valid = next(it)
        cand = {n: next(it) for n in onames}
        ccount = {n: next(it) for n in onames}
        ckind = {n: next(it) for n in onames}
        orig = {n: next(it) for n in gnames}
        checked = {n: next(it) for n in rnames}
        return cls(columns, valid, cand, ccount, ckind, orig, checked)

    # ------------------------------------------------------------ properties
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    @property
    def k(self) -> int:
        for v in self.cand.values():
            return int(v.shape[1])
        return 0

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    def num_rows(self) -> jnp.ndarray:
        return jnp.sum(self.valid.astype(jnp.int32))

    # ------------------------------------------------------------- overlays
    def has_overlay(self, name: str) -> bool:
        return name in self.cand

    def probs(self, name: str) -> jnp.ndarray:
        """(cap, K) candidate probabilities (counts normalized per row)."""
        c = self.ccount[name]
        tot = jnp.sum(c, axis=1, keepdims=True)
        return jnp.where(tot > 0, c / jnp.maximum(tot, 1e-30), 0.0)

    def is_uncertain(self, name: str) -> jnp.ndarray:
        """(cap,) bool — cell has >= 2 candidates."""
        return jnp.sum((self.ccount[name] > 0).astype(jnp.int32), axis=1) >= 2

    def candidate_matches(self, name: str, op: str, value) -> jnp.ndarray:
        """Possible-world predicate: (cap,) bool — does ANY candidate of
        ``name`` satisfy ``op value``?  (Paper §4: "query operators output a
        tuple iff at least one candidate value qualifies".)

        Range candidates (CAND_LT/CAND_GT) qualify when the candidate range
        overlaps the predicate's satisfying set.
        """
        if name not in self.cand:
            return _apply_op(self.columns[name], op, value)
        cv = self.cand[name]
        ck = self.ckind[name]
        alive = self.ccount[name] > 0
        val_ok = _apply_op(cv, op, value)
        # Range candidate overlap rules against {EQ, NE, LT, LE, GT, GE} preds.
        lt_ok = _range_lt_overlaps(cv, op, value)  # candidate == (-inf, cv)
        gt_ok = _range_gt_overlaps(cv, op, value)  # candidate == (cv, +inf)
        ok = jnp.where(ck == CAND_LT, lt_ok, jnp.where(ck == CAND_GT, gt_ok, val_ok))
        any_ok = jnp.any(ok & alive, axis=1)
        no_cand = ~jnp.any(alive, axis=1)
        base_ok = _apply_op(self.columns[name], op, value)
        return jnp.where(no_cand, base_ok, any_ok)


def _apply_op(x: jnp.ndarray, op: str, value) -> jnp.ndarray:
    if op == "==":
        return x == value
    if op == "!=":
        return x != value
    if op == "<":
        return x < value
    if op == "<=":
        return x <= value
    if op == ">":
        return x > value
    if op == ">=":
        return x >= value
    raise ValueError(f"unknown op {op!r}")


def _range_lt_overlaps(bound: jnp.ndarray, op: str, value) -> jnp.ndarray:
    """Does the candidate range (-inf, bound) intersect {x : x op value}?"""
    if op == "==":
        return value < bound
    if op == "!=":
        return jnp.ones_like(bound, dtype=bool)
    if op in ("<", "<="):
        return jnp.ones_like(bound, dtype=bool)  # range extends to -inf
    if op in (">", ">="):
        return bound > value  # some x with value < x < bound exists
    raise ValueError(op)


def _range_gt_overlaps(bound: jnp.ndarray, op: str, value) -> jnp.ndarray:
    """Does the candidate range (bound, +inf) intersect {x : x op value}?"""
    if op == "==":
        return value > bound
    if op == "!=":
        return jnp.ones_like(bound, dtype=bool)
    if op in (">", ">="):
        return jnp.ones_like(bound, dtype=bool)  # range extends to +inf
    if op in ("<", "<="):
        return value > bound  # some x with bound < x < value exists
    raise ValueError(op)


def make_relation(
    data: Mapping[str, np.ndarray],
    capacity: Optional[int] = None,
    overlay: Sequence[str] = (),
    k: int = 8,
    rules: Sequence[str] = (),
) -> Relation:
    """Build a Relation from host numpy columns.

    ``overlay`` lists attributes that may become probabilistic (the attributes
    appearing in some constraint).  ``rules`` pre-registers per-rule checked
    flags.
    """
    names = list(data)
    if not names:
        raise ValueError("empty relation")
    n = len(np.asarray(data[names[0]]))
    cap = capacity or n
    if cap < n:
        raise ValueError(f"capacity {cap} < rows {n}")

    columns = {}
    for name in names:
        arr = np.asarray(data[name])
        if arr.dtype.kind in "iu":
            arr = arr.astype(np.int32)
            pad_val = SENTINEL
        else:
            arr = arr.astype(np.float32)
            pad_val = np.float32(np.nan)
        out = np.full((cap,), pad_val, dtype=arr.dtype)
        out[:n] = arr
        columns[name] = jnp.asarray(out)

    valid = jnp.asarray(np.arange(cap) < n)

    cand, ccount, ckind, orig = {}, {}, {}, {}
    for name in overlay:
        col = columns[name]
        cv = jnp.zeros((cap, k), dtype=col.dtype)
        cand[name] = cv.at[:, 0].set(col)
        # count 0 everywhere -> "no overlay yet"; cells become uncertain only
        # once a repair writes counts.
        ccount[name] = jnp.zeros((cap, k), dtype=jnp.float32)
        ckind[name] = jnp.zeros((cap, k), dtype=jnp.int8)
        orig[name] = col
    checked = {r: jnp.zeros((cap,), dtype=bool) for r in rules}
    return Relation(columns, valid, cand, ccount, ckind, orig, checked)


def _pad_value(dtype) -> object:
    return np.float32(np.nan) if dtype == jnp.float32 else SENTINEL


def _grow_relation(rel: Relation, capacity: int) -> Relation:
    """Re-pad every array of ``rel`` to ``capacity`` rows.

    The first ``rel.capacity`` rows of every array are carried over
    bit-for-bit (overlay counts, kinds, checked flags, provenance); the
    new tail gets exactly the spare-row state ``make_relation`` would have
    produced: pad values in columns/orig, ``valid=False``, empty overlay
    with candidate slot 0 mirroring the (pad) column value, and unchecked.
    """
    old = rel.capacity
    if capacity < old:
        raise ValueError(f"cannot shrink capacity {old} -> {capacity}")
    if capacity == old:
        return rel
    extra = capacity - old
    k = rel.k

    def pad1(arr, fill):
        tail = jnp.full((extra,), fill, dtype=arr.dtype)
        return jnp.concatenate([arr, tail])

    columns = {n: pad1(c, _pad_value(c.dtype)) for n, c in rel.columns.items()}
    valid = pad1(rel.valid, False)
    cand, ccount, ckind, orig = {}, {}, {}, {}
    for name, cv in rel.cand.items():
        pad = _pad_value(cv.dtype)
        tail = jnp.zeros((extra, k), dtype=cv.dtype).at[:, 0].set(pad)
        cand[name] = jnp.concatenate([cv, tail])
        ccount[name] = jnp.concatenate(
            [rel.ccount[name], jnp.zeros((extra, k), dtype=jnp.float32)]
        )
        ckind[name] = jnp.concatenate(
            [rel.ckind[name], jnp.zeros((extra, k), dtype=jnp.int8)]
        )
        orig[name] = pad1(rel.orig[name], pad)
    checked = {r: pad1(c, False) for r, c in rel.checked.items()}
    return Relation(columns, valid, cand, ccount, ckind, orig, checked)


def append_rows(rel: Relation, data: Mapping[str, np.ndarray]) -> Tuple[Relation, int]:
    """Append host rows into a relation's spare capacity (DESIGN.md §12).

    ``data`` must provide exactly the relation's columns.  Rows land at
    the end of the valid prefix (``valid`` stays a prefix mask, the
    invariant every strip/ledger computation relies on); when the spare
    capacity runs out the relation grows to ``next_pow2`` of the needed
    row count, preserving all pre-existing overlay/checked/cand state
    bit-for-bit.  Fresh rows start exactly like ``make_relation`` rows:
    certain (empty overlay, candidate slot 0 = the value), unchecked for
    every rule, with ``orig`` provenance equal to the ingested value.

    Returns ``(new_relation, start)`` where ``start`` is the row index of
    the first appended row.  Pure — the input relation is not mutated.
    """
    names = set(rel.columns)
    if set(data) != names:
        raise ValueError(
            f"ingest columns {sorted(data)} != relation columns {sorted(names)}"
        )
    arrays = {n: np.asarray(v) for n, v in data.items()}
    lengths = {len(a) for a in arrays.values()}
    if len(lengths) != 1:
        raise ValueError(f"ragged ingest batch: column lengths {sorted(lengths)}")
    n_new = lengths.pop()
    if n_new == 0:
        return rel, int(np.asarray(rel.valid).sum())

    start = int(np.asarray(rel.valid).sum())
    needed = start + n_new
    if needed > rel.capacity:
        rel = _grow_relation(rel, next_pow2(needed))
    stop = start + n_new

    columns = dict(rel.columns)
    for name, arr in arrays.items():
        col = columns[name]
        if col.dtype == jnp.float32:
            vals = jnp.asarray(arr.astype(np.float32))
        else:
            if arr.dtype.kind not in "iu":
                raise ValueError(f"column {name!r} expects integer values")
            vals = jnp.asarray(arr.astype(np.int32))
        columns[name] = col.at[start:stop].set(vals)
    valid = rel.valid.at[start:stop].set(True)
    cand, orig = dict(rel.cand), dict(rel.orig)
    for name in rel.cand:
        cand[name] = cand[name].at[start:stop, 0].set(columns[name][start:stop])
        orig[name] = orig[name].at[start:stop].set(columns[name][start:stop])
    return (
        Relation(columns, valid, cand, dict(rel.ccount), dict(rel.ckind), orig, rel.checked),
        start,
    )


def masked_keys(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Replace masked-out entries with the sort sentinel."""
    if values.dtype == jnp.float32:
        return jnp.where(mask, values, jnp.float32(np.inf))
    return jnp.where(mask, values, SENTINEL)


def next_pow2(n: int) -> int:
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()
