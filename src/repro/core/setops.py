"""Sort-based exact set/group primitives.

These are the TPU-native replacements for the Spark shuffle primitives the
paper builds on (``groupBy``, ``filter(contains)``).  Everything is static
shape: membership is a boolean mask, groups are segment ids over a
lexicographic sort (``jax.lax.sort`` supports multi-key sorts natively, so
multi-attribute FD left-hand-sides are exact — no hash-collision risk).

All functions treat ``mask==False`` rows as absent: their keys are replaced by
a sentinel that sorts last, and outputs for them are zero/false.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.relation import masked_keys


def _lex_sort(keys: Sequence[jnp.ndarray], payloads: Sequence[jnp.ndarray]):
    """Stable lexicographic sort by ``keys`` carrying ``payloads`` along."""
    operands = tuple(keys) + tuple(payloads)
    out = jax.lax.sort(operands, dimension=0, is_stable=True, num_keys=len(keys))
    return out[: len(keys)], out[len(keys):]


def _runs(sorted_keys: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """(n,) bool: position starts a new distinct key run."""
    n = sorted_keys[0].shape[0]
    new = jnp.zeros((n,), dtype=bool).at[0].set(True)
    diff = jnp.zeros((n - 1,), dtype=bool) if n > 1 else None
    if n > 1:
        for k in sorted_keys:
            diff = diff | (k[1:] != k[:-1])
        new = new.at[1:].set(diff)
    return new


def member_in(
    query_cols: Sequence[jnp.ndarray],
    query_mask: jnp.ndarray,
    set_cols: Sequence[jnp.ndarray],
    set_mask: jnp.ndarray,
) -> jnp.ndarray:
    """Exact multi-column semijoin membership.

    Returns ``(n_q,) bool``: for each query row ``i`` with ``query_mask[i]``,
    whether its key tuple appears among the key tuples of ``set`` rows with
    ``set_mask``.  Sort-merge based (O((n+m) log(n+m))), exact for any number
    of key columns.
    """
    n_q = query_cols[0].shape[0]
    n_s = set_cols[0].shape[0]
    n = n_q + n_s
    keys = [
        jnp.concatenate([masked_keys(s, set_mask), masked_keys(q, query_mask)])
        for q, s in zip(query_cols, set_cols)
    ]
    # tag sorts set rows before query rows inside an equal-key run (stable).
    tag = jnp.concatenate(
        [jnp.zeros((n_s,), jnp.int32), jnp.ones((n_q,), jnp.int32)]
    )
    pos = jnp.concatenate(
        [jnp.full((n_s,), n_q, jnp.int32), jnp.arange(n_q, dtype=jnp.int32)]
    )
    skeys, (stag, spos) = _lex_sort(keys, (tag, pos))
    new_run = _runs(skeys)
    run_id = jnp.cumsum(new_run.astype(jnp.int32)) - 1
    has_set = jax.ops.segment_max(
        (stag == 0).astype(jnp.int32), run_id, num_segments=n
    )
    in_set = (has_set[run_id] > 0) & (stag == 1)
    out = jnp.zeros((n_q,), dtype=bool)
    out = out.at[spos].set(in_set, mode="drop")  # spos==n_q (set rows) dropped
    return out & query_mask


def group_info(
    key_cols: Sequence[jnp.ndarray],
    mask: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Group rows by key tuple.  Returns ``(group_id, group_size)`` per row.

    ``group_id`` is dense in sorted-key order (masked rows all map to the
    last group, size counted over masked-in rows only).
    """
    n = key_cols[0].shape[0]
    keys = [masked_keys(c, mask) for c in key_cols]
    pos = jnp.arange(n, dtype=jnp.int32)
    skeys, (spos,) = _lex_sort(keys, (pos,))
    new_run = _runs(skeys)
    run_id = jnp.cumsum(new_run.astype(jnp.int32)) - 1
    # scatter group id back to original positions
    gid = jnp.zeros((n,), jnp.int32).at[spos].set(run_id)
    gsize = jax.ops.segment_sum(mask.astype(jnp.int32)[spos], run_id, num_segments=n)
    return gid, gsize[gid] * mask.astype(jnp.int32)


def group_distinct_candidates(
    key_cols: Sequence[jnp.ndarray],
    value_col: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    weight: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-row distinct values of ``value_col`` within the row's key group.

    The workhorse of FD repair (§4.1): for FD ``lhs -> rhs`` call with
    ``key_cols=lhs`` and ``value_col=rhs`` to get, for every row, the rhs
    candidate values co-occurring with its lhs, plus their frequencies — i.e.
    the numerators of ``P(rhs | lhs)``.

    Returns
    -------
    cand:     (n, k) candidate values (first ``distinct`` slots populated)
    count:    (n, k) float32 frequency of each candidate in the group
    violated: (n,)  bool — row's group has >= 2 distinct values
    overflow: ()    bool — some group had more than ``k`` distinct values
    """
    n = key_cols[0].shape[0]
    keys = [masked_keys(c, mask) for c in key_cols] + [masked_keys(value_col, mask)]
    pos = jnp.arange(n, dtype=jnp.int32)
    w = mask.astype(jnp.float32) if weight is None else jnp.where(mask, weight, 0.0)
    skeys, (spos, sw) = _lex_sort(keys, (pos, w))
    sval = skeys[-1]
    new_group = _runs(skeys[:-1])  # new lhs-key run
    new_pair = _runs(skeys)  # new (lhs, rhs) pair run
    group_id = jnp.cumsum(new_group.astype(jnp.int32)) - 1
    pair_id = jnp.cumsum(new_pair.astype(jnp.int32)) - 1
    # weight mass per distinct (lhs, rhs) pair
    pair_count = jax.ops.segment_sum(sw, pair_id, num_segments=n)
    # rank of the pair within its group: pair_id - first pair_id of the group
    first_pair = jax.ops.segment_min(pair_id, group_id, num_segments=n)
    slot = pair_id - first_pair[group_id]
    # per-group candidate table, scatter at pair starts only
    at_start = new_pair
    gcand = jnp.zeros((n, k), dtype=value_col.dtype)
    gcount = jnp.zeros((n, k), dtype=jnp.float32)
    row_idx = jnp.where(at_start & (slot < k), group_id, n)
    col_idx = jnp.minimum(slot, k - 1)
    gcand = gcand.at[row_idx, col_idx].set(sval, mode="drop")
    gcount = gcount.at[row_idx, col_idx].set(pair_count[pair_id], mode="drop")
    # distinct count per group; a group is "violated" iff >= 2 distinct values
    distinct = jax.ops.segment_max(
        jnp.where(at_start, slot + 1, 0), group_id, num_segments=n
    )
    overflow = jnp.any(distinct > k)
    # map back to original row positions
    row_group = jnp.zeros((n,), jnp.int32).at[spos].set(group_id)
    cand = gcand[row_group]
    count = gcount[row_group]
    violated = (distinct[row_group] >= 2) & mask
    cand = jnp.where(mask[:, None], cand, 0)
    count = jnp.where(mask[:, None], count, 0.0)
    return cand, count, violated, overflow


def unique_counts(
    cols: Sequence[jnp.ndarray], mask: jnp.ndarray
) -> Tuple[List[jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    """Distinct key tuples (compacted to the front) with their frequencies.

    Returns ``(values, counts, num_distinct)`` where each ``values[c]`` is a
    (n,) array whose first ``num_distinct`` entries are the distinct keys.
    """
    n = cols[0].shape[0]
    keys = [masked_keys(c, mask) for c in cols]
    skeys, _ = _lex_sort(keys, ())
    new_run = _runs(skeys)
    run_id = jnp.cumsum(new_run.astype(jnp.int32)) - 1
    # mask==False rows share the sentinel run; subtract their contribution
    valid_sorted = jax.lax.sort(
        tuple(keys) + (jnp.logical_not(mask).astype(jnp.int32),),
        dimension=0,
        is_stable=True,
        num_keys=len(keys),
    )[-1]
    counts = jax.ops.segment_sum(
        1 - valid_sorted, run_id, num_segments=n
    )
    dest = jnp.where(new_run & (counts[run_id] > 0), run_id, n)
    out_vals = [
        jnp.zeros((n,), c.dtype).at[dest].set(sk, mode="drop")
        for c, sk in zip(cols, skeys)
    ]
    out_counts = jnp.zeros((n,), jnp.int32).at[dest].set(counts[run_id], mode="drop")
    num_distinct = jnp.sum((out_counts > 0).astype(jnp.int32))
    return out_vals, out_counts, num_distinct
