"""Repair accuracy metrics (paper §7: precision / recall / F1).

precision = correct updates / total updates
recall    = correct updates / total errors

An "update" is a cell whose most-probable repaired value differs from its
original (dirty) value; it is "correct" when it equals the ground truth.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Sequence

import jax.numpy as jnp

from repro.core.relation import Relation
from repro.core.repair import repaired_value


class Accuracy(NamedTuple):
    precision: float
    recall: float
    f1: float
    updates: int
    correct: int
    errors: int


def repair_accuracy(
    rel: Relation,
    truth: Dict[str, jnp.ndarray],
    attrs: Sequence[str] | None = None,
) -> Accuracy:
    """Compare repaired values against ground-truth columns."""
    attrs = list(attrs or truth.keys())
    updates = correct = errors = 0
    for attr in attrs:
        t = truth[attr]
        orig = rel.orig.get(attr, rel.columns[attr])
        fixed = repaired_value(rel, attr)
        v = rel.valid
        err = (orig != t) & v
        upd = (fixed != orig) & v
        ok = upd & (fixed == t)
        errors += int(jnp.sum(err))
        updates += int(jnp.sum(upd))
        correct += int(jnp.sum(ok))
    precision = correct / updates if updates else 1.0
    recall = correct / errors if errors else 1.0
    f1 = (
        2 * precision * recall / (precision + recall)
        if (precision + recall) > 0
        else 0.0
    )
    return Accuracy(precision, recall, f1, updates, correct, errors)
