"""Statistics for the cost model and the full/partial decision (Algorithm 2).

Two statistic families, both precomputed once per (relation, rule) pair as in
the paper (§5.2.3: "we precompute a) the group by based on the lhs and the
rhs of the FD rules, and b) a histogram to estimate the selectivity of the
theta-join"):

* **FD group stats**: per-row dirty-group membership (used at query time to
  skip violation checks for rows in clean groups — the Fig. 11 optimization),
  the error count estimate ``epsilon`` and the candidate-set size estimate
  ``p_est`` of Inequality (1).
* **DC partition stats** (``Estimate_Errors``): the theta-join comparison
  matrix is split into ``p`` value-range partitions; per partition pair the
  boundary-range overlap yields an estimated violation count.  At query time
  the ranges overlapping the query answer give the estimated errors, the
  accuracy estimate and the support (checked-diagonal fraction) — Algorithm 2
  lines 3-10.

NOTE on Algorithm 2 line 8: the pseudocode reads "if accuracy > th then full
cleaning", but the Fig. 12 narrative is the reverse ("Daisy predicts a 23%
accuracy, therefore it decides to clean the whole dataset"; the 99%/80%
accurate runs stay partial).  We follow Fig. 12: LOW predicted accuracy
triggers the full clean.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.constraints import DC, FD
from repro.core.detect import detect_fd
from repro.core.relation import Relation


class FDStats(NamedTuple):
    dirty_row: np.ndarray  # (cap,) bool — row belongs to a violating group
    epsilon: int  # number of erroneous (violating-group) rows
    p_est: float  # avg candidate-set size among dirty groups
    n: int  # dataset rows


def fd_stats(rel: Relation, fd: FD) -> FDStats:
    """Precompute the per-rule group-by statistics (host-side arrays)."""
    det = detect_fd(rel, fd, rel.valid)
    dirty = np.asarray(det.violated)
    eps = int(dirty.sum())
    distinct = np.asarray((det.rhs_count > 0).sum(axis=1))
    p_est = float(distinct[dirty].mean()) if eps else 1.0
    return FDStats(dirty, eps, p_est, int(np.asarray(rel.num_rows())))


class DCStats(NamedTuple):
    edges: np.ndarray  # (p+1,) partition boundaries over the pivot attribute
    part_rows: np.ndarray  # (p,) rows per partition
    range_vio: np.ndarray  # (p,) estimated violations involving partition
    pivot: str  # partitioning attribute
    n: int


def dc_stats(rel: Relation, dc: DC, p: int = 16) -> DCStats:
    """``Estimate_Errors`` (Algorithm 2 lines 1-7): partition the pivot
    attribute's value range, estimate per-partition-pair conflicts from
    boundary overlaps of the remaining atoms."""
    pivot = dc.atoms[0].left
    vals = {a: np.asarray(rel.columns[a]) for a in dc.attrs}
    valid = np.asarray(rel.valid)
    pv = vals[pivot][valid]
    n = int(valid.sum())
    # quantile partitions over the pivot (the matrix row/col ranges)
    qs = np.linspace(0, 100, p + 1)
    edges = np.percentile(pv, qs)
    edges[-1] = np.nextafter(edges[-1], np.inf)
    part = np.clip(np.searchsorted(edges, pv, side="right") - 1, 0, p - 1)
    part_rows = np.bincount(part, minlength=p)

    # per-partition bounds of every atom attribute
    bounds = {}
    for a in dc.attrs:
        av = vals[a][valid]
        lo = np.full(p, np.inf)
        hi = np.full(p, -np.inf)
        for i in range(p):
            sel = part == i
            if sel.any():
                lo[i] = av[sel].min()
                hi[i] = av[sel].max()
        bounds[a] = (lo, hi)

    def overlap_frac(lo1, hi1, lo2, hi2):
        lo = max(lo1, lo2)
        hi = min(hi1, hi2)
        if not np.isfinite(lo) or not np.isfinite(hi) or hi <= lo:
            return 0.0
        w1 = max(hi1 - lo1, 1e-12)
        w2 = max(hi2 - lo2, 1e-12)
        return ((hi - lo) / w1) * ((hi - lo) / w2)

    range_vio = np.zeros(p)
    for r1 in range(p):
        for r2 in range(p):
            if part_rows[r1] == 0 or part_rows[r2] == 0:
                continue
            frac = 1.0
            for atom in dc.atoms:
                lo1, hi1 = bounds[atom.left][0][r1], bounds[atom.left][1][r1]
                lo2, hi2 = bounds[atom.right][0][r2], bounds[atom.right][1][r2]
                if atom.op in ("<", "<="):
                    possible = lo1 < hi2
                elif atom.op in (">", ">="):
                    possible = hi1 > lo2
                else:
                    possible = (lo1 <= hi2) and (lo2 <= hi1)
                if not possible:
                    frac = 0.0
                    break
                frac *= max(overlap_frac(lo1, hi1, lo2, hi2), 1e-6)
            # estimated conflicts between the two partitions
            range_vio[r1] += frac * part_rows[r1] * part_rows[r2] * 0.5
    return DCStats(edges, part_rows, range_vio, pivot, n)


class Alg2Decision(NamedTuple):
    accuracy: float
    support: float
    estimated_errors: float
    full_clean: bool


def algorithm2_decide(
    stats: DCStats,
    answer_values: np.ndarray,
    answer_size: int,
    support: float,
    threshold: float,
) -> Alg2Decision:
    """Algorithm 2 lines 3-10: given a query answer over the pivot attribute,
    estimate the accuracy of partial cleaning and decide full vs partial.

    ``support`` is the fraction of the scope's comparison space already
    checked — since the work ledger (DESIGN.md §11), the caller passes its
    strip-coverage fraction directly (strips done / total), replacing the
    old diagonal-partition bookkeeping."""
    if answer_size == 0:
        return Alg2Decision(1.0, 1.0, 0.0, False)
    lo, hi = float(answer_values.min()), float(answer_values.max())
    in_range = (stats.edges[:-1] <= hi) & (stats.edges[1:] >= lo)
    # errors from ranges OUTSIDE the answer's ranges (line 5: i != range)
    errors = float(stats.range_vio[~in_range].sum())
    accuracy = answer_size / (answer_size + errors) if (answer_size + errors) else 1.0
    support = min(max(float(support), 0.0), 1.0)
    return Alg2Decision(accuracy, support, errors, accuracy < threshold)
