"""Daisy executor: query processing woven with cleaning operators (§4-§6).

``Daisy.execute(query)`` runs the cleaning-aware plan:

1. the planner injects a cleaning step per overlapping rule (planner.py);
2. ``clean_sigma`` steps relax the (dirty) answer, detect violations over the
   correlated cluster, merge probabilistic repairs, and flag the cluster
   checked;
3. the final answer is recomputed over the now-probabilistic relation with
   possible-world semantics (a tuple qualifies iff >= 1 candidate does);
4. joins run as base-join + incremental join of the relaxation extras
   (Fig. 5), are deduped, keep lineage, and are re-checked (Def. 3 (d) —
   Lemma 5 says the re-check finds nothing; we count to prove it);
5. per-rule online cost models (Inequality (1)) accumulate the observed
   work and flip the strategy to full cleaning mid-workload (Figs. 9/14);
   DC rules consult Algorithm 2's accuracy estimate instead.

The executor owns the database state: every query returns a result AND
advances the gradually-cleaned probabilistic instance (§6).

Cleaning progress — scope versions, per-strip coverage, cold-row counts,
the Algorithm-2 support fraction — lives in ONE structure, the
``core.ledger.WorkLedger`` (DESIGN.md §11): every commit path funnels
through ``_apply``/``_mark``, which bump the ledger and refresh its
per-strip cold counts, and every consumer (the planner's strip-pruned
full cleans, the background cleaner's bounded DC increments, the service
cache's version vectors, the metrics progress export) reads the same
ledger instead of keeping its own notion of what is done.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import stats as statsmod
from repro.core.constraints import DC, FD
from repro.core.cost import CostModel, sharded_detect_cost
from repro.core.detect import detect_auto, detect_fd
from repro.core.ledger import TABLE_ROWS_RULE, WorkLedger
from repro.obs.trace import NULL_TRACER
from repro.core.operators import (
    GroupBySpec,
    JoinState,
    Query,
    dedupe_pairs,
    expected_value,
    filter_mask,
    key_candidates,
    prob_equijoin,
    _finalize_groupby,
)
from repro.core.planner import (
    CleanStep,
    PlanInfo,
    plan_query,
    probe_step,
    strip_step,
)
from repro.core.relax import relax_fd
from repro.core.relation import Relation, append_rows
from repro.core.repair import Candidates, dc_repair_candidates, fd_repair_candidates
from repro.core.setops import group_distinct_candidates
from repro.core.update import apply_candidates, mark_checked, unchecked


def _blocks_attr(blocks) -> Optional[List[int]]:
    """JSON-safe span annotation for a kernel block range: ``[lo, hi)`` as
    plain ints (ledger block bounds can be numpy scalars), None passthrough."""
    if blocks is None:
        return None
    lo, hi = blocks
    return [int(lo), int(hi)]


@dataclasses.dataclass
class DaisyConfig:
    k: int = 8
    join_capacity: int = 8192
    join_row_block: int = 2048
    dc_partitions: int = 16
    dc_block: int = 256
    accuracy_threshold: float = 0.5
    expected_queries: int = 50
    use_cost_model: bool = True
    collect_stats: bool = True
    max_relax_iters: Optional[int] = None
    lemma1_fast_path: bool = False
    # sharded detection (DESIGN.md §8): with a mesh set, equality-keyed
    # rules detect over shuffle_by_key (detect_shards logical shards;
    # None -> the mesh's data-parallel extent).  Results are bit-identical
    # to the dense scans, so this is purely an execution-strategy knob.
    mesh: Optional[object] = None
    detect_shards: Optional[int] = None
    # work-ledger strip size (DESIGN.md §11): rows per partition strip, the
    # grain background DC increments and partial-work reuse operate at.
    # None -> one detect tile (dc_block); always rounded up to a whole
    # number of tiles so strips align with the dc_pairs grid.
    strip_rows: Optional[int] = None
    # compressed atom encodings (DESIGN.md §15): let the DC detect planner
    # scan int8/bf16/rank-code columns where the exactness proof holds.
    # Results are bit-identical either way — this is a bandwidth knob.
    kernel_encodings: bool = True


@dataclasses.dataclass
class StepReport:
    rule: str
    table: str
    mode: str  # incremental | full | strip | skipped
    detect_path: str = "dense"  # dense | sharded
    answer_size: int = 0
    extra: int = 0
    repaired: int = 0
    # comparison-space size this step's detects scanned: rows x partners for
    # DCs, scope rows for the FD group-by — the partial-work-reuse gauge
    # (benchmarks/serve_bg_warmup.py gates that a half-cleaned scope costs
    # strictly fewer pairs than a cold one, DESIGN.md §11)
    detect_pairs: int = 0
    # kernel launch geometry (DESIGN.md §15): DC tile pairs this step's
    # scans launched vs skipped by the ledger-masked worklist — the
    # block-sparsity gauge next to the row-level detect_pairs one
    tiles_launched: int = 0
    tiles_skipped: int = 0
    relax_iterations: int = 0
    relax_converged: bool = True
    alg2_accuracy: float = 1.0
    alg2_support: float = 0.0

    def asdict(self) -> Dict[str, object]:
        """Plain-scalar dict (all fields are host ints/floats/strs/bools), so
        service metrics can ship reports through json without touching jax."""
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ExecReport:
    steps: List[StepReport] = dataclasses.field(default_factory=list)
    notes: List[str] = dataclasses.field(default_factory=list)
    result_size: int = 0
    recheck_violations: int = 0
    join_overflow: bool = False

    def asdict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class DaisyResult:
    mask: Optional[jnp.ndarray] = None  # SP result (mask over base table)
    join: Optional[JoinState] = None  # join lineage
    groups: Optional[Dict[str, jnp.ndarray]] = None  # group-by output
    report: ExecReport = dataclasses.field(default_factory=ExecReport)


@dataclasses.dataclass
class IngestReport:
    """What one ``Daisy.ingest`` call did (DESIGN.md §12): where the rows
    landed, whether the relation grew, which strips went fresh, and which
    rule scopes queued an ingest-delta for their next cleaning step."""

    table: str
    rows: int  # appended row count
    start: int  # row index of the first appended row
    capacity_before: int
    capacity: int
    grown: bool
    fresh_strips: int  # strips (per rule scope, max over rules) marked fresh
    pending_rules: List[str] = dataclasses.field(default_factory=list)
    versions: Dict[str, int] = dataclasses.field(default_factory=dict)

    def asdict(self) -> Dict[str, object]:
        """Plain-scalar dict for service metrics / json."""
        return dataclasses.asdict(self)


class Daisy:
    """Query-driven cleaning engine (the system of §6, JAX-native)."""

    def __init__(
        self,
        db: Dict[str, Relation],
        rules: Dict[str, Sequence[FD | DC]],
        config: DaisyConfig | None = None,
        tracer=None,
    ):
        self.db = dict(db)
        self.rules = {t: list(rs) for t, rs in rules.items()}
        self.config = config or DaisyConfig()
        # observability seam (DESIGN.md §13): spans around every clean phase
        # (relax / detect / repair / mark), execute, and ingest.  Defaults to
        # the strict no-op tracer, so untraced runs pay only the call site.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.stats: Dict[Tuple[str, str], object] = {}
        self.cost: Dict[Tuple[str, str], CostModel] = {}
        # serving hooks (DESIGN.md §9/§10): a monotone version counter bumped
        # on every candidate-merge / checked-bit commit (the service cache's
        # invalidation signal), cumulative detect/repair invocation and
        # pair counters (the work the cache amortizes), the last observed
        # sharded routing per rule (feeds the cost model and the background
        # priority model), and a re-entrancy lock so concurrent sessions can
        # share one executor without torn read-modify-writes of ``self.db``.
        # Per-scope versions and strip coverage live in the work ledger
        # (DESIGN.md §11) — the executor bumps it on every commit.
        self._clean_version = 0
        self.sharded_info: Dict[Tuple[str, str], object] = {}
        self.detect_calls = 0
        self.repair_calls = 0
        self.detect_pairs = 0
        self.tiles_launched = 0
        self.tiles_skipped = 0
        self._lock = threading.RLock()
        self.ledger = WorkLedger(self.config.strip_rows, self.config.dc_block)
        if self.config.collect_stats:
            self._collect_stats()
        for table, rs in self.rules.items():
            for rule in rs:
                self.ledger.register(
                    table, rule.name, self.db[table].capacity,
                    np.asarray(self.cold_rows(table, rule.name)),
                )

    @property
    def clean_version(self) -> int:
        """Monotone clean-state version: equal versions guarantee bit-identical
        query answers (the cleaning steps of a re-executed query skip, so the
        answer is a pure function of the instance — the cache soundness
        contract, asserted in tests/test_service.py)."""
        return self._clean_version

    @property
    def lock(self) -> threading.RLock:
        """The executor's re-entrancy lock.  Callers that must read versioned
        state and act on it atomically with respect to a concurrent cleaner —
        the service layer's cache-lookup-or-execute, the background cleaner's
        increments — take this lock; ``execute`` re-acquires it re-entrantly."""
        return self._lock

    def scope_version(self, table: str, rule_name: str) -> int:
        """Monotone per-(table, rule) version: bumped exactly when a commit
        for THAT rule advances the instance.  Equal scope versions over a
        query's overlapping rules imply a bit-identical answer (DESIGN.md
        §10/§11) — the refinement the service cache keys on so background
        cleaning of one rule never invalidates another rule's entries.
        Backed by the work ledger."""
        return self.ledger.version(table, rule_name)

    def scope_versions(self, deps: Sequence[Tuple[str, str]]) -> Tuple[int, ...]:
        """Version vector over a dependency list of (table, rule) pairs (the
        service cache's key half; read under ``lock`` when a background
        cleaner may be committing concurrently)."""
        return self.ledger.versions(deps)

    def _apply(self, rel: Relation, deltas, table: str, rule_name: str) -> Relation:
        """``apply_candidates`` + version bumps (every overlay merge advances
        the probabilistic instance globally and for the committing rule)."""
        self._clean_version += 1
        self.ledger.bump(table, rule_name)
        return apply_candidates(rel, deltas)

    def _mark(self, rel: Relation, table: str, rule_name: str, scope) -> Relation:
        """``mark_checked`` + version bump + ledger coverage refresh: checked
        bits steer future cleaning, so they are part of the versioned state,
        and they are exactly what moves strip coverage (DESIGN.md §11)."""
        with self.tracer.span("clean.mark", rule=rule_name, table=table):
            self._clean_version += 1
            rel = mark_checked(rel, rule_name, scope)
            self.ledger.commit(
                table, rule_name, np.asarray(self._cold_mask(rel, table, rule_name))
            )
            cm = self.cost.get((table, rule_name))
            if cm is not None:
                cm.observe_progress(self.ledger.scope(table, rule_name).cold_fraction)
        return rel

    # ------------------------------------------------------------ statistics
    def _collect_stats(self) -> None:
        """Precompute per-(table, rule) statistics (§5.2.3, §7/Fig 11)."""
        for table, rules in self.rules.items():
            rel = self.db[table]
            n = int(np.asarray(rel.num_rows()))
            for rule in rules:
                key = (table, rule.name)
                if isinstance(rule, FD):
                    st = statsmod.fd_stats(rel, rule)
                    df = float(n)  # hash/sort group-by detection cost
                    self.stats[key] = st
                    self.cost[key] = CostModel(
                        n=n,
                        epsilon=st.epsilon,
                        p=st.p_est,
                        df=df,
                        expected_queries=self.config.expected_queries,
                    )
                else:
                    st = statsmod.dc_stats(rel, rule, p=self.config.dc_partitions)
                    df = n * n / max(self.config.dc_partitions, 1)
                    self.stats[key] = st
                    self.cost[key] = CostModel(
                        n=n,
                        epsilon=int(st.range_vio.sum()),
                        p=2.0,
                        df=df,
                        expected_queries=self.config.expected_queries,
                    )

    def _refresh_stats(self, table: str) -> None:
        """Recompute one table's per-rule statistics after an append and
        fold the new instance size into the existing cost models in place
        (histories and the switched flag survive: an append changes the
        economics of FUTURE work, not what already happened)."""
        rel = self.db[table]
        n = int(np.asarray(rel.num_rows()))
        for rule in self.rules.get(table, ()):
            key = (table, rule.name)
            cm = self.cost.get(key)
            if isinstance(rule, FD):
                st = statsmod.fd_stats(rel, rule)
                self.stats[key] = st
                if cm is not None:
                    cm.n, cm.df = n, float(n)
                    cm.epsilon, cm.p = st.epsilon, st.p_est
            else:
                st = statsmod.dc_stats(rel, rule, p=self.config.dc_partitions)
                self.stats[key] = st
                if cm is not None:
                    cm.n = n
                    cm.df = n * n / max(self.config.dc_partitions, 1)
                    cm.epsilon = int(st.range_vio.sum())

    # ---------------------------------------------------------------- ingest
    def ingest(self, table: str, rows: Mapping[str, np.ndarray]) -> IngestReport:
        """Append rows into a live table — THE streaming-ingest entry point
        (DESIGN.md §12).

        Under ``lock``, in order: the rows land in the relation's spare
        capacity (growing via ``next_pow2`` when full; every pre-existing
        overlay/checked/cand array is preserved bit-for-bit); the table's
        statistics and cost models refresh; and each rule scope's work
        ledger extends — the fresh rows' strips read as COLD and FRESH,
        with no existing checked state invalidated.  Scopes that already
        hold checked rows queue a ``PendingIngest`` delta: the next
        cleaning step touching the scope (foreground or background) gives
        those rows the fresh partners' evidence in O(new x all) work
        instead of a stop-the-world re-clean (``_process_pending``).

        Cache invalidation is exact: only the table's ``TABLE_ROWS_RULE``
        pseudo-scope version bumps here (rule scope versions move when
        their deltas merge), so every cached answer reading this table
        goes stale exactly once and entries over other tables survive.
        """
        with self._lock, self.tracer.span("daisy.ingest", table=table) as sp:
            report = self._ingest_locked(table, rows)
            sp.set(rows=report.rows, grown=report.grown)
            return report

    def _ingest_locked(
        self, table: str, rows: Mapping[str, np.ndarray]
    ) -> IngestReport:
        with self._lock:  # re-entrant; ``ingest`` already holds it
            if table not in self.db:
                raise KeyError(f"unknown table {table!r}")
            rel = self.db[table]
            cap_before = rel.capacity
            # snapshot per-rule ingest-delta inputs BEFORE the append: which
            # rows are checked, and (FDs) which rows were statically dirty —
            # the had-evidence/checked-while-clean classifier (DESIGN.md §12)
            had_checked: Dict[str, np.ndarray] = {}
            old_dirty: Dict[str, np.ndarray] = {}
            for rule in self.rules.get(table, ()):
                ch = rel.checked.get(rule.name)
                if ch is None:
                    continue
                ch_np = np.asarray(ch)
                if ch_np.any():
                    had_checked[rule.name] = ch_np
                    if isinstance(rule, FD):
                        st = self.stats.get((table, rule.name))
                        dirty = (
                            st.dirty_row if st is not None
                            else statsmod.fd_stats(rel, rule).dirty_row
                        )
                        old_dirty[rule.name] = np.asarray(dirty, dtype=bool)
            new_rel, start = append_rows(rel, rows)
            n_new = int(np.asarray(new_rel.valid).sum()) - start
            report = IngestReport(
                table=table, rows=n_new, start=start,
                capacity_before=cap_before, capacity=new_rel.capacity,
                grown=new_rel.capacity != cap_before, fresh_strips=0,
            )
            if n_new == 0:
                return report
            self.db[table] = new_rel
            hi = start + n_new
            if self.config.collect_stats:
                self._refresh_stats(table)
            cap = new_rel.capacity
            for rule in self.rules.get(table, ()):
                checked = had_checked.get(rule.name)
                od = old_dirty.get(rule.name)
                if checked is not None and checked.shape[0] < cap:
                    checked = np.pad(checked, (0, cap - checked.shape[0]))
                if od is not None and od.shape[0] < cap:
                    od = np.pad(od, (0, cap - od.shape[0]))
                cold = np.asarray(self._cold_mask(new_rel, table, rule.name))
                scope = self.ledger.record_ingest(
                    table, rule.name, cap, cold, start, hi,
                    checked=checked, old_dirty=od,
                )
                report.fresh_strips = max(report.fresh_strips, len(scope.fresh))
                if scope.pending:
                    report.pending_rules.append(rule.name)
                cm = self.cost.get((table, rule.name))
                if cm is not None:
                    cm.observe_progress(scope.cold_fraction)
            self.ledger.bump(table, TABLE_ROWS_RULE)
            report.versions = {
                rule.name: self.ledger.version(table, rule.name)
                for rule in self.rules.get(table, ())
            }
            report.versions[TABLE_ROWS_RULE] = self.ledger.version(
                table, TABLE_ROWS_RULE
            )
            return report

    # -------------------------------------------------------------- planning
    def _want_full(self) -> Dict[Tuple[str, str], bool]:
        if not self.config.use_cost_model:
            return {}
        return {key: cm.should_switch_to_full() for key, cm in self.cost.items()}

    # ---------------------------------------------------------- detect path
    def _detect_mesh(self, step: CleanStep):
        """The mesh to detect on for this step: the configured mesh when the
        planner marked the rule shardable, else None (dense scan)."""
        return self.config.mesh if step.shardable else None

    # ------------------------------------------------- background increments
    def _rule_named(self, table: str, rule_name: str):
        for rule in self.rules.get(table, ()):
            if rule.name == rule_name:
                return rule
        raise KeyError(f"no rule {rule_name!r} on table {table!r}")

    def _cold_mask(self, rel: Relation, table: str, rule_name: str) -> jnp.ndarray:
        """Cold rows of ``rel`` for a rule: unchecked rows, intersected for
        FDs with the statically-known dirty groups (clean groups skip via
        the Fig. 11 dirty-group gate without ever being marked, so they are
        not background work either).  The single definition the ledger's
        per-strip counts are folded from (DESIGN.md §11)."""
        rule = self._rule_named(table, rule_name)
        cold = unchecked(rel, rule_name)
        st = self.stats.get((table, rule_name))
        if isinstance(rule, FD) and st is not None:
            cold = cold & jnp.asarray(st.dirty_row)
        return cold

    def cold_rows(self, table: str, rule_name: str) -> jnp.ndarray:
        """Rows a first-touch foreground query would still pay detect work
        for (see ``_cold_mask``).  Read under ``lock`` if a cleaner may be
        committing concurrently."""
        return self._cold_mask(self.db[table], table, rule_name)

    def cold_count(self, table: str, rule_name: str) -> int:
        """Host count of ``cold_rows`` — a ledger read (no device sync):
        the per-strip counts are refreshed at every ``_mark`` commit.  A
        scope the ledger has never sized (a rule appended to a live Daisy)
        is registered from the real cold mask on first read."""
        scope = self.ledger.scope(table, rule_name)
        cap = self.db[table].capacity
        if scope is None or scope.capacity < cap:
            scope = self.ledger.register(
                table, rule_name, cap,
                np.asarray(self.cold_rows(table, rule_name)),
            )
        return scope.cold_count

    def _fd_increment_seed(
        self,
        rel: Relation,
        fd: FD,
        cold: jnp.ndarray,
        max_rows: Optional[int],
        prefer: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        """Whole-lhs-group seed mask for one background FD increment: the
        first (ascending group id) cold groups whose valid rows total at
        least ``max_rows`` (always >= 1 group).  Groups are taken whole —
        candidates are per-group evidence, so a split group would merge
        different candidate sets than the foreground path (DESIGN.md §10).
        ``prefer`` front-loads groups intersecting that mask (the freshly
        ingested strips, DESIGN.md §12) ahead of the ascending sweep."""
        valid = np.asarray(rel.valid)
        cold_np = np.asarray(cold)
        gid = np.zeros(valid.shape[0], dtype=np.int64)
        for attr in fd.lhs:
            _, inv = np.unique(np.asarray(rel.columns[attr]), return_inverse=True)
            gid = gid * (int(inv.max()) + 1) + inv
        # densify the combined key so per-group sizes are one bincount pass
        _, gid = np.unique(gid, return_inverse=True)
        cold_groups = np.unique(gid[cold_np])
        if prefer is not None:
            pref = np.unique(gid[np.asarray(prefer) & cold_np])
            rest = cold_groups[~np.isin(cold_groups, pref)]
            cold_groups = np.concatenate([pref, rest])
        if max_rows is not None:
            sizes = np.bincount(gid[valid], minlength=int(gid.max()) + 1)
            cum = np.cumsum(sizes[cold_groups])
            # smallest prefix of cold groups reaching max_rows (>= 1 group)
            cut = int(np.searchsorted(cum, max_rows)) + 1
            cold_groups = cold_groups[:cut]
        return jnp.asarray(valid & np.isin(gid, cold_groups))

    def clean_scope_increment(
        self,
        table: str,
        rule_name: str,
        max_rows: Optional[int] = None,
        max_strips: Optional[int] = None,
    ) -> Optional[StepReport]:
        """One preemptible background-cleaning increment for a rule scope
        (DESIGN.md §10/§11); returns its ``StepReport`` or ``None`` when the
        scope is already warm.

        Runs under ``lock`` and commits through the same ``_apply``/``_mark``
        path as foreground steps, so every increment bumps the global and
        per-scope ledger versions exactly like a query would.  FDs clean up
        to ``max_rows`` cold rows per call, seeded on whole lhs groups and
        run through the foreground incremental pipeline (relax closure,
        detect, repair, mark) — by Lemma 4 the accumulated state is the one
        the same sweeps issued as queries would reach.  DCs clean up to
        ``max_strips`` ledger strips per call (strip x rest-of-dataset
        scans through the strip-scoped kernel entry; ``None`` sweeps every
        cold strip, i.e. the remaining full clean in one increment) — the
        strip union is row-for-row identical to one full pass (DESIGN.md
        §11), so a DC increment's preemption latency is now one strip scan,
        exactly like the FD ``max_rows`` bound.  Cost-model histories are
        not polluted (``record_cost=False``)."""
        with self._lock:
            rule = self._rule_named(table, rule_name)
            report = ExecReport()
            # ingest-deltas first (DESIGN.md §12): a scope can look warm
            # (zero cold rows) while its checked rows are stale against
            # fresh partners — a pending-only increment still reports.
            pending_rep = self._process_pending(table, rule, report)
            rel = self.db[table]
            cold = self.cold_rows(table, rule_name)
            if not bool(np.asarray(jnp.any(cold))):
                return pending_rep
            if isinstance(rule, FD):
                scope_l = self.ledger.scope(table, rule_name)
                prefer = None
                if scope_l is not None and scope_l.fresh:
                    prefer = jnp.asarray(
                        scope_l.strip_mask(sorted(scope_l.fresh))
                    )
                seed = self._fd_increment_seed(
                    rel, rule, cold, max_rows, prefer=prefer
                )
                self._clean_fd(
                    probe_step(table, rule), report,
                    answer_override=seed, record_cost=False,
                )
            else:
                # register-and-refresh from the cold mask just computed, so a
                # rule appended to a live Daisy (lazily-created scope) hands
                # the strip engine its real cold strips
                scope = self.ledger.register(
                    table, rule_name, rel.capacity, np.asarray(cold)
                )
                strips = scope.cold_strips(fresh_first=True)
                if max_strips is not None:
                    strips = strips[: max(int(max_strips), 1)]
                self._clean_dc(
                    strip_step(table, rule, strips), report, record_cost=False
                )
            return report.steps[-1] if report.steps else None

    # -------------------------------------------------------- ingest deltas
    def _process_pending(
        self, table: str, rule, report: Optional[ExecReport] = None
    ) -> Optional[StepReport]:
        """Drain a scope's queued ingest-deltas (DESIGN.md §12): for every
        append since the scope's last cleaning step, give the rows that were
        CHECKED at append time the evidence the fresh rows owe them — an
        O(checked x fresh) scan, never a re-clean.  Runs at the top of every
        cleaning path (foreground steps, background increments) BEFORE any
        skip gate, because a scope can look warm while its checked rows are
        stale against fresh partners.  No rows are marked here: the fresh
        rows stay cold and collect their own full evidence at their first
        clean, so checked bits are never invalidated by an append."""
        pendings = self.ledger.take_pending(table, rule.name)
        if not pendings:
            return None
        rep = StepReport(rule.name, table, "ingest-delta")
        with self.tracer.span(
            "clean.ingest_delta", rule=rule.name, table=table,
            deltas=len(pendings),
        ) as sp:
            if isinstance(rule, FD):
                self._ingest_delta_fd(table, rule, pendings, rep)
            else:
                self._ingest_delta_dc(table, rule, pendings, rep)
            sp.set(pairs=rep.detect_pairs)
        if report is not None:
            report.steps.append(rep)
        return rep

    def _ingest_delta_fd(
        self, table: str, fd: FD, pendings, rep: StepReport
    ) -> None:
        """FD ingest-delta: re-derive candidate evidence for checked rows
        whose lhs group gained fresh members, processing appends in time
        order against the instance each one saw (``rows < hi`` masking
        makes multi-append draining exact).

        Per append, over the relaxation closure of the fresh rows' groups:

        * checked rows that were DIRTY at append time already merged their
          group's old evidence — they get the FRESH-WEIGHTED counts only
          (each fresh member contributes weight 1, old members 0: by Lemma 4
          the sum equals one merge over the whole group);
        * checked rows that were CLEAN at append time (checked-while-clean:
          marked by a pass whose detection saw no violation, so no overlay)
          and are violated NOW get the FULL group counts — their first and
          only evidence merge, identical to what a from-scratch clean gives.

        Zero-weight candidate slots merge as bitwise no-ops, so rows whose
        group gained nothing are untouched."""
        k = self.config.k
        for ent in pendings:
            rel = self.db[table]
            cap = rel.capacity
            pos = np.arange(cap)
            checked = np.zeros(cap, dtype=bool)
            c = np.asarray(ent.checked, dtype=bool)
            checked[: min(c.shape[0], cap)] = c[:cap]
            dirty = np.zeros(cap, dtype=bool)
            if ent.old_dirty is not None:
                d = np.asarray(ent.old_dirty, dtype=bool)
                dirty[: min(d.shape[0], cap)] = d[:cap]
            fresh = jnp.asarray((pos >= ent.lo) & (pos < ent.hi))
            # the instance THIS append saw: rows below its high-water mark
            rel_hi = dataclasses.replace(
                rel, valid=rel.valid & jnp.asarray(pos < ent.hi)
            )
            seed = fresh & rel_hi.valid
            if not bool(np.asarray(jnp.any(seed))):
                continue
            self.detect_calls += 1
            res = relax_fd(
                rel_hi, seed, fd,
                max_iters=self.config.max_relax_iters, use_rhs=True,
            )
            scope = (seed | res.extra) & rel_hi.valid
            scope_n = int(np.asarray(jnp.sum(scope)))
            rep.answer_size += int(np.asarray(jnp.sum(seed)))
            rep.extra += int(np.asarray(jnp.sum(res.extra)))
            rep.detect_pairs += scope_n  # group-by is O(scope)
            self.detect_pairs += scope_n
            lhs_cols = [rel.columns[a] for a in fd.lhs]
            rhs_col = rel.columns[fd.rhs]
            wt = jnp.where(fresh, jnp.float32(1.0), jnp.float32(0.0))
            full_v, full_n, violated, _ = group_distinct_candidates(
                lhs_cols, rhs_col, scope, k
            )
            fresh_v, fresh_n, _, _ = group_distinct_candidates(
                lhs_cols, rhs_col, scope, k, weight=wt
            )
            lhs_single = len(fd.lhs) == 1
            if lhs_single:
                lfull_v, lfull_n, _, _ = group_distinct_candidates(
                    [rhs_col], lhs_cols[0], scope, k
                )
                lfresh_v, lfresh_n, _, _ = group_distinct_candidates(
                    [rhs_col], lhs_cols[0], scope, k, weight=wt
                )
            checked_j = jnp.asarray(checked)
            t_fresh = checked_j & violated & jnp.asarray(dirty) & scope
            t_full = checked_j & violated & ~jnp.asarray(dirty) & scope
            kinds = jnp.zeros(full_v.shape, jnp.int8)
            deltas = []
            for rows_mask, rv, rn, lv, ln in (
                (t_fresh, fresh_v, fresh_n,
                 *((lfresh_v, lfresh_n) if lhs_single else (None, None))),
                (t_full, full_v, full_n,
                 *((lfull_v, lfull_n) if lhs_single else (None, None))),
            ):
                if not bool(np.asarray(jnp.any(rows_mask))):
                    continue
                deltas.append((fd.rhs, Candidates(rv, rn, kinds, rows_mask)))
                if lv is not None:
                    deltas.append((fd.lhs[0], Candidates(lv, ln, kinds, rows_mask)))
            if deltas:
                self.repair_calls += 1
                rep.repaired += int(np.asarray(jnp.sum(t_fresh | t_full)))
                self.db[table] = self._apply(rel, deltas, table, fd.name)

    def _ingest_delta_dc(
        self, table: str, dc: DC, pendings, rep: StepReport
    ) -> None:
        """DC ingest-delta: one [checked x fresh] matrix strip per append —
        rows already marked checked absorb the appended partners' evidence
        through the col-scoped kernel entry, O(checked x new) pairs instead
        of the O(n^2) full grid.  The fresh rows themselves stay cold: their
        own [fresh x all] evidence arrives at their first (strip or full)
        clean, which — both scopes living below the append's high-water
        mark — never re-touches a checked strip (benchmark gate (c))."""
        block = self.config.dc_block
        cm = self.cost.get((table, dc.name))
        for ent in pendings:
            rel = self.db[table]
            cap = rel.capacity
            pos = np.arange(cap)
            checked = np.zeros(cap, dtype=bool)
            c = np.asarray(ent.checked, dtype=bool)
            checked[: min(c.shape[0], cap)] = c[:cap]
            fresh = jnp.asarray((pos >= ent.lo) & (pos < ent.hi))
            row_scope = jnp.asarray(checked) & rel.valid
            if not bool(np.asarray(jnp.any(row_scope & rel.valid))):
                continue
            row_block_ids = self._active_blocks(row_scope)
            col_blocks = (ent.lo // block, -(-ent.hi // block))
            rep.answer_size += int(np.asarray(jnp.sum(fresh & rel.valid)))
            # dense scan only: the sharded path has no partner-side
            # restriction, and a delta is small by construction
            rel, det = self._dc_detect_repair(
                rel, dc, row_scope, fresh, None, None, cm, rep,
                col_blocks=col_blocks, row_block_ids=row_block_ids,
            )
            rep.repaired += int(np.asarray(jnp.sum(
                ((det.t1_count > 0) | (det.t2_count > 0)) & row_scope
            )))
            self.db[table] = rel

    # ------------------------------------------------------------- FD steps
    def _clean_fd(
        self,
        step: CleanStep,
        report: ExecReport,
        answer_override: Optional[jnp.ndarray] = None,
        record_cost: bool = True,
    ) -> None:
        """One FD cleaning step.  ``answer_override`` substitutes an explicit
        answer mask for the predicate filter (the background cleaner's
        cold-group sweeps, DESIGN.md §10 — the step then runs exactly the
        relax/detect/repair/mark pipeline a query selecting those rows
        would); ``record_cost=False`` keeps background work out of the
        per-query cost-model history."""
        table, fd = step.table, step.rule
        self._process_pending(table, fd, report)
        rel = self.db[table]
        cm = self.cost.get((table, fd.name))
        st = self.stats.get((table, fd.name))
        rep = StepReport(fd.name, table, step.mode)

        mark_scope = None
        if step.mode == "full":
            # partial-work reuse (DESIGN.md §11): detect only lhs groups that
            # still hold cold rows, taken whole (candidates are per-group
            # evidence), instead of re-scanning groups earlier passes —
            # foreground or background — already covered.  The mark still
            # covers the whole relation: skipped groups are either fully
            # checked already or statically clean (detection over them merges
            # nothing), which is exactly what the unshrunk scan committed.
            cold = self._cold_mask(rel, table, fd.name)
            if bool(np.asarray(jnp.any(cold))):
                scope = self._fd_increment_seed(rel, fd, cold, None)
            else:
                scope = rel.valid
            mark_scope = rel.valid
            rep.answer_size = int(np.asarray(jnp.sum(scope)))
        else:
            answer = (
                answer_override
                if answer_override is not None
                else filter_mask(rel, step.preds)
            )
            rep.answer_size = int(np.asarray(jnp.sum(answer)))
            # Fig. 11 skip: answer touches no dirty group and nothing unchecked
            if st is not None:
                dirty_hit = bool(
                    np.asarray(
                        jnp.any(answer & jnp.asarray(st.dirty_row) & unchecked(rel, fd.name))
                    )
                )
                if not dirty_hit:
                    rep.mode = "skipped"
                    report.steps.append(rep)
                    if cm and record_cost:
                        cm.record(rep.answer_size, 0, 0.0, 0)
                    return
            with self.tracer.span(
                "clean.relax", rule=fd.name, table=table
            ) as sp:
                res = relax_fd(
                    rel,
                    answer,
                    fd,
                    max_iters=self.config.max_relax_iters,
                    use_rhs=step.use_rhs,
                )
                scope = answer | res.extra
                rep.extra = int(np.asarray(jnp.sum(res.extra)))
                rep.relax_iterations = int(np.asarray(res.iterations))
                rep.relax_converged = bool(np.asarray(res.converged))
                sp.set(extra=rep.extra, iterations=rep.relax_iterations)

        repair_scope = scope & unchecked(rel, fd.name)
        if not bool(np.asarray(jnp.any(repair_scope))):
            # everything in scope already checked for this rule (e.g. the
            # post-clean query phase of the offline baseline) — skip the
            # detection/repair/merge entirely.
            rep.mode = "skipped"
            report.steps.append(rep)
            if cm and record_cost:
                cm.record(rep.answer_size, rep.extra, 0.0, 0)
            return
        mesh = self._detect_mesh(step)
        self.detect_calls += 1
        rep.detect_pairs = int(np.asarray(jnp.sum(scope)))  # group-by is O(scope)
        self.detect_pairs += rep.detect_pairs
        with self.tracer.span(
            "clean.detect", rule=fd.name, table=table, mode=rep.mode,
            pairs=rep.detect_pairs,
        ) as sp:
            det, sinfo = detect_auto(
                rel, fd, scope, k=self.config.k,
                mesh=mesh, n_shards=self.config.detect_shards,
                strip_rows=self.ledger.strip_rows, tracer=self.tracer,
            )
            if sinfo is not None:
                rep.detect_path = "sharded"
                self._observe_sharded(table, fd.name, sinfo, cm)
            sp.set(path=rep.detect_path)
        self.repair_calls += 1
        with self.tracer.span("clean.repair", rule=fd.name, table=table) as sp:
            deltas = fd_repair_candidates(rel, fd, det, repair_scope)
            rep.repaired = int(np.asarray(jnp.sum(det.violated & repair_scope)))
            rel = self._apply(rel, deltas, table, fd.name)
            sp.set(repaired=rep.repaired)
        rel = self._mark(
            rel, table, fd.name, scope if mark_scope is None else mark_scope
        )
        self.db[table] = rel
        if cm and record_cost:
            d_i = float(np.asarray(jnp.sum(scope)))
            cm.record(rep.answer_size, rep.extra, d_i, rep.repaired)
            if step.mode == "full":
                cm.mark_switched()
        report.steps.append(rep)

    def _observe_sharded(self, table: str, rule_name: str, info, cm) -> None:
        """Record a sharded routing's ``ShardedDetectInfo`` and feed its
        observed cost to the rule's cost model, so the full/partial decision
        (and the background priority model, DESIGN.md §10) price the shuffle
        path the executor will actually take."""
        self.sharded_info[(table, rule_name)] = info
        if cm is not None:
            cm.observe_detect_cost(sharded_detect_cost(info, n_rows=cm.n))

    # ------------------------------------------------------------- DC steps
    def _dc_detect_repair(
        self, rel, dc, row_scope, col_scope, row_blocks, mesh, cm, rep,
        col_blocks=None, row_block_ids=None, col_block_ids=None,
    ):
        """One detect + repair-candidate pass of the DC increment engine:
        scan ``row_scope x col_scope`` (strip-scoped to ``row_blocks`` /
        ``col_blocks``, or block-sparse via ``row_block_ids`` /
        ``col_block_ids``, DESIGN.md §15), merge the role fixes for
        ``row_scope`` rows, account the scanned comparison space and the
        launch geometry.  Returns ``(rel, detect_result)``."""
        table = rep.table
        self.detect_calls += 1
        rows = int(np.asarray(jnp.sum(row_scope & rel.valid)))
        cols = int(np.asarray(jnp.sum(col_scope & rel.valid)))
        rep.detect_pairs += rows * cols
        self.detect_pairs += rows * cols
        with self.tracer.span(
            "clean.detect", rule=dc.name, table=table, mode=rep.mode,
            pairs=rows * cols,
            row_blocks=_blocks_attr(row_blocks),
            col_blocks=_blocks_attr(col_blocks),
            row_block_ids=None if row_block_ids is None else len(row_block_ids),
            col_block_ids=None if col_block_ids is None else len(col_block_ids),
        ) as sp:
            det, sinfo = detect_auto(
                rel, dc, row_scope, col_scope, block=self.config.dc_block,
                mesh=mesh, n_shards=self.config.detect_shards,
                row_blocks=row_blocks, col_blocks=col_blocks,
                row_block_ids=row_block_ids, col_block_ids=col_block_ids,
                strip_rows=self.ledger.strip_rows, tracer=self.tracer,
                encode=self.config.kernel_encodings,
            )
            if sinfo is not None:
                rep.detect_path = "sharded"
                self._observe_sharded(table, dc.name, sinfo, cm)
            launched = int(getattr(det, "tiles_launched", 0))
            skipped = max(int(getattr(det, "tiles_total", 0)) - launched, 0)
            rep.tiles_launched += launched
            rep.tiles_skipped += skipped
            self.tiles_launched += launched
            self.tiles_skipped += skipped
            scope = self.ledger.scope(table, dc.name)
            if scope is not None:
                scope.note_tiles(launched, skipped)
            if cm is not None and rep.mode == "full" and det.tiles_total:
                # the measured tile-level sparsity of a full-mode scan —
                # the cost model's detect term refines on it (DESIGN.md §15)
                cm.observe_tile_sparsity(launched / det.tiles_total)
            sp.set(
                path=rep.detect_path,
                tiles_launched=launched, tiles_skipped=skipped,
            )
        self.repair_calls += 1
        with self.tracer.span("clean.repair", rule=dc.name, table=table):
            deltas = dc_repair_candidates(rel, dc, det, row_scope, k=self.config.k)
            rel = self._apply(rel, deltas, table, dc.name)
        return rel, det

    def _active_blocks(self, mask) -> Optional[np.ndarray]:
        """EXACT kernel-grid block ids holding the mask's nonzero rows
        (None for an empty mask) — the block-sparse worklist side for
        answer-shaped scans (DESIGN.md §15): blocks between two active runs
        are absent from the launch, not merely scope-pruned inside it."""
        idx = np.flatnonzero(np.asarray(mask))
        if idx.size == 0:
            return None
        return np.unique(idx // self.config.dc_block).astype(np.int32)

    def _clean_dc(
        self, step: CleanStep, report: ExecReport, record_cost: bool = True
    ) -> None:
        """One DC cleaning step through the strip-grained increment engine
        (DESIGN.md §11).  Modes:

        * ``auto`` — Algorithm 2 resolves full vs incremental at execution
          time; its support input is the ledger's strip-coverage fraction;
        * ``incremental`` — the answer's matrix strip [answer x rest] plus
          the partner strip [rest x answer] (§4.2);
        * ``full`` — the REMAINING cold strips x the whole dataset: strips
          earlier passes (foreground or background) covered are skipped,
          both in the scope mask and in the kernel grid (partial-work
          reuse, the §11 refinement of the all-or-nothing full pass — and
          what makes a full clean after background progress merge each
          row's evidence exactly once);
        * ``strip`` — an explicit cold-strip subset (``step.strips``): the
          background cleaner's bounded-latency increment.  A strip sweep
          that covers every cold strip IS the remaining full clean and is
          reported as mode ``full``.

        ``record_cost=False`` keeps background work out of the per-query
        cost-model history (a scope-completing sweep still marks the rule
        switched: after it, nothing is left for the switch to buy)."""
        table, dc = step.table, step.rule
        self._process_pending(table, dc, report)
        rel = self.db[table]
        key = (table, dc.name)
        cm = self.cost.get(key)
        st: statsmod.DCStats = self.stats.get(key)
        scope_ledger = self.ledger.register(table, dc.name, rel.capacity)
        rep = StepReport(dc.name, table, step.mode)

        answer = filter_mask(rel, step.preds) if step.preds else rel.valid
        mode = step.mode
        if mode == "auto" and st is not None:
            answer_size = int(np.asarray(jnp.sum(answer)))
            pivot_vals = np.asarray(rel.columns[st.pivot])[np.asarray(answer)]
            dec = statsmod.algorithm2_decide(
                st,
                pivot_vals,
                answer_size,
                scope_ledger.support,
                self.config.accuracy_threshold,
            )
            rep.alg2_accuracy = dec.accuracy
            rep.alg2_support = dec.support
            mode = "full" if dec.full_clean else "incremental"
        elif mode == "auto":
            mode = "incremental"

        # resolve the scan scope: which rows of the comparison matrix this
        # step owns, and the covering kernel block range (the strip grid)
        live = unchecked(rel, dc.name)
        cold_ids = scope_ledger.cold_strips()
        cold_frac = scope_ledger.cold_fraction
        row_blocks = None
        row_block_ids = None
        if mode == "incremental":
            row_scope = answer & live
        else:
            sel = cold_ids
            if step.strips is not None:
                # drop strips that raced warm since the step was planned
                sel = np.intersect1d(
                    np.asarray(step.strips, dtype=np.int64), cold_ids
                )
            if mode == "strip" and len(sel) < len(cold_ids):
                rep.mode = "strip"
            else:
                mode = "full"  # covers every cold strip == remaining full clean
            if len(sel):
                row_scope = jnp.asarray(scope_ledger.strip_mask(sel)) & live
                # EXACT cold-strip block ids, not the covering range: warm
                # strips between cold ones never launch (DESIGN.md §15)
                row_block_ids = scope_ledger.strip_block_ids(
                    sel, self.config.dc_block
                )
            else:
                row_scope = jnp.zeros_like(rel.valid)
        rep.mode = mode if mode != "strip" else rep.mode
        rep.answer_size = int(np.asarray(jnp.sum(row_scope if mode == "strip" else answer)))

        # idempotence gate (the DC analogue of the FD dirty-group skip): when
        # everything this step would scope is already checked for the rule,
        # the pass that checked it also merged its DC evidence, so
        # re-detecting would only re-merge the same evidence — double-counting
        # candidate support and advancing clean_version for no state change.
        # Repeated queries therefore skip, keeping answers version-stable
        # (the service cache's contract, DESIGN.md §9).
        if not bool(np.asarray(jnp.any(row_scope))):
            rep.mode = "skipped"
            report.steps.append(rep)
            if cm and record_cost:
                cm.record(rep.answer_size, 0, 0.0, 0)
            return

        mesh = self._detect_mesh(step)
        col_scope = rel.valid
        if mode == "incremental":
            row_block_ids = self._active_blocks(row_scope)
        rel, det = self._dc_detect_repair(
            rel, dc, row_scope, col_scope, row_blocks, mesh, cm, rep,
            row_block_ids=row_block_ids,
        )
        repaired = (det.t1_count > 0) | (det.t2_count > 0)
        rep.repaired = int(np.asarray(jnp.sum(repaired & row_scope)))

        if mode == "incremental":
            # partners of the answer (the DC-correlated tuples, §4.2) get
            # their role fixes too — the incremental matrix strip
            # [rest x answer], partner-side-restricted to the answer's
            # active blocks (DESIGN.md §15)
            partner_scope = rel.valid & ~answer
            rel, det2 = self._dc_detect_repair(
                rel, dc, partner_scope, answer, None, mesh, cm, rep,
                row_block_ids=self._active_blocks(partner_scope),
                col_block_ids=self._active_blocks(answer),
            )
            rep.extra = int(
                np.asarray(
                    jnp.sum(((det2.t1_count > 0) | (det2.t2_count > 0)) & partner_scope)
                )
            )

        rel = self._mark(rel, table, dc.name, row_scope)
        self.db[table] = rel
        if cm and record_cost:
            n = cm.n
            d_i = (
                float(rep.answer_size) * n / max(self.config.dc_partitions, 1)
                if mode == "incremental"
                else cm.df_effective * cold_frac
            )
            cm.record(rep.answer_size, rep.extra, d_i, rep.repaired)
        if cm and rep.mode == "full":
            cm.mark_switched()
        report.steps.append(rep)

    # ------------------------------------------------------------ execution
    def _run_steps(self, plan: PlanInfo, report: ExecReport) -> None:
        for step in plan.steps:
            if isinstance(step.rule, FD):
                self._clean_fd(step, report)
            else:
                self._clean_dc(step, report)

    def execute(self, query: Query) -> DaisyResult:
        # re-entrant: many serving sessions may share one executor; the lock
        # serializes the read-modify-write of self.db / cost / version state
        # so concurrent callers interleave at query granularity (candidate
        # merges stay Lemma-4 order-independent either way).
        with self._lock, self.tracer.span(
            "daisy.execute", table=query.table, joins=len(query.joins)
        ) as sp:
            plan = plan_query(
                query, self.rules, self._want_full(),
                lemma1_fast_path=self.config.lemma1_fast_path,
                ledger=self.ledger,
            )
            report = ExecReport(notes=list(plan.notes))

            if not query.joins:
                result = self._execute_sp(query, plan, report)
            else:
                result = self._execute_join(query, plan, report)
            sp.set(steps=len(report.steps), result_size=report.result_size)
            return result

    # ----------------------------------------------------------- SP queries
    def _execute_sp(self, query: Query, plan: PlanInfo, report: ExecReport) -> DaisyResult:
        self._run_steps(plan, report)
        rel = self.db[query.table]
        mask = filter_mask(rel, query.preds)
        report.result_size = int(np.asarray(jnp.sum(mask)))
        result = DaisyResult(mask=mask, report=report)
        if query.groupby is not None:
            result.groups = self._groupby_sp(rel, mask, query.groupby)
        return result

    def _groupby_sp(self, rel: Relation, mask, spec: GroupBySpec):
        from repro.core.operators import groupby_agg

        return groupby_agg(rel, mask, spec)

    # --------------------------------------------------------- join queries
    def _execute_join(self, query: Query, plan: PlanInfo, report: ExecReport) -> DaisyResult:
        # pre-clean qualifying masks (the dirty base join inputs)
        pre_masks: Dict[str, jnp.ndarray] = {
            query.table: filter_mask(self.db[query.table], query.preds)
        }
        for j in query.joins:
            pre_masks[j.right] = filter_mask(self.db[j.right], j.right_preds)

        # clean each side's qualifying part (push-down, §5.1)
        self._run_steps(plan, report)

        post_masks: Dict[str, jnp.ndarray] = {
            query.table: filter_mask(self.db[query.table], query.preds)
        }
        for j in query.joins:
            post_masks[j.right] = filter_mask(self.db[j.right], j.right_preds)

        state: Optional[JoinState] = None
        for j in query.joins:
            state = self._join_once(query, state, j, pre_masks, post_masks, report)
        report.result_size = int(np.asarray(jnp.sum(state.valid)))
        report.recheck_violations = self._recheck(state)
        result = DaisyResult(join=state, report=report)
        if query.groupby is not None:
            result.groups = self._groupby_join(state, query.groupby)
        return result

    def _key_source(self, state: Optional[JoinState], base: str, col: str) -> str:
        """Which table provides ``col`` for the current join state."""
        tables = [base] if state is None else list(state.tables)
        for t in tables:
            if col in self.db[t].columns:
                return t
        raise KeyError(f"join key {col!r} not found among {tables}")

    def _join_once(
        self,
        query: Query,
        state: Optional[JoinState],
        j,
        pre_masks,
        post_masks,
        report: ExecReport,
    ) -> JoinState:
        cfg = self.config
        left_table = self._key_source(state, query.table, j.left_on)
        rel_l = self.db[left_table]
        rel_r = self.db[j.right]
        kv_l, al_l = key_candidates(rel_l, j.left_on)
        kv_r, al_r = key_candidates(rel_r, j.right_on)

        if state is None:
            pre_l, post_l = pre_masks[query.table], post_masks[query.table]
            pre_r, post_r = pre_masks[j.right], post_masks[j.right]
            # base join on the dirty qualifying parts
            li, ri, v, ovf = prob_equijoin(
                kv_l, al_l, pre_l, kv_r, al_r, pre_r,
                cfg.join_capacity, cfg.join_row_block,
            )
            # incremental join of the relaxation extras (Fig. 5):
            # extras_l x post_r, then pre_l x extras_r
            extra_l = post_l & ~pre_l
            extra_r = post_r & ~pre_r
            li2, ri2, v2, ovf2 = prob_equijoin(
                kv_l, al_l, extra_l, kv_r, al_r, post_r,
                cfg.join_capacity, cfg.join_row_block,
            )
            li3, ri3, v3, ovf3 = prob_equijoin(
                kv_l, al_l, pre_l, kv_r, al_r, extra_r,
                cfg.join_capacity, cfg.join_row_block,
            )
            li = jnp.concatenate([li, li2, li3])
            ri = jnp.concatenate([ri, ri2, ri3])
            v = jnp.concatenate([v, v2, v3])
            v = dedupe_pairs(li, ri, v)
            # compact to capacity
            order = jnp.argsort(~v, stable=True)[: cfg.join_capacity]
            li, ri, v = li[order], ri[order], v[order]
            overflow = ovf | ovf2 | ovf3
            report.join_overflow = bool(np.asarray(overflow))
            return JoinState(
                tables=(left_table, j.right),
                rows={left_table: li, j.right: ri},
                valid=v,
                overflow=overflow,
            )

        # chained join: gather current result's key candidates
        rows_l = state.rows[left_table]
        kv_res = kv_l[rows_l]
        al_res = al_l[rows_l] & state.valid[:, None]
        post_r = post_masks.get(j.right, self.db[j.right].valid)
        li, ri, v, ovf = prob_equijoin(
            kv_res, al_res, state.valid, kv_r, al_r, post_r,
            cfg.join_capacity, cfg.join_row_block,
        )
        v = dedupe_pairs(li, ri, v)
        new_rows = {
            t: jnp.where(v, r[jnp.minimum(li, r.shape[0] - 1)], r.shape[0])
            for t, r in state.rows.items()
        }
        new_rows[j.right] = jnp.where(v, ri, rel_r.capacity)
        report.join_overflow = report.join_overflow or bool(np.asarray(ovf))
        return JoinState(
            tables=state.tables + (j.right,),
            rows=new_rows,
            valid=v,
            overflow=state.overflow | ovf,
        )

    def _recheck(self, state: JoinState) -> int:
        """Def. 3 (d): re-check the stitched join result for violations.
        Lemma 5 predicts zero NEW violations among unchecked rows."""
        total = 0
        for table in state.tables:
            rel = self.db[table]
            used = jnp.zeros((rel.capacity,), bool).at[
                jnp.where(state.valid, state.rows[table], rel.capacity)
            ].set(True, mode="drop")
            for rule in self.rules.get(table, ()):
                if isinstance(rule, FD):
                    self.detect_calls += 1
                    det = detect_fd(rel, rule, used & rel.valid, k=self.config.k)
                    fresh = det.violated & unchecked(rel, rule.name)
                    total += int(np.asarray(jnp.sum(fresh)))
        return total

    def _groupby_join(self, state: JoinState, spec: GroupBySpec):
        """Group-by over join lineage: gather key/value columns, aggregate
        with expected-value semantics."""
        table = spec.table or self._key_source(state, state.tables[0], spec.keys[0])
        rel = self.db[table]
        rows = state.rows[table]
        safe = jnp.minimum(rows, rel.capacity - 1)
        keys = [rel.columns[a][safe] for a in spec.keys]
        w = state.valid.astype(jnp.float32)
        if spec.value:
            vt = spec.table or self._key_source(state, state.tables[0], spec.value)
            vrel = self.db[vt]
            vrows = jnp.minimum(state.rows[vt], vrel.capacity - 1)
            v = expected_value(vrel, spec.value)[vrows]
        else:
            v = jnp.zeros_like(w)
        return _finalize_groupby(spec, keys, state.valid, w, v)
