"""Cleaning-aware logical planning (paper §5.1).

The planner detects which rules overlap the query's attributes
((X u Y) n (P u W) != {}), injects a cleaning step per overlapping rule, and
chooses placement + mode:

* **group-by with no select/join** -> cleaning pushed below the aggregation
  as a FULL clean (the group-by touches the whole dataset, so incremental
  relaxation has nothing to prune — §4 "we push down cleaning to avoid the
  grouping recomputation");
* **select** -> clean AFTER the filter via query-result relaxation, unless
  the per-rule online cost model (Inequality (1)) says the remaining-dirty
  full clean is now cheaper (the Fig. 9/14 switch);
* **join** -> clean each side's qualifying part before the join
  (push-down, §5.1), then incremental-join the extra tuples (Fig. 5) and
  re-check the stitched result (Def. 3 (d));
* **FD filtered on the rhs only** -> the Lemma-1 fast path: relaxation skips
  the rhs expansion (one effective closure round).
* **DC** -> mode 'auto': the full/partial decision is Algorithm 2's accuracy
  estimate, which needs the answer and is therefore taken at execution time.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.constraints import DC, FD, equality_key_attrs, overlaps_query
from repro.core.operators import JoinClause, Pred, Query


@dataclasses.dataclass(frozen=True)
class CleanStep:
    table: str
    rule: FD | DC
    placement: str  # 'pre' (below the filter / full) or 'post' (on the result)
    mode: str  # 'incremental' | 'full' | 'auto' (DC: Algorithm 2 at exec time)
    use_rhs: bool = True  # Algorithm 1 rhs expansion (False = Lemma-1 path)
    preds: Tuple[Pred, ...] = ()  # the filter this step cleans against
    # the rule has an equality routing key, so detection MAY take the
    # sharded path when the executor runs on a mesh (DESIGN.md §8); the
    # executor combines this with its mesh config at execution time.
    shardable: bool = False
    # partition-strip grain (DESIGN.md §11): when set, the step scans ONLY
    # these ledger strips (DC row-block strips of the comparison matrix) —
    # the background cleaner's bounded increments and the planner's
    # ledger-pruned full cleans both express their scope this way.  None
    # means the step is not strip-scoped (FD steps, answer-scoped DC steps).
    strips: Tuple[int, ...] | None = None


@dataclasses.dataclass
class PlanInfo:
    steps: List[CleanStep]
    join_order: List[JoinClause]
    notes: List[str]


def _fd_use_rhs(fd: FD, preds: Sequence[Pred], lemma1_fast_path: bool) -> bool:
    """Lemma 1: a filter purely on the rhs converges in one lhs round, so the
    rhs expansion adds no *qualifying* tuples and may be skipped.

    NOTE: the paper's own candidate tables (2b, 4d) nevertheless use lhs
    candidates drawn from rhs-sharing tuples OUTSIDE that one-round closure
    (its Example-2 narrative contradicts its Table 2b values).  We therefore
    default to the full closure — candidate sets exactly match the paper's
    tables — and expose the Lemma-1 shortcut as an opt-in fast path
    (``DaisyConfig.lemma1_fast_path``) for workloads that only need
    qualification recovery, not full candidate domains."""
    if not lemma1_fast_path:
        return True
    pred_attrs = {p.col for p in preds} & set(fd.attrs)
    return not (pred_attrs and pred_attrs <= {fd.rhs})


def probe_step(table: str, rule) -> CleanStep:
    """An incremental step with no predicate filter: the executor substitutes
    an explicit answer mask (``answer_override``).  Background FD increments
    use it so a cold-group sweep runs the same relax -> detect -> repair ->
    mark pipeline a foreground query selecting those groups would
    (DESIGN.md §10), keeping the shardable mark consistent with the planner's.
    """
    return CleanStep(
        table, rule, "post", "incremental", True, (), bool(equality_key_attrs(rule))
    )


def strip_step(table: str, rule, strips) -> CleanStep:
    """A DC step scoped to a set of ledger strips (DESIGN.md §11): the
    executor scans ``strips`` x rest-of-dataset and marks exactly the cold
    rows it covered.  This is the background cleaner's bounded-latency DC
    increment — and, with ALL cold strips passed, the ledger-pruned form of
    the full clean (foreground full cleans route through it too, so both
    paths are one increment engine)."""
    return CleanStep(
        table, rule, "pre", "strip", True, (),
        bool(equality_key_attrs(rule)), tuple(int(s) for s in strips),
    )


def plan_query(
    query: Query,
    rules: Dict[str, Sequence[FD | DC]],
    want_full: Dict[Tuple[str, str], bool],
    lemma1_fast_path: bool = False,
    ledger=None,
) -> PlanInfo:
    """Build the cleaning plan.  ``want_full[(table, rule)]`` carries the
    cost model's current verdict (executor refreshes it before each query).

    With a ``WorkLedger`` passed, cost-model DC full cleans plan at strip
    grain: the step carries the scope's cold strips, so the executor scans
    only the part of the comparison matrix no earlier pass (foreground or
    background) already covered — partial-work reuse, DESIGN.md §11."""
    steps: List[CleanStep] = []
    notes: List[str] = []

    def add_steps(table: str, preds: Tuple[Pred, ...], attrs: Sequence[str]):
        for rule in rules.get(table, ()):  # planner preserves rule order
            if not overlaps_query(rule, attrs):
                continue
            if ledger is not None and ledger.has_pending(table, rule.name):
                # the executor drains queued ingest-deltas at the top of
                # every cleaning step (DESIGN.md §12); surface it in the plan
                notes.append(
                    f"{rule.name}@{table}: ingest-delta pending "
                    "(drained before this step)"
                )
            full = want_full.get((table, rule.name), False)
            shardable = bool(equality_key_attrs(rule))
            if isinstance(rule, FD):
                if not preds and query.groupby is not None:
                    steps.append(
                        CleanStep(table, rule, "pre", "full", True, (), shardable)
                    )
                    notes.append(f"{rule.name}@{table}: pushdown full (bare group-by)")
                elif full:
                    steps.append(
                        CleanStep(table, rule, "pre", "full", True, preds, shardable)
                    )
                    notes.append(f"{rule.name}@{table}: cost-model switch -> full")
                else:
                    use_rhs = _fd_use_rhs(rule, preds, lemma1_fast_path)
                    steps.append(
                        CleanStep(
                            table, rule, "post", "incremental", use_rhs, preds,
                            shardable,
                        )
                    )
                    if not use_rhs:
                        notes.append(f"{rule.name}@{table}: Lemma-1 rhs-filter path")
            else:
                mode = "full" if full else "auto"
                strips = None
                if full and ledger is not None:
                    scope = ledger.scope(table, rule.name)
                    if scope is not None and scope.strips_done > 0:
                        strips = tuple(int(s) for s in scope.cold_strips())
                        notes.append(
                            f"{rule.name}@{table}: full clean pruned to "
                            f"{len(strips)}/{scope.n_strips} cold strips"
                        )
                steps.append(
                    CleanStep(
                        table, rule, "post", mode, True, preds, shardable, strips
                    )
                )
                if not shardable:
                    notes.append(
                        f"{rule.name}@{table}: no equality atom — dense detect only"
                    )

    base_attrs = list(query.attrs)
    add_steps(query.table, tuple(query.preds), base_attrs)
    for j in query.joins:
        add_steps(j.right, tuple(j.right_preds), base_attrs)
    return PlanInfo(steps, list(query.joins), notes)
