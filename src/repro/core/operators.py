"""Query AST + probabilistic execution primitives (paper §4, §5).

The supported query template (§5):

    SELECT <list> FROM T [, (J)]
    [WHERE col op val [AND col op val ...]]
    [GROUP BY keys [agg]]

Execution follows the paper's possible-worlds semantics over the
attribute-level-uncertain relation:

* **filter**: a tuple qualifies iff >= 1 candidate qualifies
  (``Relation.candidate_matches``);
* **join**: a pair qualifies iff the candidate value sets of the join keys
  overlap (§4: "for (self-)joins on probabilistic join keys, a pair
  qualifies iff the candidate values of the join keys overlap"); lineage =
  the originating row-id arrays, kept in the result;
* **group-by**: expected-value aggregation — each candidate contributes its
  probability mass to its group (the probabilistic-DB expectation semantics
  of [34], the paper's uncertainty model).

Static shapes throughout: masks for SP results, fixed-capacity (li, ri) index
arrays + overflow flag for joins (jnp.nonzero with static size).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.relation import CAND_VALUE, Relation
from repro.core.setops import group_info, unique_counts


# --------------------------------------------------------------------- AST
@dataclasses.dataclass(frozen=True)
class Pred:
    col: str
    op: str
    value: float | int


@dataclasses.dataclass(frozen=True)
class JoinClause:
    right: str  # right table name
    left_on: str
    right_on: str
    right_preds: Tuple[Pred, ...] = ()


@dataclasses.dataclass(frozen=True)
class GroupBySpec:
    keys: Tuple[str, ...]
    agg: str = "count"  # count | sum | avg
    value: Optional[str] = None  # aggregated column (for sum/avg)
    table: Optional[str] = None  # which table the key/value columns live in


@dataclasses.dataclass(frozen=True)
class Query:
    table: str
    preds: Tuple[Pred, ...] = ()
    project: Tuple[str, ...] = ()
    joins: Tuple[JoinClause, ...] = ()
    groupby: Optional[GroupBySpec] = None

    @property
    def attrs(self) -> Tuple[str, ...]:
        out = list(self.project)
        for p in self.preds:
            out.append(p.col)
        for j in self.joins:
            out.append(j.left_on)
            out.append(j.right_on)
            for p in j.right_preds:
                out.append(p.col)
        if self.groupby:
            out.extend(self.groupby.keys)
            if self.groupby.value:
                out.append(self.groupby.value)
        return tuple(dict.fromkeys(out))


# ----------------------------------------------------------- fingerprinting
def _fp_value(v) -> str:
    """Canonical token for a predicate constant: bools/ints by value, floats
    by exact bit pattern (hex), so equal constants always tokenize equally
    while 1 and 1.0000001 never collide."""
    if isinstance(v, (bool, np.bool_)):
        return f"b{int(v)}"
    if isinstance(v, (int, np.integer)):
        return f"i{int(v)}"
    return f"f{float(v).hex()}"


def _fp_preds(preds: Sequence[Pred]) -> List[Tuple[str, str, str]]:
    return sorted((p.col, p.op, _fp_value(p.value)) for p in preds)


def query_fingerprint(query: Query) -> str:
    """Stable fingerprint of a query's logical content (DESIGN.md §9).

    The service cache keys on ``(fingerprint, clean_version)``, so this must
    be deterministic across processes — hashlib over a canonical token
    stream, never ``hash()`` (PYTHONHASHSEED).  Conjunctive predicates are
    order-normalized (AND commutes); join order is preserved because it
    decides capacity truncation and is therefore answer-relevant.
    """
    parts: List[str] = ["T", query.table]
    # projection feeds Query.attrs and hence the planner's rule-overlap
    # decision, so it is state-trajectory-relevant even though it never
    # filters rows; list order is not (attrs dedups into a set check).
    for col in sorted(query.project):
        parts += ["R", col]
    for col, op, val in _fp_preds(query.preds):
        parts += ["P", col, op, val]
    for j in query.joins:
        parts += ["J", j.right, j.left_on, j.right_on]
        for col, op, val in _fp_preds(j.right_preds):
            parts += ["P", col, op, val]
    g = query.groupby
    if g is not None:
        parts += ["G", ",".join(g.keys), g.agg, g.value or "", g.table or ""]
    return hashlib.sha256("\x1f".join(parts).encode()).hexdigest()[:16]


# ----------------------------------------------------------------- results
@dataclasses.dataclass
class JoinState:
    """Lineage of a (possibly multi-way) join: per-table originating row ids
    for each result pair (the paper's probabilistic-join lineage)."""

    tables: Tuple[str, ...]
    rows: Dict[str, jnp.ndarray]  # table -> (cap_out,) int32 row ids
    valid: jnp.ndarray  # (cap_out,) bool
    overflow: jnp.ndarray  # () bool


# ----------------------------------------------------------------- filters
def filter_mask(rel: Relation, preds: Sequence[Pred]) -> jnp.ndarray:
    """Possible-world conjunctive filter."""
    mask = rel.valid
    for p in preds:
        mask = mask & rel.candidate_matches(p.col, p.op, p.value)
    return mask


def key_candidates(rel: Relation, attr: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(cap, K) candidate values + alive mask for a join key.  Rows without
    an overlay expose their primary value as the single candidate.  Range
    candidates (CAND_LT/GT) do not participate in equi-join matching."""
    col = rel.columns[attr]
    if attr not in rel.cand:
        return col[:, None], rel.valid[:, None]
    cand = rel.cand[attr]
    alive = (rel.ccount[attr] > 0) & (rel.ckind[attr] == CAND_VALUE)
    has = jnp.any(alive, axis=1)
    # no-overlay rows: candidate 0 = primary value
    vals = jnp.where(has[:, None], cand, jnp.concatenate(
        [col[:, None], cand[:, 1:]], axis=1))
    alive = jnp.where(
        has[:, None],
        alive,
        jnp.zeros_like(alive).at[:, 0].set(True),
    )
    return vals, alive & rel.valid[:, None]


def candidate_overlap_matrix(
    l_vals: jnp.ndarray,
    l_alive: jnp.ndarray,
    r_vals: jnp.ndarray,
    r_alive: jnp.ndarray,
) -> jnp.ndarray:
    """(n_l, n_r) bool — candidate sets overlap (the possible-world join)."""
    kl = l_vals.shape[1]
    kr = r_vals.shape[1]
    match = jnp.zeros((l_vals.shape[0], r_vals.shape[0]), dtype=bool)
    for a in range(kl):
        for b in range(kr):
            m = (l_vals[:, a][:, None] == r_vals[:, b][None, :]) & (
                l_alive[:, a][:, None] & r_alive[:, b][None, :]
            )
            match = match | m
    return match


def prob_equijoin(
    l_vals: jnp.ndarray,
    l_alive: jnp.ndarray,
    mask_l: jnp.ndarray,
    r_vals: jnp.ndarray,
    r_alive: jnp.ndarray,
    mask_r: jnp.ndarray,
    cap_out: int,
    row_block: int = 1024,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Possible-world equi-join.  Returns (li, ri, valid, overflow) with
    static output capacity ``cap_out``.  Processes left rows in blocks so the
    match matrix stays bounded."""
    n_l = l_vals.shape[0]
    n_r = r_vals.shape[0]
    nb = -(-n_l // row_block)
    all_li, all_ri, all_v = [], [], []
    overflow = jnp.bool_(False)
    for b in range(nb):
        lo = b * row_block
        hi = min(lo + row_block, n_l)
        match = candidate_overlap_matrix(
            l_vals[lo:hi], l_alive[lo:hi], r_vals, r_alive
        )
        match = match & mask_l[lo:hi, None] & mask_r[None, :]
        cnt = jnp.sum(match.astype(jnp.int32))
        li, ri = jnp.nonzero(
            match, size=cap_out, fill_value=(hi - lo, n_r)
        )
        v = li < (hi - lo)
        overflow = overflow | (cnt > cap_out)
        all_li.append(jnp.where(v, li + lo, n_l))
        all_ri.append(ri)
        all_v.append(v)
    li = jnp.concatenate(all_li)
    ri = jnp.concatenate(all_ri)
    v = jnp.concatenate(all_v)
    # compact valid pairs to the front, truncate to cap_out
    order = jnp.argsort(~v, stable=True)
    li, ri, v = li[order][:cap_out], ri[order][:cap_out], v[order][:cap_out]
    overflow = overflow | (jnp.sum(jnp.concatenate(all_v).astype(jnp.int32)) > cap_out)
    return li, ri, v, overflow


def dedupe_pairs(
    li: jnp.ndarray, ri: jnp.ndarray, valid: jnp.ndarray
) -> jnp.ndarray:
    """Mark duplicate (li, ri) pairs invalid (keep first occurrence)."""
    n = li.shape[0]
    big = jnp.int32(np.iinfo(np.int32).max)
    k1 = jnp.where(valid, li, big)
    k2 = jnp.where(valid, ri, big)
    pos = jnp.arange(n, dtype=jnp.int32)
    sk1, sk2, spos = jax.lax.sort((k1, k2, pos), num_keys=2)
    dup = jnp.zeros((n,), bool)
    if n > 1:
        dup = dup.at[1:].set((sk1[1:] == sk1[:-1]) & (sk2[1:] == sk2[:-1]))
    keep_sorted = ~dup
    keep = jnp.zeros((n,), bool).at[spos].set(keep_sorted)
    return valid & keep


# ---------------------------------------------------------------- group-by
def expected_value(rel: Relation, attr: str) -> jnp.ndarray:
    """Per-row expected value of a (possibly probabilistic) numeric column."""
    col = rel.columns[attr].astype(jnp.float32)
    if attr not in rel.cand:
        return col
    probs = rel.probs(attr)
    vals = jnp.where(
        rel.ckind[attr] == CAND_VALUE, rel.cand[attr].astype(jnp.float32), col[:, None]
    )
    has = jnp.any(rel.ccount[attr] > 0, axis=1)
    exp = jnp.sum(probs * vals, axis=1)
    return jnp.where(has, exp, col)


def groupby_agg(
    rel: Relation,
    mask: jnp.ndarray,
    spec: GroupBySpec,
    weights: jnp.ndarray | None = None,
) -> Dict[str, jnp.ndarray]:
    """Expected-value group-by over (possibly probabilistic) keys.

    Probabilistic keys contribute probability-weighted mass to each candidate
    key's group.  Returns dense arrays: key columns, per-group weighted count
    and aggregate, plus ``num_groups``.
    """
    base_w = mask.astype(jnp.float32) if weights is None else jnp.where(mask, weights, 0.0)
    vcol = expected_value(rel, spec.value) if spec.value else jnp.zeros_like(base_w)

    # expand probabilistic single-key groupings; multi-key uses primary values
    if len(spec.keys) == 1 and spec.keys[0] in rel.cand:
        attr = spec.keys[0]
        kv, alive = key_candidates(rel, attr)
        probs = rel.probs(attr)
        has = jnp.any(rel.ccount[attr] > 0, axis=1)
        w = jnp.where(
            has[:, None], probs, jnp.zeros_like(probs).at[:, 0].set(1.0)
        ) * base_w[:, None]
        flat_keys = [kv.reshape(-1)]
        flat_w = w.reshape(-1)
        flat_v = jnp.repeat(vcol, kv.shape[1])
        flat_mask = (flat_w > 0)
    else:
        flat_keys = [rel.columns[a] for a in spec.keys]
        flat_w = base_w
        flat_v = vcol
        flat_mask = mask

    return _finalize_groupby(spec, flat_keys, flat_mask, flat_w, flat_v)


def _finalize_groupby(spec, flat_keys, flat_mask, flat_w, flat_v):
    """Segment-sum per distinct key.  ``group_info`` gids are dense in sorted
    key order and ``unique_counts`` emits uniques in the same order, so
    unique ``i`` aligns with segment ``i`` by construction (masked rows land
    in the trailing sentinel segment and contribute zero weight)."""
    n = flat_keys[0].shape[0]
    gid, _ = group_info(flat_keys, flat_mask)
    wsum = jax.ops.segment_sum(jnp.where(flat_mask, flat_w, 0.0), gid, num_segments=n)
    vsum = jax.ops.segment_sum(
        jnp.where(flat_mask, flat_w * flat_v, 0.0), gid, num_segments=n
    )
    uvals, _, nuniq = unique_counts(flat_keys, flat_mask)
    result = {f"key_{a}": uvals[i] for i, a in enumerate(spec.keys)}
    result["count"] = wsum
    if spec.agg == "sum":
        result["agg"] = vsum
    elif spec.agg == "avg":
        result["agg"] = jnp.where(wsum > 0, vsum / jnp.maximum(wsum, 1e-30), 0.0)
    else:
        result["agg"] = wsum
    result["num_groups"] = nuniq
    return result
