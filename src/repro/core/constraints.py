"""Denial constraints.

The paper cleans violations of denial constraints (DCs):

    forall t1..tk  NOT (p1 AND p2 ... AND pm)

Two families are treated specially, as in the paper:

* **FD** ``X -> Y`` (the equality special case; Example 1, §4.1).  ``X`` may be
  multi-attribute, ``Y`` is a single attribute (wider FDs decompose, §4.1).
* **General binary DCs** with order predicates between two tuples, e.g.
  Example 4's  ``NOT (t1.salary < t2.salary AND t1.tax > t2.tax)`` (§4.2).
  Each atom relates attribute ``left`` of t1 with attribute ``right`` of t2
  via an operator; in the paper's evaluation (and ours) ``left == right``
  ("conditions over the same attribute", §4.2 — following BigDansing).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

OPS = ("==", "!=", "<", "<=", ">", ">=")

_INVERT = {"==": "!=", "!=": "==", "<": ">=", "<=": ">", ">": "<=", ">=": "<"}
_FLIP = {"==": "==", "!=": "!=", "<": ">", "<=": ">=", ">": "<", ">=": "<="}


def invert_op(op: str) -> str:
    """Negation: NOT(a op b) == a invert_op(op) b."""
    return _INVERT[op]


def flip_op(op: str) -> str:
    """Commutation: a op b == b flip_op(op) a."""
    return _FLIP[op]


@dataclasses.dataclass(frozen=True)
class FD:
    """Functional dependency lhs -> rhs."""

    name: str
    lhs: Tuple[str, ...]
    rhs: str

    def __init__(self, name: str, lhs, rhs: str):
        if isinstance(lhs, str):
            lhs = (lhs,)
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "lhs", tuple(lhs))
        object.__setattr__(self, "rhs", rhs)

    @property
    def attrs(self) -> Tuple[str, ...]:
        return self.lhs + (self.rhs,)


@dataclasses.dataclass(frozen=True)
class Atom:
    """One predicate of a binary DC: t1.left  op  t2.right."""

    left: str
    op: str
    right: str

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"bad op {self.op!r}")


@dataclasses.dataclass(frozen=True)
class DC:
    """Binary denial constraint NOT(atom1 AND atom2 AND ...)."""

    name: str
    atoms: Tuple[Atom, ...]

    def __init__(self, name: str, atoms: Sequence[Atom]):
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "atoms", tuple(atoms))

    @property
    def attrs(self) -> Tuple[str, ...]:
        seen = []
        for a in self.atoms:
            for c in (a.left, a.right):
                if c not in seen:
                    seen.append(c)
        return tuple(seen)


def fd_as_dc(fd: FD) -> DC:
    """An FD X->Y is the DC NOT(t1.X == t2.X AND t1.Y != t2.Y)."""
    atoms = [Atom(a, "==", a) for a in fd.lhs] + [Atom(fd.rhs, "!=", fd.rhs)]
    return DC(fd.name, atoms)


def rule_attrs(rule) -> Tuple[str, ...]:
    if isinstance(rule, FD):
        return rule.attrs
    return rule.attrs


def equality_key_attrs(rule) -> Tuple[str, ...]:
    """Attributes usable as a shard-routing key for distributed detection
    (DESIGN.md §8): every violating pair agrees on them, so hash-routing
    rows by their combined value puts all of a row's potential partners on
    the same shard.

    FDs always key on the lhs.  A general DC contributes an attribute per
    equality atom over the *same* attribute on both sides (``t1.a == t2.a``
    — the paper's "conditions over the same attribute", §4.2); an equality
    atom across two different attributes gives each role a different
    routing key and is not shardable this way.  Empty result means the
    rule has no equality key and sharded detection must fall back to the
    dense scan.
    """
    if isinstance(rule, FD):
        return rule.lhs
    return tuple(
        a.left for a in rule.atoms if a.op == "==" and a.left == a.right
    )


def overlaps_query(rule, query_attrs: Sequence[str]) -> bool:
    """Paper §4.1: a rule affects a query iff (X u Y) n (P u W) != {} ."""
    return bool(set(rule_attrs(rule)) & set(query_attrs))
