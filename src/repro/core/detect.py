"""Violation detection (paper §4.1 FDs, §4.2 general DCs).

FD detection is the BigDansing-style group-by (no self-join): sort rows by
(lhs, rhs), a group violates iff it contains >= 2 distinct rhs values.  The
same pass yields the per-group distinct (value, frequency) table — exactly
the numerators of the candidate probabilities P(rhs | lhs), so detection and
candidate computation share one sort (the paper's "relaxation benefit":
candidates come from the correlated tuples, not from dataset re-scans).

DC detection is the partitioned theta-join (Okcan-Riedewald matrix): every
ordered pair (t1, t2) with all atoms true is a violation.  The pairwise scan
is the paper's compute hot-spot and runs in the Pallas ``dc_pairs`` kernel
(blocked VMEM tiles + block-bound pruning, DESIGN.md §7); detection for the
t2 role reuses the same kernel with flipped atoms, so both roles' statistics
are row-indexed and accumulate TPU-grid-friendly.

Scopes: ``row_scope`` is the paper's "query result (+ extra)" side and
``col_scope`` the "rest of the dataset" side — incremental cleaning shrinks
these masks instead of re-partitioning a matrix.

``detect_auto`` is the dispatch seam to the distributed path (DESIGN.md
§8): on a mesh, rules with an equality key are routed through
``dist.shuffle.shuffle_by_key`` and scanned per shard.  It always returns
a ``DetectResult`` carrying the detection plus the sharded routing info
(or ``None`` on the dense path); the four ``detect_{dc,fd}_auto[_info]``
functions remain as deprecated thin aliases.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.constraints import DC, FD, flip_op
from repro.core.relation import Relation
from repro.core.setops import group_distinct_candidates
from repro.kernels import ops as kops


class FDDetectResult(NamedTuple):
    violated: jnp.ndarray  # (cap,) bool — row belongs to a violating group
    rhs_cand: jnp.ndarray  # (cap, K) candidate rhs values (group-distinct)
    rhs_count: jnp.ndarray  # (cap, K) frequency of each candidate
    lhs_cand: jnp.ndarray | None  # (cap, K) candidate lhs values (1-attr lhs)
    lhs_count: jnp.ndarray | None
    overflow: jnp.ndarray  # () bool — >K distinct candidates somewhere


def detect_fd(
    rel: Relation, fd: FD, scope: jnp.ndarray, k: int | None = None
) -> FDDetectResult:
    """Detect FD violations among rows in ``scope``; compute candidates.

    Candidate rhs values for a row = distinct rhs values of scope rows
    sharing its lhs (with frequencies).  When the lhs is a single attribute,
    candidate lhs values (P(lhs | rhs), paper Example 2) are computed by the
    swapped grouping.
    """
    k = k or max(rel.k, 2)
    scope = scope & rel.valid
    lhs_cols = [rel.columns[a] for a in fd.lhs]
    rhs_col = rel.columns[fd.rhs]
    rhs_cand, rhs_count, violated, overflow = group_distinct_candidates(
        lhs_cols, rhs_col, scope, k
    )
    lhs_cand = lhs_count = None
    if len(fd.lhs) == 1:
        lhs_cand, lhs_count, _, ovf2 = group_distinct_candidates(
            [rhs_col], lhs_cols[0], scope, k
        )
        overflow = overflow | ovf2
    return FDDetectResult(violated, rhs_cand, rhs_count, lhs_cand, lhs_count, overflow)


class DCDetectResult(NamedTuple):
    """Per-row DC violation statistics for both tuple roles.

    ``t1_count[i]``: number of partners t2 with all atoms (t1=i) true.
    ``t1_stat[a][i]``: extremal partner value of atom ``a``'s rhs attribute
    over i's violating partners — the bound of the candidate range fix
    (paper Example 4: fix for t1 under ``t1.x < t2.x`` is ``x > max t2.x``).
    ``t2_*``: same with i in the t2 role.
    """

    t1_count: jnp.ndarray  # (cap,) int32
    t2_count: jnp.ndarray  # (cap,) int32
    t1_stat: Tuple[jnp.ndarray, ...]  # n_atoms x (cap,)
    t2_stat: Tuple[jnp.ndarray, ...]  # n_atoms x (cap,)
    # launch-geometry telemetry of the scan that produced this detection
    # (DESIGN.md §15); zero on paths that predate tile accounting.
    tiles_launched: int = 0
    tiles_total: int = 0
    bytes_moved: int = 0


# For a violating atom ``t1.l op t2.r``:
#  * the t1-side fix must make ``t1.l inv(op) t2.r`` hold for ALL partners ->
#    bound is the max (for op in {<,<=}) or min (for {>,>=}) of partner r.
#  * the t2-side fix bound is the min/max of partner l symmetrically.
_T1_REDUCE = {"<": "max", "<=": "max", ">": "min", ">=": "min", "==": "min", "!=": "min"}


def detect_dc(
    rel: Relation,
    dc: DC,
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    block: int = 256,
    row_blocks: Tuple[int, int] | None = None,
    col_blocks: Tuple[int, int] | None = None,
    row_block_ids=None,
    col_block_ids=None,
    encode: bool = True,
) -> DCDetectResult:
    """Detect DC violations between ``row_scope`` rows (role t1) and
    ``col_scope`` rows (role t2), both directions — one fused kernel launch
    covering both roles (DESIGN.md §15).

    ``row_blocks=(lo, hi)`` is the partition-strip entry (DESIGN.md §11):
    only the row blocks of that strip are launched — the executor passes the
    covering block range of the strips a ledger-driven step scans, so a
    strip increment pays ``strip x n`` tile work instead of ``n x n``.

    ``col_blocks`` restricts the PARTNER side the same way — the
    ingest-delta entry (DESIGN.md §12): checked rows scan only the freshly
    appended column strip, costing O(checked x fresh) tiles.  Both roles
    are launched over the same partner strip (the t2 role flips the atoms
    but its partners live in ``col_scope`` all the same).

    ``row_block_ids`` / ``col_block_ids`` generalize both to arbitrary
    block-id worklists — the ledger's cold geometry (DESIGN.md §15):
    checked x checked tile pairs are simply absent from the launch.

    ``encode=True`` lets the planner compress atom columns (int8/bf16/rank
    codes) where the exactness proof holds; stats are decoded back to the
    original value space before returning, so results are bit-identical
    either way.
    """
    row_scope = row_scope & rel.valid
    col_scope = col_scope & rel.valid
    ops = [a.op for a in dc.atoms]
    reduces = [_T1_REDUCE[op] for op in ops]
    flipped = [flip_op(op) for op in ops]
    t2_reduces = [_T1_REDUCE[op] for op in flipped]

    attrs = {a.left for a in dc.atoms} | {a.right for a in dc.atoms}
    plan = (
        kops.plan_dc_encodings(
            {name: rel.columns[name] for name in attrs},
            [(a.left, a.right, a.op) for a in dc.atoms],
        )
        if encode
        else None
    )
    if plan is not None:
        # one encoded array per attribute: same-attribute atoms keep sharing
        # one object, so the fused kernel's column dedup still applies.
        cols = {name: kops.encode_column(rel.columns[name], plan[name]) for name in attrs}
    else:
        cols = {name: rel.columns[name] for name in attrs}
    l_cols = [cols[a.left] for a in dc.atoms]
    r_cols = [cols[a.right] for a in dc.atoms]

    # role t1: rows are t1, partners t2 in col_scope; stat over partner r.
    # role t2: rows are t2 — atom becomes row.r flip(op) col.l; stat over
    # partner l with the same reduce orientation seen from the row's side.
    res = kops.dc_pair_scan(
        l_cols, r_cols, ops, flipped, row_scope, col_scope,
        reduces, t2_reduces, block=block,
        row_blocks=row_blocks, col_blocks=col_blocks,
        row_block_ids=row_block_ids, col_block_ids=col_block_ids,
    )
    t1_stat, t2_stat = res.t1_stat, res.t2_stat
    if plan is not None:
        t1_stat = tuple(
            kops.decode_stat(
                s, res.t1_count, plan[a.right], rel.columns[a.right].dtype, red
            )
            for s, a, red in zip(t1_stat, dc.atoms, reduces)
        )
        t2_stat = tuple(
            kops.decode_stat(
                s, res.t2_count, plan[a.left], rel.columns[a.left].dtype, red
            )
            for s, a, red in zip(t2_stat, dc.atoms, t2_reduces)
        )
    return DCDetectResult(
        res.t1_count, res.t2_count, tuple(t1_stat), tuple(t2_stat),
        tiles_launched=res.tiles.launched, tiles_total=res.tiles.total,
        bytes_moved=res.tiles.bytes_moved,
    )


def dc_violation_count(result: DCDetectResult) -> jnp.ndarray:
    """Total number of violating ordered pairs (each counted once)."""
    return jnp.sum(result.t1_count)


# ------------------------------------------------------------------ dispatch
# The seam between the dense single-device scans above and the sharded path
# in repro.dist.detect (DESIGN.md §8).  Imports of the dist layer are lazy:
# core stays importable without touching mesh machinery, and the sharded
# module itself imports this one.


def will_shard(rule, mesh, n_shards: int | None = None) -> bool:
    """True when the auto dispatchers below will take the sharded path for
    ``rule`` on ``mesh`` — the single source of truth for that decision."""
    from repro.core.constraints import equality_key_attrs

    if mesh is None or not equality_key_attrs(rule):
        return False
    if n_shards is not None:
        return n_shards >= 2
    from repro.dist.detect import default_n_shards

    return default_n_shards(mesh) >= 2


class DetectResult(NamedTuple):
    """What any detection dispatch returns: the rule-shaped detection
    (``FDDetectResult`` for FDs, ``DCDetectResult`` for DCs) plus the
    ``ShardedDetectInfo`` of the routing when the sharded path ran
    (``None`` on the dense path) — the executor feeds ``info`` to the cost
    model so the full/partial decision prices the shuffle (DESIGN.md §10).
    """

    detection: object  # FDDetectResult | DCDetectResult
    info: object | None  # dist.detect.ShardedDetectInfo | None


def detect_auto(
    rel: Relation,
    rule,
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray | None = None,
    *,
    k: int | None = None,
    block: int = 256,
    mesh=None,
    n_shards: int | None = None,
    row_blocks: Tuple[int, int] | None = None,
    col_blocks: Tuple[int, int] | None = None,
    row_block_ids=None,
    col_block_ids=None,
    encode: bool = True,
    strip_rows: int | None = None,
    tracer=None,
) -> DetectResult:
    """THE detection entry point: dispatch ``rule`` (FD or DC) to the dense
    or sharded scan and always return a ``DetectResult``.

    Sharding: when a mesh is active and the rule carries an equality key
    (``will_shard``), rows route through ``dist.shuffle.shuffle_by_key``
    and scan per shard — bit-identical to the dense result, with the
    routing's ``ShardedDetectInfo`` attached.

    FD rules use ``row_scope`` as the group-by scope and ``k`` for the
    candidate width; ``col_scope``/``block``/``row_blocks``/``col_blocks``
    are DC-only (``col_scope`` is required for DCs).  ``row_blocks`` /
    ``col_blocks`` — and their worklist generalizations ``row_block_ids``
    / ``col_block_ids`` (DESIGN.md §15) — strip-scope the DENSE DC scan
    only (the sharded path re-routes rows, so strip locality does not
    survive the shuffle; its scopes already shrink to the strip's rows,
    and its per-shard launches self-restrict to the routed occupancy).
    ``strip_rows`` feeds the
    sharded path's per-shard strip-coverage report (DESIGN.md §11).
    ``tracer`` (DESIGN.md §13) reaches only the sharded path, which spans
    its shuffle and per-shard scans; the dense scans are one kernel call
    and are timed by the caller's ``clean.detect`` span.
    """
    if isinstance(rule, FD):
        if will_shard(rule, mesh, n_shards):
            from repro.dist.detect import detect_fd_sharded_info

            det, info = detect_fd_sharded_info(
                rel, rule, row_scope, mesh, k=k, n_shards=n_shards,
                strip_rows=strip_rows, tracer=tracer,
            )
            return DetectResult(det, info)
        return DetectResult(detect_fd(rel, rule, row_scope, k=k), None)
    if isinstance(rule, DC):
        if col_scope is None:
            raise ValueError("detect_auto on a DC requires col_scope")
        if will_shard(rule, mesh, n_shards):
            from repro.dist.detect import detect_dc_sharded_info

            det, info = detect_dc_sharded_info(
                rel, rule, row_scope, col_scope, mesh, n_shards=n_shards,
                block=block, strip_rows=strip_rows, tracer=tracer,
            )
            return DetectResult(det, info)
        return DetectResult(
            detect_dc(
                rel, rule, row_scope, col_scope, block=block,
                row_blocks=row_blocks, col_blocks=col_blocks,
                row_block_ids=row_block_ids, col_block_ids=col_block_ids,
                encode=encode,
            ),
            None,
        )
    raise TypeError(f"detect_auto: unsupported rule type {type(rule).__name__}")


# Deprecated thin aliases (pre-§12 API): prefer ``detect_auto``.


def detect_dc_auto_info(
    rel: Relation,
    dc: DC,
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    block: int = 256,
    mesh=None,
    n_shards: int | None = None,
    row_blocks: Tuple[int, int] | None = None,
    strip_rows: int | None = None,
):
    """Deprecated: ``detect_auto(rel, dc, ...)`` — returns the same
    ``(detection, info)`` pair."""
    return tuple(
        detect_auto(
            rel, dc, row_scope, col_scope, block=block, mesh=mesh,
            n_shards=n_shards, row_blocks=row_blocks, strip_rows=strip_rows,
        )
    )


def detect_dc_auto(
    rel: Relation,
    dc: DC,
    row_scope: jnp.ndarray,
    col_scope: jnp.ndarray,
    block: int = 256,
    mesh=None,
    n_shards: int | None = None,
) -> DCDetectResult:
    """Deprecated: ``detect_auto(rel, dc, ...).detection``."""
    return detect_auto(
        rel, dc, row_scope, col_scope, block=block, mesh=mesh, n_shards=n_shards
    ).detection


def detect_fd_auto_info(
    rel: Relation,
    fd: FD,
    scope: jnp.ndarray,
    k: int | None = None,
    mesh=None,
    n_shards: int | None = None,
    strip_rows: int | None = None,
):
    """Deprecated: ``detect_auto(rel, fd, ...)`` — returns the same
    ``(detection, info)`` pair."""
    return tuple(
        detect_auto(
            rel, fd, scope, k=k, mesh=mesh, n_shards=n_shards,
            strip_rows=strip_rows,
        )
    )


def detect_fd_auto(
    rel: Relation,
    fd: FD,
    scope: jnp.ndarray,
    k: int | None = None,
    mesh=None,
    n_shards: int | None = None,
) -> FDDetectResult:
    """Deprecated: ``detect_auto(rel, fd, ...).detection``."""
    return detect_auto(rel, fd, scope, k=k, mesh=mesh, n_shards=n_shards).detection
