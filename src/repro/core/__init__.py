"""Daisy core: query-driven denial-constraint cleaning (the paper's contribution).

Public API re-exports.
"""

from repro.core.accuracy import Accuracy, repair_accuracy
from repro.core.constraints import DC, FD, Atom, fd_as_dc, overlaps_query
from repro.core.cost import CostModel
from repro.core.detect import DetectResult, detect_auto, detect_dc, detect_fd
from repro.core.executor import Daisy, DaisyConfig, DaisyResult, IngestReport
from repro.core.ledger import (
    TABLE_ROWS_RULE,
    PendingIngest,
    StripLedger,
    WorkLedger,
)
from repro.core.offline import OfflineCleaner
from repro.core.operators import GroupBySpec, JoinClause, Pred, Query, filter_mask
from repro.core.planner import plan_query
from repro.core.relation import Dictionary, Relation, append_rows, make_relation
from repro.core.relax import relax_fd
from repro.core.repair import repaired_value
from repro.core.update import apply_candidates, mark_checked, unchecked

__all__ = [
    "Accuracy",
    "Atom",
    "CostModel",
    "DC",
    "Daisy",
    "DaisyConfig",
    "DaisyResult",
    "DetectResult",
    "Dictionary",
    "FD",
    "GroupBySpec",
    "IngestReport",
    "JoinClause",
    "OfflineCleaner",
    "PendingIngest",
    "Pred",
    "Query",
    "Relation",
    "StripLedger",
    "TABLE_ROWS_RULE",
    "WorkLedger",
    "append_rows",
    "apply_candidates",
    "detect_auto",
    "detect_dc",
    "detect_fd",
    "fd_as_dc",
    "filter_mask",
    "make_relation",
    "mark_checked",
    "overlaps_query",
    "plan_query",
    "relax_fd",
    "repair_accuracy",
    "repaired_value",
    "unchecked",
]
