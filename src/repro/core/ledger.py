"""The per-(table, rule, partition-strip) work ledger (DESIGN.md §11).

The paper's DC detection partitions the cartesian comparison matrix and
prunes partitions by boundary ranges (§4.2); the ``dc_pairs`` kernel runs
that plan as a 2-D grid of block tiles (DESIGN.md §7).  Cleaning
*progress*, however, was tracked at whole-(table, rule) granularity —
one monotone version plus an all-or-nothing cold test — so a background
DC increment was one unpreemptible full pairwise pass and a foreground
query could never reuse a half-cleaned scope.  The ledger replaces those
ad-hoc mechanisms with one structure per (table, rule) scope:

* the row space splits into **Okcan–Riedewald block-row strips** of
  ``strip_rows`` rows, aligned to the kernel tile grid (``strip_rows`` is
  a multiple of the detect block, so a strip is a whole number of grid
  rows and a strip-scoped scan is a grid-row range, not a masked full
  sweep);
* every detect/repair commit reports the rows still cold (unchecked and,
  for FDs, statically dirty); the ledger folds them into per-strip cold
  counts, from which strip coverage, cold totals and the Algorithm-2
  support fraction are all host-cheap reads;
* the scope **version** — the service cache's invalidation coordinate
  (DESIGN.md §9/§10) — lives here too: equal ledger vectors over a
  query's dependency scopes imply bit-identical answers, because every
  commit path bumps the ledger exactly when it advances the instance.

Why ledger-equal ⇒ bit-identical (the §11 argument, short form): repairs
merge into the candidate overlay, never into the base columns detection
reads, and the Lemma-4 merge is commutative and associative over
row-disjoint deltas.  A strip therefore contributes the same delta
whenever it is cleaned, and "which strips have contributed" — exactly
what the ledger tracks — determines the overlay state up to merge order,
which the merge erases.

Thread-safety: the ledger is NOT internally locked; every mutation and
every read that must be consistent with the instance happens under the
executor's lock (``Daisy.lock``), which is also what serializes the
background cleaner against foreground queries.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Pseudo-rule naming a table's ROW COUNT as a cache-version coordinate:
# ``Daisy.ingest`` bumps (table, TABLE_ROWS_RULE) so cached answers over a
# grown table go stale even when they overlap no cleaning rule (DESIGN.md
# §12).  Cleaning commits never bump it, so rule-free entries survive all
# background cleaning — invalidation stays exact.
TABLE_ROWS_RULE = "__rows__"


def resolve_strip_rows(strip_rows: Optional[int], block: int) -> int:
    """Align the configured strip size to the detect tile grid: at least
    one block, rounded up to a whole number of blocks (a strip must be a
    contiguous run of kernel grid rows for the strip-scoped scan entry)."""
    base = int(strip_rows) if strip_rows else int(block)
    if base <= 0:
        raise ValueError(f"strip_rows must be positive, got {strip_rows}")
    return -(-base // int(block)) * int(block)


@dataclasses.dataclass
class PendingIngest:
    """One ingest's unprocessed delta against a scope's CHECKED rows
    (DESIGN.md §12).  Fresh rows occupy ``[lo, hi)``; ``checked``
    snapshots which rows were already checked for the rule when the
    append landed (those rows' overlays must absorb the fresh partners'
    evidence without being re-scanned); ``old_dirty`` (FDs only)
    snapshots which rows were statically dirty BEFORE the append — it
    classifies checked rows into "has full old evidence" (merge
    fresh-weighted counts) versus "checked while clean, no evidence"
    (merge full counts).  Entries are processed in append order: each is
    evaluated against rows ``< hi`` only, so a later append's rows never
    leak into an earlier delta."""

    lo: int
    hi: int
    checked: np.ndarray  # (cap,) bool host snapshot at append time
    old_dirty: Optional[np.ndarray] = None  # (cap,) bool, FD scopes only


@dataclasses.dataclass
class StripLedger:
    """Work ledger for ONE (table, rule) scope: per-strip cold-row counts
    plus the scope's monotone version (see the module docstring for the
    locking and soundness contracts).  Since DESIGN.md §12 it also owns
    the scope's ingest state: which strips hold FRESH rows (recent data
    is hot data — the background cleaner's priority signal) and the
    pending ingest-deltas the next cleaning step must process."""

    table: str
    rule: str
    capacity: int
    strip_rows: int
    version: int = 0
    cold_per_strip: np.ndarray = dataclasses.field(default=None)  # (n_strips,) int64
    fresh: set = dataclasses.field(default_factory=set)  # strip ids with fresh rows
    pending: List[PendingIngest] = dataclasses.field(default_factory=list)
    # cumulative DC-scan launch geometry for this scope (DESIGN.md §15):
    # tile pairs actually launched vs skipped by the ledger worklist
    tiles_launched: int = 0
    tiles_skipped: int = 0

    def __post_init__(self):
        if self.cold_per_strip is None:
            self.cold_per_strip = np.zeros(self.n_strips, dtype=np.int64)

    # ------------------------------------------------------------- geometry
    @property
    def n_strips(self) -> int:
        """Number of block-row strips covering the row space."""
        return -(-self.capacity // self.strip_rows)

    def strip_mask(self, strips: Sequence[int]) -> np.ndarray:
        """Row mask (capacity,) selecting the given strips."""
        mask = np.zeros(self.capacity, dtype=bool)
        for s in strips:
            mask[s * self.strip_rows : (s + 1) * self.strip_rows] = True
        return mask

    def strip_blocks(self, strips: Sequence[int], block: int) -> Tuple[int, int]:
        """Covering kernel-grid block-row range [lo, hi) of the given strips
        (the ``row_blocks`` argument of the strip-scoped detect entry).
        ``strip_rows`` is block-aligned, so strip bounds are block bounds.

        One contiguous range, not per-strip runs: warm strips inside the
        range cost only grid iterations — their row blocks are fully
        scoped out, so the kernel's scope-masked bound pruning gives them
        identity bounds and ``@pl.when`` skips the tile body entirely
        (DESIGN.md §7)."""
        per = self.strip_rows // block
        lo = min(strips) * per
        hi = (max(strips) + 1) * per
        return lo, min(hi, -(-self.capacity // block))

    def strip_block_ids(self, strips: Sequence[int], block: int) -> np.ndarray:
        """EXACT kernel-grid block-row ids of the given strips — the
        block-sparse worklist entry (DESIGN.md §15).  Unlike
        ``strip_blocks``, warm strips between the selected ones are not
        covered at all: their tile pairs are absent from the launch, not
        merely scope-pruned inside it.  ``strip_rows`` is block-aligned,
        so each strip contributes a whole run of block ids."""
        per = self.strip_rows // block
        nb = -(-self.capacity // block)
        ids = [
            b
            for s in sorted(set(strips))
            for b in range(s * per, min((s + 1) * per, nb))
        ]
        return np.asarray(ids, dtype=np.int32)

    def cold_block_ids(self, block: int) -> np.ndarray:
        """Block-row ids of every strip still holding cold rows — the row
        side of a full-scope ledger-masked scan (checked x checked tile
        pairs never launch, DESIGN.md §15)."""
        return self.strip_block_ids(np.flatnonzero(self.cold_per_strip > 0), block)

    # ------------------------------------------------------------- progress
    @property
    def cold_count(self) -> int:
        """Rows a first-touch foreground detect would still pay for."""
        return int(self.cold_per_strip.sum())

    @property
    def strips_done(self) -> int:
        """Strips with no cold rows left (fully covered for this rule)."""
        return int((self.cold_per_strip == 0).sum())

    @property
    def support(self) -> float:
        """Fraction of strips covered — the Algorithm-2 support input
        (replaces the diagonal-partition bookkeeping, DESIGN.md §11)."""
        return self.strips_done / max(self.n_strips, 1)

    @property
    def cold_fraction(self) -> float:
        """Cold strips over total strips — prices the REMAINING full-clean
        detection (``CostModel.remaining_full_clean_cost``)."""
        return 1.0 - self.support

    def cold_strips(self, fresh_first: bool = False) -> np.ndarray:
        """Ids of strips that still hold cold rows, ascending — or, with
        ``fresh_first``, fresh strips ahead of stale ones (each group
        ascending): the background cleaner's recent-data-is-hot-data
        ordering (DESIGN.md §12)."""
        cold = np.flatnonzero(self.cold_per_strip > 0)
        if not fresh_first or not self.fresh:
            return cold
        is_fresh = np.isin(cold, sorted(self.fresh))
        return np.concatenate([cold[is_fresh], cold[~is_fresh]])

    @property
    def fresh_cold_count(self) -> int:
        """Cold rows sitting in fresh strips (the ingest-priority signal)."""
        if not self.fresh:
            return 0
        ids = [s for s in self.fresh if s < self.n_strips]
        return int(self.cold_per_strip[ids].sum()) if ids else 0

    def note_fresh(self, lo: int, hi: int) -> None:
        """Mark the strips overlapping row range [lo, hi) as fresh."""
        if hi > lo:
            self.fresh.update(range(lo // self.strip_rows,
                                    -(-hi // self.strip_rows)))

    def prune_fresh(self) -> None:
        """Drop fresh flags on strips that no longer hold cold rows —
        called after commits so the priority signal decays as the fresh
        data gets cleaned."""
        self.fresh = {s for s in self.fresh
                      if s < self.n_strips and self.cold_per_strip[s] > 0}

    def note_tiles(self, launched: int, skipped: int) -> None:
        """Accumulate one DC scan's launch geometry (DESIGN.md §15):
        ``launched`` tile pairs ran, ``skipped`` were pruned from the
        launch by the ledger worklist.  Called under the executor lock."""
        self.tiles_launched += int(launched)
        self.tiles_skipped += int(skipped)

    # -------------------------------------------------------------- commits
    def bump(self) -> None:
        """Advance the scope version (every instance-advancing commit)."""
        self.version += 1

    def observe_cold(self, cold: np.ndarray) -> None:
        """Fold a fresh cold-row mask into per-strip counts.  ``cold`` is
        the (capacity,) host bool mask of rows a foreground detect would
        still scan; called under the executor lock at every commit."""
        cold = np.asarray(cold, dtype=bool)
        pad = self.n_strips * self.strip_rows - cold.shape[0]
        if pad:
            cold = np.pad(cold, (0, pad))
        self.cold_per_strip = cold.reshape(self.n_strips, self.strip_rows).sum(
            axis=1, dtype=np.int64
        )


class WorkLedger:
    """All scopes' strip ledgers behind one lookup — the single progress
    structure foreground cleaning, background cleaning and the service
    cache key on (DESIGN.md §11).  Unknown scopes read as version 0 and
    empty progress, mirroring the old version-dict semantics."""

    def __init__(self, strip_rows: int, block: int):
        self.strip_rows = resolve_strip_rows(strip_rows, block)
        self.block = int(block)
        self._scopes: Dict[Tuple[str, str], StripLedger] = {}

    # ------------------------------------------------------------- registry
    def register(self, table: str, rule: str, capacity: int,
                 cold: Optional[np.ndarray] = None) -> StripLedger:
        """Create (or return) the scope's strip ledger; ``cold`` seeds the
        initial per-strip cold counts.  A scope first seen through a bare
        version bump (capacity 0 — e.g. a rule appended to a live Daisy)
        grows to the real capacity on its first sized registration; the
        version is preserved, the strip grid re-derives."""
        key = (table, rule)
        scope = self._scopes.get(key)
        if scope is None:
            scope = StripLedger(table, rule, int(capacity), self.strip_rows)
            self._scopes[key] = scope
        elif int(capacity) > scope.capacity:
            # growth without a cold mask seeds ALL-COLD, never all-warm: an
            # unknown scope must read as work to do (a warm-seeded scope
            # would skip every clean forever and serve dirty silently); the
            # first checked-bit commit replaces the pessimistic counts with
            # the real ones.
            scope.capacity = int(capacity)
            scope.cold_per_strip = np.full(
                scope.n_strips, scope.strip_rows, dtype=np.int64
            )
        if cold is not None:
            scope.observe_cold(cold)
        return scope

    def scope(self, table: str, rule: str) -> Optional[StripLedger]:
        """The scope's ledger, or None when never registered."""
        return self._scopes.get((table, rule))

    def scopes(self) -> List[StripLedger]:
        """Every registered scope ledger (stable registration order)."""
        return list(self._scopes.values())

    # ------------------------------------------------------------- versions
    def version(self, table: str, rule: str) -> int:
        """Monotone per-scope version (0 for unknown scopes)."""
        scope = self._scopes.get((table, rule))
        return 0 if scope is None else scope.version

    def versions(self, deps: Sequence[Tuple[str, str]]) -> Tuple[int, ...]:
        """Version vector over a dependency list — the service cache's key
        half (read under the executor lock when a cleaner may commit)."""
        return tuple(self.version(t, r) for t, r in deps)

    def bump(self, table: str, rule: str) -> None:
        """Advance one scope's version (auto-registers unknown scopes so a
        commit can never be dropped from the vector)."""
        self.register(table, rule, 0).bump()

    def commit(self, table: str, rule: str, cold: np.ndarray) -> None:
        """One instance-advancing commit that also refreshed coverage:
        bump the version AND fold the new cold mask (checked-bit commits)."""
        scope = self.register(table, rule, cold.shape[0])
        scope.bump()
        scope.observe_cold(cold)
        scope.prune_fresh()

    def record_ingest(
        self,
        table: str,
        rule: str,
        capacity: int,
        cold: np.ndarray,
        lo: int,
        hi: int,
        checked: Optional[np.ndarray] = None,
        old_dirty: Optional[np.ndarray] = None,
    ) -> StripLedger:
        """Fold one append into a rule scope (DESIGN.md §12): extend the
        strip grid to the (possibly grown) capacity, replace the cold
        counts with the post-append mask, mark the strips holding rows
        [lo, hi) fresh, and — when any row was already checked — queue a
        ``PendingIngest`` delta for the next cleaning step.  Does NOT
        bump the scope version: ingest by itself changes no overlay or
        checked bit; the versions move when the delta is processed."""
        scope = self.register(table, rule, capacity, cold=cold)
        scope.note_fresh(lo, hi)
        if checked is not None and bool(np.asarray(checked).any()):
            scope.pending.append(
                PendingIngest(lo=lo, hi=hi, checked=np.asarray(checked, dtype=bool),
                              old_dirty=old_dirty)
            )
        return scope

    def take_pending(self, table: str, rule: str) -> List[PendingIngest]:
        """Claim (and clear) a scope's queued ingest-deltas, append order.
        The caller owns processing them under the executor lock."""
        scope = self._scopes.get((table, rule))
        if scope is None or not scope.pending:
            return []
        out, scope.pending = scope.pending, []
        return out

    def has_pending(self, table: str, rule: str) -> bool:
        """True when the scope has unprocessed ingest-deltas."""
        scope = self._scopes.get((table, rule))
        return scope is not None and bool(scope.pending)

    # ------------------------------------------------------------- progress
    def cold_count(self, table: str, rule: str) -> int:
        scope = self._scopes.get((table, rule))
        return 0 if scope is None else scope.cold_count

    def support(self, table: str, rule: str) -> float:
        scope = self._scopes.get((table, rule))
        return 1.0 if scope is None else scope.support

    def progress(self) -> Dict[str, Dict[str, int]]:
        """JSON-serializable per-scope progress: strips done / total plus
        remaining cold rows (exported by ``service.metrics`` snapshots).
        Capacity-0 scopes — version-only coordinates like the
        ``TABLE_ROWS_RULE`` pseudo-rule — carry no strip grid and are
        skipped."""
        return {
            f"{s.table}/{s.rule}": {
                "strips_done": s.strips_done,
                "strips_total": s.n_strips,
                "cold_rows": s.cold_count,
                "tiles_launched": s.tiles_launched,
                "tiles_skipped": s.tiles_skipped,
            }
            for s in self._scopes.values()
            if s.capacity > 0
        }
