"""The per-(table, rule, partition-strip) work ledger (DESIGN.md §11).

The paper's DC detection partitions the cartesian comparison matrix and
prunes partitions by boundary ranges (§4.2); the ``dc_pairs`` kernel runs
that plan as a 2-D grid of block tiles (DESIGN.md §7).  Cleaning
*progress*, however, was tracked at whole-(table, rule) granularity —
one monotone version plus an all-or-nothing cold test — so a background
DC increment was one unpreemptible full pairwise pass and a foreground
query could never reuse a half-cleaned scope.  The ledger replaces those
ad-hoc mechanisms with one structure per (table, rule) scope:

* the row space splits into **Okcan–Riedewald block-row strips** of
  ``strip_rows`` rows, aligned to the kernel tile grid (``strip_rows`` is
  a multiple of the detect block, so a strip is a whole number of grid
  rows and a strip-scoped scan is a grid-row range, not a masked full
  sweep);
* every detect/repair commit reports the rows still cold (unchecked and,
  for FDs, statically dirty); the ledger folds them into per-strip cold
  counts, from which strip coverage, cold totals and the Algorithm-2
  support fraction are all host-cheap reads;
* the scope **version** — the service cache's invalidation coordinate
  (DESIGN.md §9/§10) — lives here too: equal ledger vectors over a
  query's dependency scopes imply bit-identical answers, because every
  commit path bumps the ledger exactly when it advances the instance.

Why ledger-equal ⇒ bit-identical (the §11 argument, short form): repairs
merge into the candidate overlay, never into the base columns detection
reads, and the Lemma-4 merge is commutative and associative over
row-disjoint deltas.  A strip therefore contributes the same delta
whenever it is cleaned, and "which strips have contributed" — exactly
what the ledger tracks — determines the overlay state up to merge order,
which the merge erases.

Thread-safety: the ledger is NOT internally locked; every mutation and
every read that must be consistent with the instance happens under the
executor's lock (``Daisy.lock``), which is also what serializes the
background cleaner against foreground queries.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def resolve_strip_rows(strip_rows: Optional[int], block: int) -> int:
    """Align the configured strip size to the detect tile grid: at least
    one block, rounded up to a whole number of blocks (a strip must be a
    contiguous run of kernel grid rows for the strip-scoped scan entry)."""
    base = int(strip_rows) if strip_rows else int(block)
    if base <= 0:
        raise ValueError(f"strip_rows must be positive, got {strip_rows}")
    return -(-base // int(block)) * int(block)


@dataclasses.dataclass
class StripLedger:
    """Work ledger for ONE (table, rule) scope: per-strip cold-row counts
    plus the scope's monotone version (see the module docstring for the
    locking and soundness contracts)."""

    table: str
    rule: str
    capacity: int
    strip_rows: int
    version: int = 0
    cold_per_strip: np.ndarray = dataclasses.field(default=None)  # (n_strips,) int64

    def __post_init__(self):
        if self.cold_per_strip is None:
            self.cold_per_strip = np.zeros(self.n_strips, dtype=np.int64)

    # ------------------------------------------------------------- geometry
    @property
    def n_strips(self) -> int:
        """Number of block-row strips covering the row space."""
        return -(-self.capacity // self.strip_rows)

    def strip_mask(self, strips: Sequence[int]) -> np.ndarray:
        """Row mask (capacity,) selecting the given strips."""
        mask = np.zeros(self.capacity, dtype=bool)
        for s in strips:
            mask[s * self.strip_rows : (s + 1) * self.strip_rows] = True
        return mask

    def strip_blocks(self, strips: Sequence[int], block: int) -> Tuple[int, int]:
        """Covering kernel-grid block-row range [lo, hi) of the given strips
        (the ``row_blocks`` argument of the strip-scoped detect entry).
        ``strip_rows`` is block-aligned, so strip bounds are block bounds.

        One contiguous range, not per-strip runs: warm strips inside the
        range cost only grid iterations — their row blocks are fully
        scoped out, so the kernel's scope-masked bound pruning gives them
        identity bounds and ``@pl.when`` skips the tile body entirely
        (DESIGN.md §7)."""
        per = self.strip_rows // block
        lo = min(strips) * per
        hi = (max(strips) + 1) * per
        return lo, min(hi, -(-self.capacity // block))

    # ------------------------------------------------------------- progress
    @property
    def cold_count(self) -> int:
        """Rows a first-touch foreground detect would still pay for."""
        return int(self.cold_per_strip.sum())

    @property
    def strips_done(self) -> int:
        """Strips with no cold rows left (fully covered for this rule)."""
        return int((self.cold_per_strip == 0).sum())

    @property
    def support(self) -> float:
        """Fraction of strips covered — the Algorithm-2 support input
        (replaces the diagonal-partition bookkeeping, DESIGN.md §11)."""
        return self.strips_done / max(self.n_strips, 1)

    @property
    def cold_fraction(self) -> float:
        """Cold strips over total strips — prices the REMAINING full-clean
        detection (``CostModel.remaining_full_clean_cost``)."""
        return 1.0 - self.support

    def cold_strips(self) -> np.ndarray:
        """Ascending ids of strips that still hold cold rows."""
        return np.flatnonzero(self.cold_per_strip > 0)

    # -------------------------------------------------------------- commits
    def bump(self) -> None:
        """Advance the scope version (every instance-advancing commit)."""
        self.version += 1

    def observe_cold(self, cold: np.ndarray) -> None:
        """Fold a fresh cold-row mask into per-strip counts.  ``cold`` is
        the (capacity,) host bool mask of rows a foreground detect would
        still scan; called under the executor lock at every commit."""
        cold = np.asarray(cold, dtype=bool)
        pad = self.n_strips * self.strip_rows - cold.shape[0]
        if pad:
            cold = np.pad(cold, (0, pad))
        self.cold_per_strip = cold.reshape(self.n_strips, self.strip_rows).sum(
            axis=1, dtype=np.int64
        )


class WorkLedger:
    """All scopes' strip ledgers behind one lookup — the single progress
    structure foreground cleaning, background cleaning and the service
    cache key on (DESIGN.md §11).  Unknown scopes read as version 0 and
    empty progress, mirroring the old version-dict semantics."""

    def __init__(self, strip_rows: int, block: int):
        self.strip_rows = resolve_strip_rows(strip_rows, block)
        self.block = int(block)
        self._scopes: Dict[Tuple[str, str], StripLedger] = {}

    # ------------------------------------------------------------- registry
    def register(self, table: str, rule: str, capacity: int,
                 cold: Optional[np.ndarray] = None) -> StripLedger:
        """Create (or return) the scope's strip ledger; ``cold`` seeds the
        initial per-strip cold counts.  A scope first seen through a bare
        version bump (capacity 0 — e.g. a rule appended to a live Daisy)
        grows to the real capacity on its first sized registration; the
        version is preserved, the strip grid re-derives."""
        key = (table, rule)
        scope = self._scopes.get(key)
        if scope is None:
            scope = StripLedger(table, rule, int(capacity), self.strip_rows)
            self._scopes[key] = scope
        elif int(capacity) > scope.capacity:
            # growth without a cold mask seeds ALL-COLD, never all-warm: an
            # unknown scope must read as work to do (a warm-seeded scope
            # would skip every clean forever and serve dirty silently); the
            # first checked-bit commit replaces the pessimistic counts with
            # the real ones.
            scope.capacity = int(capacity)
            scope.cold_per_strip = np.full(
                scope.n_strips, scope.strip_rows, dtype=np.int64
            )
        if cold is not None:
            scope.observe_cold(cold)
        return scope

    def scope(self, table: str, rule: str) -> Optional[StripLedger]:
        """The scope's ledger, or None when never registered."""
        return self._scopes.get((table, rule))

    def scopes(self) -> List[StripLedger]:
        """Every registered scope ledger (stable registration order)."""
        return list(self._scopes.values())

    # ------------------------------------------------------------- versions
    def version(self, table: str, rule: str) -> int:
        """Monotone per-scope version (0 for unknown scopes)."""
        scope = self._scopes.get((table, rule))
        return 0 if scope is None else scope.version

    def versions(self, deps: Sequence[Tuple[str, str]]) -> Tuple[int, ...]:
        """Version vector over a dependency list — the service cache's key
        half (read under the executor lock when a cleaner may commit)."""
        return tuple(self.version(t, r) for t, r in deps)

    def bump(self, table: str, rule: str) -> None:
        """Advance one scope's version (auto-registers unknown scopes so a
        commit can never be dropped from the vector)."""
        self.register(table, rule, 0).bump()

    def commit(self, table: str, rule: str, cold: np.ndarray) -> None:
        """One instance-advancing commit that also refreshed coverage:
        bump the version AND fold the new cold mask (checked-bit commits)."""
        scope = self.register(table, rule, cold.shape[0])
        scope.bump()
        scope.observe_cold(cold)

    # ------------------------------------------------------------- progress
    def cold_count(self, table: str, rule: str) -> int:
        scope = self._scopes.get((table, rule))
        return 0 if scope is None else scope.cold_count

    def support(self, table: str, rule: str) -> float:
        scope = self._scopes.get((table, rule))
        return 1.0 if scope is None else scope.support

    def progress(self) -> Dict[str, Dict[str, int]]:
        """JSON-serializable per-scope progress: strips done / total plus
        remaining cold rows (exported by ``service.metrics`` snapshots)."""
        return {
            f"{s.table}/{s.rule}": {
                "strips_done": s.strips_done,
                "strips_total": s.n_strips,
                "cold_rows": s.cold_count,
            }
            for s in self._scopes.values()
        }
