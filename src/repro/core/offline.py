"""The paper's offline comparison baseline (§7 "our own offline implementation").

Full-dataset cleaning before any query arrives, combining the
state-of-the-art optimizations the paper credits:

* FD error detection: BigDansing-style group-by instead of a self-join —
  identical to our sort-based ``detect_fd`` over the WHOLE relation;
* DC error detection: the optimized theta-join (same ``dc_pairs`` scan, full
  matrix scope);
* data repairing: HoloClean-style co-occurrence domain pruning — candidate
  values for an erroneous rhs are the rhs values of tuples sharing its lhs
  (exactly the group-distinct candidate table), probabilistic output.

After ``clean_all`` the database is fully probabilistic; ``execute`` runs
queries through a rule-free Daisy executor (the cleaning steps no-op on a
fully checked relation).  Integration tests assert the FD-correctness
guarantee: Daisy's incremental answers == offline answers (§1 contribution 1).
"""

from __future__ import annotations

from typing import Dict, Sequence

from repro.core.constraints import DC, FD
from repro.core.detect import detect_dc, detect_fd
from repro.core.executor import Daisy, DaisyConfig, DaisyResult
from repro.core.operators import Query
from repro.core.relation import Relation
from repro.core.repair import dc_repair_candidates, fd_repair_candidates
from repro.core.update import apply_candidates, mark_checked


class OfflineCleaner:
    """Clean everything up front, then answer queries."""

    def __init__(
        self,
        db: Dict[str, Relation],
        rules: Dict[str, Sequence[FD | DC]],
        config: DaisyConfig | None = None,
    ):
        self.config = config or DaisyConfig()
        self.rules = {t: list(rs) for t, rs in rules.items()}
        self.db = dict(db)
        self._engine: Daisy | None = None

    def clean_all(self) -> None:
        for table, rules in self.rules.items():
            rel = self.db[table]
            for rule in rules:
                if isinstance(rule, FD):
                    det = detect_fd(rel, rule, rel.valid, k=self.config.k)
                    deltas = fd_repair_candidates(rel, rule, det, rel.valid)
                else:
                    det = detect_dc(
                        rel, rule, rel.valid, rel.valid, block=self.config.dc_block
                    )
                    deltas = dc_repair_candidates(rel, rule, det, rel.valid, k=self.config.k)
                rel = apply_candidates(rel, deltas)
                rel = mark_checked(rel, rule.name, rel.valid)
            self.db[table] = rel

    def execute(self, query: Query) -> DaisyResult:
        if self._engine is None:
            # rules kept (for join re-checks) but everything is checked, so
            # cleaning steps no-op; disable the cost model and stats re-scan.
            cfg = DaisyConfig(**{**self.config.__dict__, "use_cost_model": False,
                                 "collect_stats": False})
            self._engine = Daisy(self.db, self.rules, cfg)
        result = self._engine.execute(query)
        self.db = self._engine.db
        return result
