"""Query result relaxation (paper §4.1, Algorithm 1).

Given a query answer ``A`` (a boolean mask over the relation) and an FD
``lhs -> rhs``, augment ``A`` with *correlated tuples*: unvisited tuples whose
lhs key appears among the answer's lhs keys, or whose rhs value appears among
the answer's rhs values.  Iterate to a transitive-closure fixpoint
(Example 3: the closure walks lhs- and rhs-sharing chains).

The pseudocode of Algorithm 1 keeps ``A`` fixed while draining ``unvisited``;
the accompanying text and Example 3 make clear the intended semantics is the
transitive closure ("Algorithm 1 determines the whole cluster of correlated
entities"), so each iteration recomputes the frontier from ``A ∪ total_extra``.

Faithfulness hooks:
* Lemma 1 — a filter on the **rhs** converges after ONE iteration (the lhs
  expansion already covers every candidate; the rhs expansion adds nothing).
  ``relax_fd`` reports the iteration count so tests can assert this.
* Lemma 2 — the probability that one more iteration is needed is estimated
  with the hypergeometric expression (``lemma2_prob``).
* Lemma 3 — ``lemma3_upper_bound`` computes the relaxed-size upper bound
  from the dataset / result frequency distributions.

TPU adaptation: masks instead of dynamic sets, ``lax.while_loop`` with a
static ``max_iters`` bound (the closure's diameter is <= n, but every round
at least doubles the reached cluster frontier through a shared value, so
``ceil(log2(n)) + 2`` rounds suffice; we expose the bound and a converged
flag).  Membership tests are exact sort-merge semijoins (``setops.member_in``)
or the blocked Pallas ``semijoin`` kernel for single-column keys.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.constraints import FD
from repro.core.relation import Relation
from repro.core.setops import member_in


class RelaxResult(NamedTuple):
    extra: jnp.ndarray  # (cap,) bool — total_extra of Algorithm 1
    iterations: jnp.ndarray  # () int32 — rounds until fixpoint
    converged: jnp.ndarray  # () bool — fixpoint reached within max_iters


def default_max_iters(capacity: int) -> int:
    return int(math.ceil(math.log2(max(capacity, 2)))) + 2


def relax_fd(
    rel: Relation,
    answer: jnp.ndarray,
    fd: FD,
    max_iters: int | None = None,
    use_rhs: bool = True,
) -> RelaxResult:
    """Algorithm 1: compute the correlated extra tuples for ``answer``.

    ``use_rhs=False`` restricts expansion to lhs-sharing only (used by the
    planner when the filter is on the rhs — per Lemma 1 the rhs expansion is
    provably empty, so skipping it saves a semijoin).
    """
    iters = max_iters or default_max_iters(rel.capacity)
    lhs_cols = [rel.columns[a] for a in fd.lhs]
    rhs_col = rel.columns[fd.rhs]
    valid = rel.valid
    answer = answer & valid

    def body(state):
        reached, unvisited, it, _changed = state
        # line 6: unvisited tuples sharing an lhs key with the reached set
        extra_l = member_in(lhs_cols, unvisited, lhs_cols, reached)
        unvisited = unvisited & ~extra_l
        reached = reached | extra_l
        if use_rhs:
            # line 8: unvisited tuples sharing an rhs value with the reached set
            extra_r = member_in([rhs_col], unvisited, [rhs_col], reached)
            unvisited = unvisited & ~extra_r
            reached = reached | extra_r
            changed = jnp.any(extra_l) | jnp.any(extra_r)
        else:
            changed = jnp.any(extra_l)
        return reached, unvisited, it + 1, changed

    def cond(state):
        _, _, it, changed = state
        return changed & (it < iters)

    init = (answer, valid & ~answer, jnp.int32(0), jnp.bool_(True))
    reached, unvisited, it, changed = jax.lax.while_loop(cond, body, init)
    return RelaxResult(
        extra=reached & ~answer,
        iterations=it,
        converged=~changed,
    )


def lemma2_prob(n: int, num_violations: int, relaxed_size: int) -> float:
    """Lemma 2: P(>=1 violation inside a relaxed result of size |A_R|).

    Hypergeometric: 1 - C(n - #vio, |A_R|) / C(n, |A_R|).
    Computed in log-space to stay stable for large n.
    """
    n = int(n)
    v = int(num_violations)
    a = int(relaxed_size)
    if v <= 0 or a <= 0:
        return 0.0
    if a > n - v:
        return 1.0
    log_p0 = (
        math.lgamma(n - v + 1)
        - math.lgamma(n - v - a + 1)
        + math.lgamma(n - a + 1)
        - math.lgamma(n + 1)
    )
    return 1.0 - math.exp(log_p0)


def lemma3_upper_bound(
    dataset_freq: Sequence[jnp.ndarray], result_freq: Sequence[jnp.ndarray]
) -> jnp.ndarray:
    """Lemma 3: upper bound on the relaxed result growth per iteration.

    For each constraint attribute ``A_i``, ``dataset_freq[i]`` / ``result_freq[i]``
    hold the dataset / result frequencies of the attribute's values that occur
    in the result.  R = sum_i (sum_j D_ij - sum_j Dq_ij).
    """
    total = jnp.float32(0.0)
    for d, q in zip(dataset_freq, result_freq):
        total = total + jnp.sum(d) - jnp.sum(q)
    return total
