"""In-place probabilistic dataset update (paper §4, §6).

"After the execution of each query, we isolate the changes, and apply the
delta to the original dataset" — here the delta is a set of per-attribute
``Candidates`` overlays, merged into the Relation pytree functionally
(donated buffers give true in-place on TPU).

``merge_candidates`` implements the Lemma-4 merge: the union of two candidate
sets with counts summed for identical (value, kind) pairs, and same-kind
range candidates coalesced to the tighter bound (see ``_dedupe_sum``) —
commutative and associative by construction, property-tested in
tests/test_properties.py.  Overflow beyond the K overlay slots keeps the K
heaviest candidates (DESIGN.md §2 assumption (a)).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.relation import Relation
from repro.core.repair import Candidates


def _dedupe_sum(values, counts, kinds):
    """Per-row: merge duplicate slots, zeroing the absorbed one.

    Two slots merge when they denote the same candidate *world set*:

    * identical ``(value, kind)`` pairs — counts summed (Lemma 4's union
      with multiplicity);
    * same-kind RANGE candidates (``CAND_LT``/``CAND_GT``) over the same
      attribute — counts summed and the bound *tightened* (max for GT,
      min for LT).  A range fix must invert its atom against every known
      violating partner (Example 4): keeping the looser of two bounds
      would admit still-violating worlds, and tightening is what makes a
      partner scan decomposable over row partitions (the bound over
      old ∪ fresh rows is exactly max/min of the per-partition bounds —
      the ingest-delta exactness argument, DESIGN.md §12).  max/min are
      commutative/associative, so the Lemma-4 merge laws survive.

    O(K^2) slot-pair comparisons, vectorized over rows — K is small
    (<=16).  Empty slots (count 0) never match anything.  Returns the
    merged ``(values, counts)`` (kinds are unchanged: a merge only ever
    happens between same-kind slots).
    """
    k2 = values.shape[1]
    out_values = values
    out_counts = counts
    for i in range(k2):
        for j in range(i + 1, k2):
            alive = (out_counts[:, i] > 0) & (out_counts[:, j] > 0)
            same_kind = kinds[:, i] == kinds[:, j]
            is_range = kinds[:, i] != 0  # CAND_LT / CAND_GT
            same = alive & same_kind & (
                is_range | (out_values[:, i] == out_values[:, j])
            )
            tighter = jnp.where(
                kinds[:, i] == 2,  # CAND_GT: (bound, +inf) — keep the max bound
                jnp.maximum(out_values[:, i], out_values[:, j]),
                jnp.minimum(out_values[:, i], out_values[:, j]),
            )
            out_values = out_values.at[:, i].set(
                jnp.where(same & is_range, tighter, out_values[:, i])
            )
            out_counts = out_counts.at[:, i].set(
                jnp.where(same, out_counts[:, i] + out_counts[:, j], out_counts[:, i])
            )
            out_counts = out_counts.at[:, j].set(
                jnp.where(same, 0.0, out_counts[:, j])
            )
    return out_values, out_counts


@functools.partial(jax.jit, static_argnums=(6,))
def merge_candidates(
    a_values, a_counts, a_kinds, b_values, b_counts, b_kinds, k: int
):
    """Union-merge two per-row candidate sets, keep top-k by count.

    Jitted (k static): the O(K^2) dedupe unrolls into one fused kernel
    instead of ~K^2 eager dispatches.
    """
    values = jnp.concatenate([a_values, b_values], axis=1)
    counts = jnp.concatenate([a_counts, b_counts], axis=1)
    kinds = jnp.concatenate([a_kinds, b_kinds], axis=1)
    values, counts = _dedupe_sum(values, counts, kinds)
    # top-k by count (stable: ties keep lower slot first)
    order = jnp.argsort(-counts, axis=1, stable=True)[:, :k]
    rows = jnp.arange(values.shape[0])[:, None]
    return values[rows, order], counts[rows, order], kinds[rows, order]


def apply_candidates(
    rel: Relation, deltas: Sequence[Tuple[str, Candidates]]
) -> Relation:
    """Merge candidate deltas into the relation's overlay (rows-masked)."""
    cand = dict(rel.cand)
    ccount = dict(rel.ccount)
    ckind = dict(rel.ckind)
    k = rel.k
    for attr, delta in deltas:
        if attr not in cand:
            raise KeyError(
                f"attribute {attr!r} has no overlay; pass it in make_relation(overlay=...)"
            )
        mv, mc, mk = merge_candidates(
            cand[attr],
            ccount[attr],
            ckind[attr],
            delta.values,
            jnp.where(delta.rows[:, None], delta.counts, 0.0),
            delta.kinds,
            k,
        )
        rows = delta.rows[:, None]
        cand[attr] = jnp.where(rows, mv, cand[attr])
        ccount[attr] = jnp.where(rows, mc, ccount[attr])
        ckind[attr] = jnp.where(rows, mk, ckind[attr])
    return dataclasses.replace(rel, cand=cand, ccount=ccount, ckind=ckind)


def mark_checked(rel: Relation, rule_name: str, scope: jnp.ndarray) -> Relation:
    """Record that ``scope`` rows have been checked for ``rule_name``
    ("Daisy maintains information about which tuples have been checked for
    each rule", §4.3)."""
    checked = dict(rel.checked)
    prev = checked.get(rule_name)
    if prev is None:
        prev = jnp.zeros_like(rel.valid)
    checked[rule_name] = prev | (scope & rel.valid)
    return dataclasses.replace(rel, checked=checked)


def unchecked(rel: Relation, rule_name: str) -> jnp.ndarray:
    prev = rel.checked.get(rule_name)
    if prev is None:
        return rel.valid
    return rel.valid & ~prev
