"""The cost model (paper §5.2): incremental vs full cleaning, online.

Implements the two cost expressions and the online Inequality-(1) check that
drives the strategy switch seen in Figs. 9 and 14 ("Daisy initially applies
data cleaning incrementally, and then, by evaluating the total cost after
each query, switches strategy and applies the cleaning task over the rest of
the dataset").

Two extensions beyond the paper's formulas live here as well (DESIGN.md §10):

* **Sharded detection pricing.**  When the executor detects over the
  key-routed shuffle (DESIGN.md §8) it feeds the observed
  ``ShardedDetectInfo`` — per-shard row counts and the retry history —
  back through ``observe_detect_cost``, so the full/partial decision
  prices the *sharded* comparison space (``Σ rows_s²`` plus the shuffle
  passes) instead of the dense ``n²/partitions`` estimate.
* **Background scope priorities.**  ``ScopePriority`` /
  ``prioritize_scopes`` rank the cold (unchecked-and-dirty) rule scopes a
  background cleaner should full-clean first: expected detect pair-count
  a first-touch foreground query would pay, times the touch probability
  observed in session lineage.

Per-query incremental cost (formula (1)):

    (n - sum_{j<i} q_j)                relaxation over the unknown tuples
  +  d_i                               error detection over q_i + e_i
  +  eps_i (q_i + e_i)                 data repairing over the enhanced result
  +  (n - sum eps_j) + p sum eps_j     probabilistic dataset update
  +  eps_i p

Offline cost (per §5.2.1, plus executing the q queries over clean data):

    q n + df + eps n + n + eps p

All quantities are row counts — the model compares relative work, as in the
paper (both sides run on the same executor so constants cancel).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional


def sharded_detect_cost(info, n_rows: Optional[int] = None) -> float:
    """Price a full-scope sharded detect from an observed routing.

    ``info`` is duck-typed as ``repro.dist.detect.ShardedDetectInfo``
    (``n_shards``, ``per_shard_rows``, ``routed_rows``, ``retries``,
    ``sharded_pairs``) — this module stays importable without the dist
    layer.  The estimate is the uniform per-shard pair count at ``n_rows``
    scaled by the observed skew (actual routed pairs over the uniform pair
    count of the observed routing), plus one shuffle pass over the rows per
    attempt the retry history says the routing needed.
    """
    n = int(n_rows if n_rows is not None else info.routed_rows)
    shards = max(int(info.n_shards), 1)
    per = -(-n // shards)
    uniform = float(shards * per * per)
    if info.routed_rows:
        obs_per = -(-int(info.routed_rows) // shards)
        obs_uniform = float(shards * obs_per * obs_per) or 1.0
        skew = max(float(info.sharded_pairs) / obs_uniform, 1.0)
    else:
        skew = 1.0
    return uniform * skew + (int(info.retries) + 1) * n


@dataclasses.dataclass(frozen=True)
class ScopePriority:
    """One cold (table, rule) scope ranked for background cleaning
    (DESIGN.md §10).

    ``expected_pairs`` is the detect comparison-space a first-touch
    foreground query would pay on this scope right now — the rule's
    effective full-detect cost (dense, or sharded once the executor has
    observed a routing) scaled by the cold fraction.  ``touch_probability``
    is the Laplace-smoothed share of recently answered queries whose
    dependency set included this scope (from session lineage), i.e. how
    likely the next query is to pay that first touch.
    """

    table: str
    rule: str
    cold_rows: int  # unchecked rows a foreground detect would still scan
    expected_pairs: float
    touch_probability: float
    # streaming ingest (DESIGN.md §12): >1 when the scope holds FRESH cold
    # strips or queued ingest-deltas — appended rows are the coldest state a
    # foreground query can hit, so they outrank equally-priced steady scopes
    fresh_boost: float = 1.0
    pending: bool = False  # queued ingest-deltas awaiting _process_pending

    @property
    def priority(self) -> float:
        """Expected foreground work saved by cleaning this scope now."""
        return self.expected_pairs * self.touch_probability * self.fresh_boost


def prioritize_scopes(scopes: Iterable[ScopePriority]) -> List[ScopePriority]:
    """Sort cold scopes by descending expected saved work; drop warm ones.
    A scope with zero cold rows but queued ingest-deltas is still work
    (DESIGN.md §12) and is kept.

    Ties break on (table, rule) so the background cleaner's pick is
    deterministic under equal priorities (the seeded interleaving tests
    rely on that).
    """
    return sorted(
        (s for s in scopes if s.cold_rows > 0 or s.pending),
        key=lambda s: (-s.priority, s.table, s.rule),
    )


@dataclasses.dataclass
class QueryCost:
    q_i: int  # result size
    e_i: int  # extra (relaxed) tuples
    d_i: float  # detection cost actually incurred
    eps_i: int  # errors repaired this query


@dataclasses.dataclass
class CostModel:
    """Online cost model for one (relation, rule) pair."""

    n: int  # dataset size
    epsilon: int  # estimated total errors (from stats)
    p: float  # estimated candidate-set size per error (from stats)
    df: float  # full-clean detection cost estimate (n for FDs, n^2/parts for DCs)
    expected_queries: int = 50  # workload length estimate (paper: known q)
    history: List[QueryCost] = dataclasses.field(default_factory=list)
    switched: bool = False
    # observed full-detect cost on the sharded path (DESIGN.md §8/§10):
    # None until the executor has seen a ShardedDetectInfo for this rule
    df_observed: Optional[float] = None
    # ledger strip coverage (DESIGN.md §11): fraction of the scope's strips
    # still cold, fed by the executor at every commit.  None until observed;
    # with it, the remaining-full-clean price shrinks as strips complete —
    # foreground OR background — so the Inequality-(1) flip can fire
    # mid-scope instead of waiting on query-coverage estimates.
    cold_fraction: Optional[float] = None
    # measured tile-level launch sparsity of the last full-mode DC scan
    # (tiles launched / dense tiles, DESIGN.md §15): the kernel-truth
    # counterpart of ``cold_fraction`` — identical for block-aligned strips,
    # but measured from the worklist the scan actually launched
    tile_ratio: Optional[float] = None

    # -------------------------------------------------------------- records
    def record(self, q_i: int, e_i: int, d_i: float, eps_i: int) -> None:
        self.history.append(QueryCost(q_i, e_i, d_i, eps_i))

    def observe_progress(self, cold_fraction: float) -> None:
        """Record the ledger's current cold-strip fraction for this scope
        (the executor calls this from every ``_mark`` commit)."""
        self.cold_fraction = min(max(float(cold_fraction), 0.0), 1.0)

    def observe_tile_sparsity(self, ratio: float) -> None:
        """Record a full-mode scan's measured launch ratio — tiles launched
        over the dense tile count (DESIGN.md §15)."""
        self.tile_ratio = min(max(float(ratio), 0.0), 1.0)

    def observe_detect_cost(self, cost: float) -> None:
        """Record an observed full-detect cost (e.g. ``sharded_detect_cost``
        of a routing the executor actually ran), so the full/partial decision
        prices the execution path detection will really take."""
        self.df_observed = cost if self.df_observed is None else min(
            self.df_observed, cost
        )

    @property
    def df_effective(self) -> float:
        """Full-detect cost the decision should use: the static estimate,
        improved by the cheapest observed (sharded) detect if any."""
        return self.df if self.df_observed is None else min(self.df, self.df_observed)

    @property
    def seen_rows(self) -> int:
        return sum(h.q_i for h in self.history)

    @property
    def repaired_errors(self) -> int:
        return sum(h.eps_i for h in self.history)

    # ---------------------------------------------------------------- costs
    def _update_cost(self, prior_eps: int, eps_i: int) -> float:
        """Probabilistic-update (outer-join) cost.  Implementation refinement
        over the raw formula (documented in DESIGN.md §2): Daisy isolates the
        delta first, so an EMPTY delta skips the outer-join entirely — the
        n-scan is only paid when eps_i > 0."""
        if eps_i <= 0:
            return 0.0
        return (self.n - prior_eps) + self.p * prior_eps + eps_i * self.p

    def incremental_query_cost(self, q_i: int, e_i: int, d_i: float, eps_i: int) -> float:
        prior_q = self.seen_rows
        prior_eps = self.repaired_errors
        relax = max(self.n - prior_q, 0)
        repair = eps_i * (q_i + e_i)
        return relax + d_i + repair + self._update_cost(prior_eps, eps_i)

    def incremental_cost_so_far(self) -> float:
        total = 0.0
        prior_q = 0
        prior_eps = 0
        for h in self.history:
            relax = max(self.n - prior_q, 0)
            repair = h.eps_i * (h.q_i + h.e_i)
            total += relax + h.d_i + repair + self._update_cost(prior_eps, h.eps_i)
            prior_q += h.q_i
            prior_eps += h.eps_i
        return total

    def projected_incremental_remaining(self) -> float:
        """Extrapolate the remaining workload.  Future relax scans shrink
        with coverage (the formula's ``n - sum q_j``), and future updates are
        only paid while errors remain, so the projection uses the CURRENT
        state, not the historical average: each remaining query costs the
        cost the next query would, with the error stream assumed to continue
        at the observed dirty-query rate until ``epsilon`` is exhausted."""
        done = len(self.history)
        remaining = max(self.expected_queries - done, 0)
        if done == 0 or remaining == 0:
            return 0.0
        avg_q = self.seen_rows / done
        avg_e = sum(h.e_i for h in self.history) / done
        avg_d = sum(h.d_i for h in self.history) / done
        dirty_queries = sum(1 for h in self.history if h.eps_i > 0)
        avg_eps = self.repaired_errors / max(dirty_queries, 1)
        dirty_rate = dirty_queries / done
        eps_left = max(self.epsilon - self.repaired_errors, 0)
        total = 0.0
        seen = float(self.seen_rows)
        prior_eps = float(self.repaired_errors)
        for _ in range(remaining):
            eps_i = avg_eps if (dirty_rate > 0 and eps_left > 0) else 0.0
            eps_i = min(eps_i, eps_left)
            relax = max(self.n - seen, 0.0)
            repair = eps_i * (avg_q + avg_e)
            update = (
                (self.n - prior_eps) + self.p * prior_eps + eps_i * self.p
                if eps_i > 0
                else 0.0
            )
            total += relax + avg_d + repair + update
            seen += avg_q
            prior_eps += eps_i
            eps_left -= eps_i
        return total

    def offline_cost(self) -> float:
        q = self.expected_queries
        return (
            q * self.n
            + self.df_effective
            + self.epsilon * self.n
            + self.n
            + self.epsilon * self.p
        )

    def remaining_full_clean_cost(self) -> float:
        """Cleaning the REST of the dataset now (what the switch buys):
        detection over the still-cold part + repair of remaining errors +
        update.  The cold part is the ledger's strip-coverage fraction when
        observed (DESIGN.md §11) — query-coverage row sums double-count
        revisited rows, the ledger does not — else the row-sum estimate."""
        unseen = max(self.n - self.seen_rows, 0)
        eps_left = max(self.epsilon - self.repaired_errors, 0)
        frac = unseen / max(self.n, 1)
        if self.cold_fraction is not None:
            frac = min(frac, self.cold_fraction)
        detect_frac = frac
        if self.tile_ratio is not None:
            # the detect term prices kernel launches, and the worklist scan
            # measures exactly what fraction of the dense grid it launches
            # (DESIGN.md §15); repair/update stay row-fraction priced
            detect_frac = min(detect_frac, self.tile_ratio)
        return (
            detect_frac * self.df_effective
            + eps_left * frac * self.p
            + frac * self.n
        )

    # -------------------------------------------------------------- decision
    def should_switch_to_full(self) -> bool:
        """Inequality (1) evaluated online: switch when the projected
        incremental remainder exceeds full-cleaning the remaining dirty part
        (plus running the remaining queries over clean data)."""
        if self.switched:
            return False
        done = len(self.history)
        remaining_q = max(self.expected_queries - done, 0)
        if done == 0 or remaining_q == 0:
            return False
        incremental = self.projected_incremental_remaining()
        full = self.remaining_full_clean_cost() + remaining_q * self.n
        return incremental > full

    def mark_switched(self) -> None:
        self.switched = True
