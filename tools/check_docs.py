#!/usr/bin/env python
"""Fail when src/ cites a DESIGN.md section that has no matching header.

Docstrings reference design sections as ``DESIGN.md §N``; DESIGN.md marks
section headers as ``## §N Title``.  This check keeps the two in sync the
same way the collect-only CI job keeps imports in sync: a citation to a
section that was renumbered or never written fails in seconds.

Run from the repo root (CI docs job and tests/test_docs.py both do):

    python tools/check_docs.py
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

CITE = re.compile(r"DESIGN\.md\s*§(\d+)")
HEADER = re.compile(r"^#+\s*§(\d+)\b", re.M)


def cited_sections() -> dict[str, set[str]]:
    """section number -> files citing it."""
    cites: dict[str, set[str]] = {}
    for path in sorted((ROOT / "src").rglob("*.py")):
        for num in CITE.findall(path.read_text()):
            cites.setdefault(num, set()).add(str(path.relative_to(ROOT)))
    return cites


def check() -> list[str]:
    problems = []
    design = ROOT / "DESIGN.md"
    if not design.exists():
        return ["DESIGN.md does not exist but src/ docstrings cite it"]
    headers = set(HEADER.findall(design.read_text()))
    for num, files in sorted(cited_sections().items(), key=lambda kv: int(kv[0])):
        if num not in headers:
            problems.append(
                f"DESIGN.md §{num} is cited by {', '.join(sorted(files))} "
                f"but DESIGN.md has no '§{num}' header"
            )
    if not (ROOT / "README.md").exists():
        problems.append("README.md does not exist")
    return problems


def main() -> int:
    problems = check()
    for p in problems:
        print(f"ERROR: {p}", file=sys.stderr)
    if not problems:
        cites = cited_sections()
        total = sum(len(v) for v in cites.values())
        print(
            f"docs OK: {len(cites)} DESIGN.md sections cited from "
            f"{total} file references"
        )
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
