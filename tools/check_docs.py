#!/usr/bin/env python
"""Fail when the repo's docs rot: dangling DESIGN.md section citations,
dangling markdown links/anchors, or undocumented public service API.

Four checks, all static (stdlib only — the CI docs job runs without jax):

1. **Section citations.**  Docstrings reference design sections as
   ``DESIGN.md §N``; DESIGN.md marks section headers as ``## §N Title``.
   A citation to a section that was renumbered or never written fails.
2. **Markdown links.**  Every relative link target in README.md and
   DESIGN.md must exist, and every ``#fragment`` must resolve to a
   heading of the target file (GitHub-style slugs).
3. **Service/obs docstrings.**  Every public module/class/function/method
   in ``src/repro/service/`` and ``src/repro/obs/`` must carry a
   docstring — the service layer's thread-safety contracts and the
   tracing layer's clock/no-op contracts live there (DESIGN.md §9/§10,
   §13), so a missing docstring is missing documentation of who may
   touch what under which lock.
4. **Declared public surface.**  ``repro.core``, ``repro.service``,
   ``repro.dist``, and ``repro.obs`` declare their stable API via
   ``__all__``: every public
   name the package ``__init__`` binds must appear in ``__all__`` and
   vice versa, so a re-export added without declaring it (or a stale
   ``__all__`` entry after a rename) fails the docs job, not a user's
   ``import *``.

Run from the repo root (CI docs job and tests/test_docs.py both do):

    python tools/check_docs.py
"""

from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

CITE = re.compile(r"DESIGN\.md\s*§(\d+)")
HEADER = re.compile(r"^#+\s*§(\d+)\b", re.M)
# [text](target) — target without scheme/mailto is a repo-relative link
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
MD_HEADING = re.compile(r"^#+\s+(.*)$", re.M)


def cited_sections() -> dict[str, set[str]]:
    """section number -> files citing it."""
    cites: dict[str, set[str]] = {}
    for path in sorted((ROOT / "src").rglob("*.py")):
        for num in CITE.findall(path.read_text()):
            cites.setdefault(num, set()).add(str(path.relative_to(ROOT)))
    return cites


# ------------------------------------------------------------- markdown links
def heading_slugs(md_text: str) -> set[str]:
    """GitHub-style anchor slugs for every heading: lowercase, punctuation
    stripped (including '§'), spaces to dashes."""
    slugs = set()
    for title in MD_HEADING.findall(md_text):
        title = re.sub(r"[`*_]", "", title).strip()
        slug = re.sub(r"[^\w\- ]", "", title.lower())
        slugs.add(re.sub(r" +", "-", slug.strip()))
    return slugs


def link_problems(md_text: str, source: str, root: pathlib.Path) -> list[str]:
    """Dangling relative links/anchors in one markdown document.  Pure
    function of the text (unit-tested directly in tests/test_docs.py)."""
    problems = []
    for target in LINK.findall(md_text):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
            continue
        path_part, _, fragment = target.partition("#")
        if path_part:
            dest = (root / path_part).resolve()
            if not dest.exists():
                problems.append(f"{source}: link target {path_part!r} does not exist")
                continue
        else:
            dest = root / source
        if fragment:
            if dest.suffix != ".md" or not dest.is_file():
                problems.append(
                    f"{source}: anchor {target!r} points into a non-markdown target"
                )
                continue
            if fragment not in heading_slugs(dest.read_text()):
                problems.append(
                    f"{source}: anchor #{fragment} has no matching heading in "
                    f"{dest.name}"
                )
    return problems


def markdown_problems() -> list[str]:
    problems = []
    for name in ("README.md", "DESIGN.md"):
        path = ROOT / name
        if path.exists():
            problems += link_problems(path.read_text(), name, ROOT)
    return problems


# ----------------------------------------------------- service/obs docstrings
DOCSTRING_DIRS = ("service", "obs")
def _missing_docstrings(tree: ast.Module, rel: str) -> list[str]:
    missing = []
    if ast.get_docstring(tree) is None:
        missing.append(f"{rel}: module has no docstring")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            if node.name.startswith("_"):
                continue
            if ast.get_docstring(node) is None:
                missing.append(f"{rel}: public {node.name!r} has no docstring")
            if isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if (
                        isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and not sub.name.startswith("_")
                        and ast.get_docstring(sub) is None
                    ):
                        missing.append(
                            f"{rel}: public method "
                            f"{node.name}.{sub.name!r} has no docstring"
                        )
    return missing


def service_docstring_problems() -> list[str]:
    """Undocumented public symbols under src/repro/service/ and
    src/repro/obs/ (ast-based, so the check needs no imports and runs in
    the bare docs job)."""
    problems = []
    for pkg in DOCSTRING_DIRS:
        for path in sorted((ROOT / "src" / "repro" / pkg).glob("*.py")):
            rel = str(path.relative_to(ROOT))
            problems += _missing_docstrings(ast.parse(path.read_text()), rel)
    return problems


def public_service_symbols() -> int:
    """Count of public defs the docstring check covers (non-vacuity probe
    for tests)."""
    count = 0
    for pkg in DOCSTRING_DIRS:
        for path in sorted((ROOT / "src" / "repro" / pkg).glob("*.py")):
            for node in ast.walk(ast.parse(path.read_text())):
                if isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
                ) and not node.name.startswith("_"):
                    count += 1
    return count


# ------------------------------------------------------------- public surface
PUBLIC_PACKAGES = ("core", "service", "dist", "obs")


def _bound_public_names(tree: ast.Module) -> set[str]:
    """Public names a package ``__init__`` binds at the top level:
    re-exports (``from ... import``), defs, and simple assignments."""
    names: set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.ImportFrom):
            names.update(a.asname or a.name for a in node.names)
        elif isinstance(node, ast.Import):
            names.update((a.asname or a.name).split(".")[0] for a in node.names)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            names.update(
                t.id for t in node.targets if isinstance(t, ast.Name)
            )
    return {n for n in names if not n.startswith("_") and n != "*"}


def _declared_all(tree: ast.Module) -> list[str] | None:
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "__all__" for t in node.targets
        ):
            try:
                value = ast.literal_eval(node.value)
            except ValueError:
                return None
            return [str(v) for v in value]
    return None


def public_api_problems() -> list[str]:
    """Undeclared or stale ``__all__`` entries in the stable packages
    (ast-based — no imports, so the bare docs job can run it)."""
    problems = []
    for pkg in PUBLIC_PACKAGES:
        path = ROOT / "src" / "repro" / pkg / "__init__.py"
        rel = str(path.relative_to(ROOT))
        tree = ast.parse(path.read_text())
        declared = _declared_all(tree)
        if declared is None:
            problems.append(f"{rel}: package declares no literal __all__")
            continue
        bound = _bound_public_names(tree)
        for name in sorted(bound - set(declared)):
            problems.append(
                f"{rel}: public symbol {name!r} is bound but missing from __all__"
            )
        for name in sorted(set(declared) - bound):
            problems.append(
                f"{rel}: __all__ lists {name!r} but the package does not bind it"
            )
        if sorted(declared) != declared:
            problems.append(f"{rel}: __all__ is not sorted")
    return problems


# ------------------------------------------------------------------ top level
def check() -> list[str]:
    problems = []
    design = ROOT / "DESIGN.md"
    if not design.exists():
        return ["DESIGN.md does not exist but src/ docstrings cite it"]
    headers = set(HEADER.findall(design.read_text()))
    for num, files in sorted(cited_sections().items(), key=lambda kv: int(kv[0])):
        if num not in headers:
            problems.append(
                f"DESIGN.md §{num} is cited by {', '.join(sorted(files))} "
                f"but DESIGN.md has no '§{num}' header"
            )
    if not (ROOT / "README.md").exists():
        problems.append("README.md does not exist")
    problems += markdown_problems()
    problems += service_docstring_problems()
    problems += public_api_problems()
    return problems


def main() -> int:
    problems = check()
    for p in problems:
        print(f"ERROR: {p}", file=sys.stderr)
    if not problems:
        cites = cited_sections()
        total = sum(len(v) for v in cites.values())
        print(
            f"docs OK: {len(cites)} DESIGN.md sections cited from "
            f"{total} file references; markdown links resolve; "
            f"{public_service_symbols()} public service symbols documented; "
            f"__all__ consistent across {len(PUBLIC_PACKAGES)} packages"
        )
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
