#!/usr/bin/env python
"""Summarize a Chrome trace-event JSON dump produced by ``--trace``
(DESIGN.md §13).

    PYTHONPATH=src python tools/trace_summary.py --trace out.json [--top K]

Loads the dump back into ``SpanEvent``s (``repro.obs.load_trace``), then
prints the per-phase cost rollup (count, inclusive total, exclusive
self-time, slowest instance — largest self-time first) and the K slowest
individual spans with their attrs.  The same numbers Perfetto would show
interactively, but greppable — CI logs and benchmark JSON artifacts
carry the identical rollup, so a regression can be pinned to a phase
without opening a UI.
"""

from __future__ import annotations

import argparse
import sys


def summarize(path: str, top_k: int = 10) -> str:
    """The printed summary for one trace file (pure; tested directly)."""
    from repro.obs import format_rollup, load_trace, rollup, top_spans

    events = load_trace(path)
    if not events:
        return f"{path}: no spans"
    t_lo = min(e.t0 for e in events)
    t_hi = max(e.t0 + e.dur for e in events)
    lines = [
        f"{path}: {len(events)} spans across "
        f"{len({e.thread for e in events})} tracks, "
        f"{t_hi - t_lo:.3f}s span window",
        "",
        format_rollup(rollup(events)),
        "",
        f"top {top_k} slowest spans:",
    ]
    for ev in top_spans(events, k=top_k):
        attrs = " ".join(f"{k}={v}" for k, v in sorted(ev.attrs.items()))
        lines.append(
            f"  {ev.dur*1e3:>9.1f}ms {ev.name:<24} [{ev.thread}] {attrs}"
        )
    return "\n".join(lines)


def main(argv=None) -> int:
    """CLI entry point: ``--trace`` file(s) to summarize, ``--top K``."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--trace", required=True, nargs="+", metavar="OUT.json",
        help="trace file(s) written by --trace / repro.obs.write_trace",
    )
    ap.add_argument("--top", type=int, default=10, metavar="K",
                    help="how many slowest spans to list (default 10)")
    args = ap.parse_args(argv)
    for path in args.trace:
        print(summarize(path, top_k=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
